"""Placement machinery edge cases: tied Pareto points, empty task
lists, and the greedy fallback for DAGs too big to enumerate must all
return a valid (runtime, cloud-cost) frontier."""
import numpy as np

from repro.core.placement import (Task, enumerate_placements, pareto_filter,
                                  simulate)


def _is_valid_frontier(points):
    """No kept point may dominate another kept point (<= in both dims,
    < in at least one)."""
    for i, (rt_i, cl_i) in enumerate(points):
        for j, (rt_j, cl_j) in enumerate(points):
            if i == j:
                continue
            if rt_i <= rt_j and cl_i <= cl_j and (rt_i < rt_j or cl_i < cl_j):
                return False
    return True


def test_pareto_filter_tied_points_keep_one():
    """Exactly tied (runtime, cost) points collapse to one frontier
    entry; the result is still a valid frontier covering every input."""
    pts = [(1.0, 2.0, 0), (1.0, 2.0, 1), (2.0, 1.0, 2), (2.0, 1.0, 3),
           (3.0, 1.0, 4)]                  # 4 dominated by 2, ties 0/1, 2/3
    keep = pareto_filter(pts)
    assert keep == [0, 2]
    kept = [(pts_rt, pts_cl) for pts_rt, pts_cl, i in pts if i in keep]
    assert _is_valid_frontier(kept)
    # every input point is matched-or-dominated by some kept point
    for rt, cl, _ in pts:
        assert any(k_rt <= rt and k_cl <= cl for k_rt, k_cl in kept)


def test_pareto_filter_all_identical():
    pts = [(5.0, 5.0, i) for i in range(4)]
    keep = pareto_filter(pts)
    assert keep == [0]


def test_enumerate_placements_empty_task_list():
    """No tasks: one trivial all-on-prem placement with zero cost."""
    out = enumerate_placements([], n_cores=4)
    assert len(out) == 1
    mask, rt, on_s, cl_s = out[0]
    assert mask == () and rt == 0.0 and on_s == 0.0 and cl_s == 0.0


def test_simulate_empty_task_list():
    rt, on_s, cl_s = simulate([], [], n_cores=2)
    assert rt == 0.0 and on_s == 0.0 and cl_s == 0.0


def _chain(n):
    """n-task chain with varied durations/sizes."""
    return [Task(f"t{i}", (i - 1,) if i else (), 10.0 + 3.0 * (i % 5),
                 4.0 + 2.0 * (i % 3), 0.5 + 0.1 * i, 0.2)
            for i in range(n)]


def test_enumerate_placements_greedy_fallback_valid_frontier():
    """DAGs above the exhaustive limit (>14 tasks) take the greedy
    fallback, which must still return a frontier: sorted by cloud cost,
    mutually non-dominating, and containing the zero-cloud placement the
    throughput guarantee relies on."""
    tasks = _chain(16)
    out = enumerate_placements(tasks, n_cores=4)
    assert len(out) >= 1
    cls = [cl for _, _, _, cl in out]
    assert cls == sorted(cls)
    assert cls[0] == 0.0                    # all-on-prem endpoint kept
    assert _is_valid_frontier([(rt, cl) for _, rt, _, cl in out])
    # masks must be real placements for THIS dag
    for mask, rt, on_s, cl_s in out:
        assert len(mask) == len(tasks)
        rt2, on2, cl2 = simulate(tasks, mask, 4)
        assert (rt2, on2, cl2) == (rt, on_s, cl_s)


def test_enumerate_placements_exhaustive_matches_greedy_endpoints():
    """At <=14 tasks the exhaustive frontier contains the all-on-prem
    placement and is valid."""
    tasks = _chain(6)
    out = enumerate_placements(tasks, n_cores=4)
    masks = [m for m, *_ in out]
    assert tuple(False for _ in tasks) in masks
    assert _is_valid_frontier([(rt, cl) for _, rt, _, cl in out])

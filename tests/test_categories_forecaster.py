"""Content categories (KMeans) + forecasting model."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.categories import classify_1d, classify_full, kmeans
from repro.core.forecaster import (forecast, init_forecaster, make_dataset,
                                   train_forecaster)


def test_kmeans_recovers_clusters():
    rng = np.random.default_rng(0)
    true_centers = np.array([[0.1, 0.2], [0.5, 0.6], [0.9, 0.95]])
    X = np.concatenate([c + rng.normal(0, 0.02, (100, 2))
                        for c in true_centers]).astype(np.float32)
    centers, assign = kmeans(X, 3, seed=1)
    centers = np.asarray(centers)
    # ordered by mean quality; must match true centers closely
    np.testing.assert_allclose(centers, true_centers, atol=0.05)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_assignment_is_nearest_center(seed):
    rng = np.random.default_rng(seed)
    X = rng.random((50, 4)).astype(np.float32)
    centers, assign = kmeans(X, 3, iters=10, seed=seed)
    centers, assign = np.asarray(centers), np.asarray(assign)
    d = ((X[:, None] - centers[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(assign, d.argmin(1))


def test_classify_1d_matches_full_when_discriminative():
    # categories separated along every config axis -> 1-D classification
    # agrees with full-vector classification (paper §4.2 premise)
    centers = jnp.asarray([[0.2, 0.3], [0.5, 0.6], [0.8, 0.9]])
    for c in range(3):
        vec = centers[c] + 0.02
        assert int(classify_full(vec, centers)) == c
        for k in range(2):
            assert int(classify_1d(vec[k], k, centers)) == c


def test_forecaster_learns_periodic_pattern():
    # synthetic periodic labels: category = (t // 10) % 3
    T = 3000
    labels = (np.arange(T) // 10) % 3
    X, Y = make_dataset(labels, 3, interval=30, n_split=4, horizon=30)
    params = init_forecaster(jax.random.PRNGKey(0), 4, 3)
    before = float(jnp.mean(jnp.abs(forecast(params, jnp.asarray(X)) - Y)))
    params, metrics = train_forecaster(params, X, Y, epochs=30)
    after = metrics["val_mae"]
    assert after < before
    assert after < 0.05


def test_forecast_is_distribution():
    params = init_forecaster(jax.random.PRNGKey(0), 4, 5)
    h = jnp.ones((4, 5)) / 5
    r = forecast(params, h)
    np.testing.assert_allclose(float(r.sum()), 1.0, atol=1e-5)

"""Fused whole-run ingestion engine: the single-dispatch outer scan
(forecast -> LP -> switch, ``run_skyscraper_fused``) must reproduce the
windowed host loop for every forecast mode — including a padded tail
window — and the serving pool's device-side planning must never
recompile after warmup."""
import numpy as np
import pytest

from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.core.switcher import compile_cache_size, compile_cache_sizes
from repro.data.stream import generate


@pytest.fixture(scope="module")
def fitted():
    return fit(COVID, n_cores=8, days_unlabeled=2.0, n_categories=4, seed=0)


@pytest.fixture(scope="module")
def stream():
    # T = 4752 segments; with plan_days=0.02 -> W = 864, so the run is
    # 5 full windows + a 432-segment tail (T not divisible by W)
    return generate(COVID, days=0.11, seed=42)


RUN_KW = dict(n_cores=8, cloud_budget_core_s=3_000.0, plan_days=0.02)


@pytest.mark.parametrize("mode", ["oracle", "model", "uniform"])
def test_fused_matches_windowed(fitted, stream, mode):
    W = max(1, int(RUN_KW["plan_days"] * 86400
                   / fitted.workload.segment_seconds))
    assert stream.n_segments % W != 0, "test must cover a padded tail"
    ref = IG.run_skyscraper(fitted, stream, forecast_mode=mode, **RUN_KW)
    got = IG.run_skyscraper_fused(fitted, stream, forecast_mode=mode,
                                  **RUN_KW)
    # float32 tolerance on every accumulated quantity; the discrete
    # decision traces are identical in practice but the windowed loop
    # forecasts in float64 numpy while the fused engine is float32
    # on-device, so a 1-ulp rounding difference may legitimately flip an
    # argmax tie on some platforms — allow 0.1% of decisions to differ
    rtol = 5e-4
    T = stream.n_segments
    assert got.quality_sum == pytest.approx(ref.quality_sum, rel=rtol)
    assert got.onprem_core_s == pytest.approx(ref.onprem_core_s, rel=rtol)
    assert got.cloud_core_s == pytest.approx(ref.cloud_core_s,
                                             rel=rtol, abs=1.0)
    assert got.buffer_peak_s == pytest.approx(ref.buffer_peak_s, rel=rtol,
                                              abs=1.0)
    assert got.quality_max_sum == pytest.approx(ref.quality_max_sum)
    allow = max(3, int(0.001 * T))
    assert int(np.abs(got.k_hist - ref.k_hist).sum()) <= 2 * allow
    assert int((got.c_trace != ref.c_trace).sum()) <= allow
    assert int((got.k_trace != ref.k_trace).sum()) <= allow
    assert len(got.plans) == len(ref.plans)
    for (r_f, a_f), (r_w, a_w) in zip(got.plans, ref.plans):
        np.testing.assert_allclose(r_f, r_w, atol=1e-5)
        # alpha rows can differ wholesale at an LP vertex tie; require
        # near-universal agreement instead of bit equality
        assert (np.abs(a_f - a_w) <= 1e-4).mean() >= 0.99


def test_fused_cloud_path_matches_windowed(fitted, stream):
    """A tiny buffer forces cloud placements: the in-carry cloud-budget
    ration must track the host loop's bookkeeping."""
    kw = dict(n_cores=8, cloud_budget_core_s=5_000.0, buffer_gb=0.05,
              plan_days=0.02, forecast_mode="oracle")
    ref = IG.run_skyscraper(fitted, stream, **kw)
    got = IG.run_skyscraper_fused(fitted, stream, **kw)
    assert ref.cloud_core_s > 0.0, "setup must exercise the cloud path"
    assert got.cloud_core_s == pytest.approx(ref.cloud_core_s, rel=5e-4,
                                             abs=1.0)
    assert got.quality_sum == pytest.approx(ref.quality_sum, rel=5e-4)
    assert got.cloud_core_s <= 5_000.0 + 1e-3


def test_fused_single_dispatch_compiles_once(fitted, stream):
    """Re-running the fused engine with the same shapes/mode must not
    add jit cache entries — the whole run stays one executable."""
    IG.run_skyscraper_fused(fitted, stream, forecast_mode="oracle",
                            **RUN_KW)                       # warmup
    n0 = IG.fused_cache_size()
    IG.run_skyscraper_fused(fitted, stream, forecast_mode="oracle",
                            **RUN_KW)
    IG.run_skyscraper_fused(fitted, stream, forecast_mode="oracle",
                            n_cores=8, cloud_budget_core_s=9_999.0,
                            plan_days=0.02)                 # budget is traced
    assert IG.fused_cache_size() == n0


def test_fused_multi_matches_windowed_multi(fitted):
    """The fused multi-stream engine agrees with the windowed host loop
    (same joint LP optimum; vertex ties may differ, so compare the
    realized quality, not bit-level traces)."""
    s1 = generate(COVID, days=0.1, seed=5)
    s2 = generate(COVID, days=0.1, seed=17)
    kw = dict(n_cores_each=8, cloud_budget_core_s=2_000.0)
    got = IG.run_skyscraper_multi([fitted, fitted], [s1, s2], **kw)
    ref = IG.run_skyscraper_multi_windowed([fitted, fitted], [s1, s2], **kw)
    assert got["quality_pct"] == pytest.approx(ref["quality_pct"], abs=0.1)
    np.testing.assert_allclose(got["per_stream_pct"],
                               ref["per_stream_pct"], atol=0.1)


def _make_pool(V=3, plan_segments=12):
    from repro.core.api import Skyscraper, SkyscraperPool
    rng = np.random.default_rng(0)
    mat = rng.normal(0, 1, (64, 64)).astype(np.float32)
    segments = [{"d": float(d)} for d in np.linspace(0.0, 1.0, 40)]

    def proc(seg, knobs):
        n = knobs["samples"]
        acc = mat
        for _ in range(4 * n):              # cost grows with the knob
            acc = acc @ mat
        return seg["d"], 1.0 - seg["d"] * (1.0 - 0.8 * n / 4.0)

    sky = Skyscraper(segment_seconds=1.0, n_categories=3)
    sky.set_resources(num_cores=1, buffer_gb=0.1)
    sky.register_knob("samples", [1, 2, 4])
    sky.fit(segments, proc, plan_segments=plan_segments, profile_repeats=3)
    if len(sky.configs) > 1:
        # budget strictly inside the cost range -> the planner must mix,
        # so plans respond to the forecasted content distribution
        sky.set_budget(0.5 * (float(sky.cost.min()) + float(sky.cost.max())))
    return SkyscraperPool(sky, n_streams=V), segments, rng


def test_pool_fused_zero_recompiles_across_windows():
    """SkyscraperPool on the fused engine: ticking V streams through 3+
    planning windows (including replans after the label buffers fill, so
    the uniform->model flip is covered) must keep every jit cache
    stable after the first window's warmup."""
    pool, segments, rng = _make_pool(V=3, plan_segments=12)
    plan_every = pool.sky._plan_every

    def tick():
        segs = [segments[rng.integers(len(segments))]
                for _ in range(pool.V)]
        statuses, _ = pool.process(segs)
        return statuses

    for _ in range(plan_every + 1):        # warmup: step+shift+replan
        tick()
    sizes0 = compile_cache_sizes()
    tuple0 = compile_cache_size()
    for _ in range(3 * plan_every + 2):    # 3+ more planning windows
        statuses = tick()
    assert compile_cache_sizes() == sizes0, (compile_cache_sizes(), sizes0)
    assert compile_cache_size() == tuple0
    assert len(statuses) == pool.V
    assert all(np.isfinite(s["quality"]) for s in statuses)


def test_pool_fused_plans_adapt_to_history():
    """After the rolling label buffers fill, the device-side replan must
    switch from the uniform prior to the forecaster (plans change)."""
    import jax.numpy as jnp
    pool, segments, rng = _make_pool(V=2, plan_segments=8)
    assert len(pool.sky.configs) > 1, "fixture must keep >1 Pareto config"
    a0 = np.asarray(pool._alpha)
    # feed hard content only -> histories skew -> forecast != uniform
    for _ in range(max(pool._hist_len, pool.sky._plan_every) * 2):
        pool.process([segments[-1]] * pool.V)
    assert pool._seen >= pool._hist_len
    assert int(jnp.sum(pool._bufs >= 0)) == pool._bufs.size
    a1 = np.asarray(pool._alpha)
    assert a0.shape == a1.shape
    assert np.abs(a1 - a0).max() > 1e-6, "replan never left the prior"

"""The host-side dispatch tracer: span records + Chrome-trace output,
the ``OBS.json`` regression gates (ceilings, host-class-gated span
floors, topology skips, disappearing engines), and the three-way
observability coverage lint."""

import copy
import json

import numpy as np

from repro.analysis.run import coverage_violations
from repro.obs import validate_chrome_trace
from repro.obs.run import SPAN_FLOOR_US, compare, main, run_obs
from repro.obs.trace import SpanRecorder, trace_all

# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_trace_subset_records_and_chrome_trace():
    records, trace = trace_all(only="switch_step", reps=2)
    assert records, "substring filter matched no engines"
    for name, rec in records.items():
        assert "skipped" not in rec, name
        for key in ("cold_us", "span_us", "span_min_us",
                    "new_executables", "recompiles", "arg_bytes",
                    "out_bytes", "host_transfers"):
            assert key in rec, f"{name} missing {key}"
        assert rec["recompiles"] == 0
        assert rec["host_transfers"] == 0
        assert rec["span_us"] >= rec["span_min_us"] > 0
        assert rec["arg_bytes"] > 0 and rec["out_bytes"] > 0
    assert validate_chrome_trace(trace) == []
    # cold + reps warm spans per engine
    assert len(trace["traceEvents"]) == 3 * len(records)
    json.dumps(trace)                       # round-trips


def test_validate_chrome_trace_catches_malformed():
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [{"ph": "X", "ts": 0.0, "pid": 0, "tid": 0,
                            "dur": -1.0}]}
    problems = validate_chrome_trace(bad)
    assert any("missing 'name'" in p for p in problems)
    assert any("negative dur" in p for p in problems)
    unserializable = {"traceEvents": [
        {"name": "x", "ph": "X", "ts": 0.0, "pid": 0, "tid": 0,
         "args": {"a": np.float32(1.0)}}]}
    assert any("serializable" in p
               for p in validate_chrome_trace(unserializable))


def test_span_recorder_clamps_duration():
    rec = SpanRecorder()
    t = rec.origin
    rec.span("zero", "cat", t, t, tid=0)    # zero-length span
    ev = rec.chrome_trace()["traceEvents"][0]
    assert ev["dur"] > 0                    # clamped, still renders


# ---------------------------------------------------------------------------
# OBS.json compare gates (synthetic reports: each gate in isolation)
# ---------------------------------------------------------------------------

def _report(**eng):
    rec = {"span_us": 6000.0, "cold_us": 1e5, "new_executables": 1,
           "recompiles": 0, "host_transfers": 0}
    rec.update(eng)
    return {"schema": 1, "topology": {"n_devices": 1},
            "host": {"host_cores": 4.0}, "engines": {"e": rec},
            "n_engines": 1, "n_skipped": 0}


def test_compare_clean_baseline_passes():
    base = _report()
    assert compare(copy.deepcopy(base), base) == []


def test_compare_ceilings_zero_headroom():
    base = _report()
    for key in ("new_executables", "recompiles", "host_transfers"):
        new = _report(**{key: base["engines"]["e"][key] + 1})
        regs = compare(new, base)
        assert len(regs) == 1 and key in regs[0] and "ceiling" in regs[0]


def test_compare_span_floor_only_above_noise_floor():
    base = _report()
    assert compare(_report(span_us=7100.0), base) == []      # within 20%
    regs = compare(_report(span_us=7300.0), base)            # >20%
    assert len(regs) == 1 and "span_us" in regs[0]
    # micro-span baselines never gate, however large the ratio
    tiny = _report(span_us=SPAN_FLOOR_US / 10)
    assert compare(_report(span_us=SPAN_FLOOR_US), tiny) == []


def test_compare_host_class_change_makes_spans_advisory():
    base = _report()
    slow = _report(span_us=50_000.0)
    slow["host"] = {"host_cores": 1.0}
    assert compare(slow, base) == []
    # ceilings still gate across host classes
    slow["engines"]["e"]["recompiles"] = 2
    assert len(compare(slow, base)) == 1


def test_compare_topology_change_skips_engine_gates():
    base = _report()
    other = _report(recompiles=5, span_us=1e6)
    other["topology"] = {"n_devices": 8}
    assert compare(other, base) == []


def test_compare_disappeared_or_skipped_engine_fails():
    base = _report()
    gone = copy.deepcopy(base)
    gone["engines"] = {}
    regs = compare(gone, base)
    assert len(regs) == 1 and "disappeared" in regs[0]
    skipped = copy.deepcopy(base)
    skipped["engines"]["e"] = {"skipped": "no mesh"}
    regs = compare(skipped, base)
    assert len(regs) == 1 and "skipped" in regs[0]
    # a baseline-side skip carries no numbers to gate against
    base_skip = copy.deepcopy(base)
    base_skip["engines"]["e"] = {"skipped": "no mesh"}
    assert compare(copy.deepcopy(base_skip), base_skip) == []


# ---------------------------------------------------------------------------
# driver + coverage lint
# ---------------------------------------------------------------------------

def test_obs_main_writes_reports_and_self_compare_passes(tmp_path):
    out = tmp_path / "OBS.json"
    trace = tmp_path / "TRACE.json"
    rc = main(["--only", "switch_step", "--smoke",
               "--json", str(out), "--trace", str(trace)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["n_engines"] >= 1 and report["engines"]
    assert validate_chrome_trace(json.loads(trace.read_text())) == []
    # the report gates cleanly against itself
    rc = main(["--only", "switch_step", "--smoke",
               "--json", str(out), "--trace", str(trace),
               "--compare", str(out)])
    assert rc == 0


def test_obs_run_marks_topology_and_host():
    report, _ = run_obs(only="switch_step", reps=1, with_hlo=False)
    assert report["topology"]["n_devices"] >= 1
    assert report["host"]["host_cores"] >= 1.0


def test_coverage_lint_clean_on_this_repo():
    """Every cache probe is claimed by an engine, every probe_name
    resolves, every engine is traceable — the three observability
    registries agree."""
    assert coverage_violations() == []


def test_coverage_lint_flags_unclaimed_probe():
    from repro.core.switcher import _CACHE_PROBES, register_cache_probe
    register_cache_probe("obs_test_bogus_probe", lambda: 0)
    try:
        v = coverage_violations()
        assert any(x["check"] == "probe_without_engine"
                   and x["path"] == "obs_test_bogus_probe" for x in v)
        assert all(x["path"] == "obs_test_bogus_probe" for x in v)
    finally:
        del _CACHE_PROBES["obs_test_bogus_probe"]
    assert coverage_violations() == []

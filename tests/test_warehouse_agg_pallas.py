"""Fused Pallas filter+group+aggregate kernel (interpret mode on CPU)
plus the segment-aggregation edge-case contracts it shares with the XLA
path:

- Pallas vs XLA vs numpy agreement for every agg, scalar and wide
  value columns, multi-block grids, and post-reduction nodes;
- the empty-group contract (0.0 / count 0 / masked row — never ±inf)
  on the single-device, sharded (stacked AND collective pmax/pmin on
  the forced-8-device tier-1 leg), and Pallas paths;
- exhaustive ``int_pred`` coverage vs a float64 mirror across
  signs/integrality/out-of-int32-range thresholds (the old ``i±1``
  rewrites mis-bucketed negative non-integral thresholds and broke at
  the int32 clamp edge);
- ``lax.top_k`` tie-breaking (incl. ``-0.0`` vs ``+0.0``, which plain
  ``np.argsort(-score)`` orders differently) mirrored by
  ``execute_ref``;
- the scatter census: ZERO executed scatters on the Pallas path for a
  groupby plan whose XLA path executes >= 1.

fp32 exactness contract for the Pallas path: counts/max/min and
integer-valued sums are exact; float sums/means regroup the addition
across row tiles and match to the same tolerance as multi-shard
merges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jaxpr_lint import lint_jaxpr, trace_closed_jaxpr
from repro.analysis.registry import DEFAULT_INVARIANTS
from repro.kernels.warehouse_agg import FusedAggSpec, fused_segment_agg
from repro.warehouse import (Filter, GroupBy, MultiGroupBy, SegmentStore,
                             ShardedStore, TopK, WindowAgg, execute,
                             execute_ref)
from repro.warehouse import query as Q

AGGS = ("sum", "mean", "count", "max", "min")


def _rows(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "stream_id": rng.integers(0, 6, n).astype(np.int32),
        "t": np.sort(rng.integers(0, 300, n)).astype(np.int32),
        "category": rng.integers(0, 5, n).astype(np.int32),
        "k": rng.integers(0, 3, n).astype(np.int32),
        "quality": rng.random(n).astype(np.float32),
        "on_core_s": (rng.random(n) * 20 - 5).astype(np.float32),
        "cloud_core_s": (rng.random(n) * 5).astype(np.float32),
        "buffer_s": (rng.random(n) * 40).astype(np.float32),
        "out": rng.random((n, 3)).astype(np.float32),
    }


def _store(n=130, seed=0):
    s = SegmentStore(out_dim=3, chunk_rows=48)   # ragged: capacity pad
    if n:
        s.append_rows(_rows(n, seed))
    return s


def _check(table, mask, ref, rmask, value, agg, exact_val=None):
    np.testing.assert_array_equal(np.asarray(mask), rmask)
    np.testing.assert_array_equal(np.asarray(table["count"]),
                                  ref["count"])
    got = np.asarray(table[value], np.float32)
    want = np.asarray(ref[value], np.float32)
    assert np.all(np.isfinite(got)), f"non-finite {agg} result leaked"
    if exact_val if exact_val is not None else agg in ("count", "max",
                                                       "min"):
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("agg", AGGS)
def test_pallas_groupby_matches_ref(agg):
    store = _store()
    plan = (Filter("quality", "ge", 0.3),
            GroupBy("category", "on_core_s", agg=agg, num_groups=5))
    ref, rmask = execute_ref(store.host_rows(), store.n_rows, plan)
    table, mask = execute(store, plan, use_pallas=True)
    _check(table, mask, ref, rmask, "on_core_s", agg)
    # and Pallas == XLA under the same contract
    tx, mx = execute(store, plan, use_pallas=False)
    _check(tx, mx, ref, rmask, "on_core_s", agg)


@pytest.mark.parametrize("agg", ("sum", "mean", "count"))
def test_pallas_wide_multigroupby(agg):
    store = _store()
    plan = (Filter("k", "le", 1),
            MultiGroupBy(keys=("t", "category"), value="out", agg=agg,
                         nums=(4, 5), windows=(100, 0)))
    ref, rmask = execute_ref(store.host_rows(), store.n_rows, plan)
    table, mask = execute(store, plan, use_pallas=True)
    _check(table, mask, ref, rmask, "out", agg)


def test_pallas_window_with_topk_post():
    store = _store()
    plan = (Filter("quality", "ge", 0.4),
            WindowAgg(window=60, value="quality", agg="mean",
                      num_windows=6),
            TopK(3, by="quality"))
    ref, rmask = execute_ref(store.host_rows(), store.n_rows, plan)
    table, mask = execute(store, plan, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(mask), rmask)
    np.testing.assert_array_equal(np.asarray(table["window"]),
                                  ref["window"])
    np.testing.assert_allclose(np.asarray(table["quality"]),
                               ref["quality"], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("agg", AGGS)
def test_multi_block_grid_direct(agg):
    """Force a many-step grid (block_rows << capacity) on the raw
    kernel: the revisited-accumulator pattern across tiles."""
    store = _store(n=140)
    spec = FusedAggSpec(filters=(("quality", "ge", 0),),
                        keys=(("category", 5, 0),),
                        value="buffer_s", agg=agg)
    _, fvals = Q.normalize((Filter("quality", "ge", 0.25),))
    part = fused_segment_agg(store.columns, jnp.int32(store.n_rows),
                             fvals, spec=spec, block_rows=16)
    out, cnt = Q._seg_finalize(part["acc"], part["cnt"], agg)
    ref, _ = execute_ref(store.host_rows(), store.n_rows,
                         (Filter("quality", "ge", 0.25),
                          GroupBy("category", "buffer_s", agg=agg,
                                  num_groups=5)))
    np.testing.assert_array_equal(np.asarray(cnt), ref["count"])
    if agg in ("count", "max", "min"):
        np.testing.assert_array_equal(np.asarray(out), ref["buffer_s"])
    else:
        np.testing.assert_allclose(np.asarray(out), ref["buffer_s"],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# empty-group contract (satellite: ±inf must never leak)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg", AGGS)
@pytest.mark.parametrize("use_pallas", (False, True))
def test_empty_group_contract(agg, use_pallas):
    """A filter that empties a group (and group ids never present at
    all): 0.0 value, count 0, masked-off row — on single-device XLA,
    single-device Pallas, and both sharded modes (stacked here;
    collective pmax/pmin on the forced-8-device leg)."""
    store = _store()
    sharded = ShardedStore(out_dim=3, n_shards=2, chunk_rows=48)
    sharded.append_rows(_rows(130))
    # category 2 emptied by the filter; groups 5..7 never present
    plan = (Filter("category", "ne", 2),
            GroupBy("category", "quality", agg=agg, num_groups=8))
    ref, rmask = execute_ref(store.host_rows(), store.n_rows, plan)
    assert ref["count"][2] == 0 and not rmask[2]
    assert np.all(ref["count"][5:] == 0) and not rmask[5:].any()
    assert np.all(np.isfinite(ref["quality"]))
    assert np.all(ref["quality"][[2, 5, 6, 7]] == 0.0)
    for table, mask in (execute(store, plan, use_pallas=use_pallas),
                        sharded.query(plan, use_pallas=use_pallas)):
        _check(table, mask, ref, rmask, "quality", agg)


@pytest.mark.parametrize("use_pallas", (False, True))
def test_all_rows_filtered(use_pallas):
    """The all-rows-filtered degenerate chunk: every group empty."""
    store = _store()
    for agg in AGGS:
        plan = (Filter("quality", "lt", -5.0),
                GroupBy("category", "quality", agg=agg, num_groups=5))
        ref, rmask = execute_ref(store.host_rows(), store.n_rows, plan)
        assert not rmask.any() and np.all(ref["quality"] == 0.0)
        table, mask = execute(store, plan, use_pallas=use_pallas)
        _check(table, mask, ref, rmask, "quality", agg)


@pytest.mark.parametrize("use_pallas", (False, True))
def test_single_group_degenerate(use_pallas):
    """num_groups=1: the whole store collapses into one accumulator."""
    store = _store()
    for agg in AGGS:
        plan = (GroupBy("k", "quality", agg=agg, num_groups=1),)
        ref, rmask = execute_ref(store.host_rows(), store.n_rows, plan)
        table, mask = execute(store, plan, use_pallas=use_pallas)
        _check(table, mask, ref, rmask, "quality", agg)


def test_empty_store_empty_groups():
    store = _store(n=0)
    cols = {k: np.asarray(v) for k, v in store.columns.items()}
    for agg in ("max", "min", "mean"):
        plan = (GroupBy("category", "quality", agg=agg, num_groups=4),)
        ref, rmask = execute_ref(cols, 0, plan)
        assert not rmask.any() and np.all(ref["quality"] == 0.0)
        for up in (False, True):
            table, mask = execute((store.columns, 0), plan,
                                  use_pallas=up)
            _check(table, mask, ref, rmask, "quality", agg)


# ---------------------------------------------------------------------------
# int_pred exhaustive property coverage (satellite: the ±1 off-by-one)
# ---------------------------------------------------------------------------

_I32 = 2 ** 31
_X_EDGE = np.asarray(
    [-_I32, -_I32 + 1, -7, -6, -5, -2, -1, 0, 1, 2, 5, 6, 7,
     _I32 - 2, _I32 - 1], np.int32)
_THRESHOLDS = [
    -float(_I32) - 0.7, -float(_I32), -_I32 + 0.5, -6.5, -6.0, -5.5,
    -1.5, -1.0, -0.5, -0.0, 0.0, 0.5, 1.0, 2.5, 5.0, 6.999,
    _I32 - 1.5, float(_I32 - 1), _I32 - 0.5, float(_I32), _I32 + 0.7,
    -1e20, 1e20, float("-inf"), float("inf"),
]


@pytest.mark.parametrize("op", ("eq", "ne", "lt", "le", "gt", "ge"))
def test_int_pred_vs_float64(op):
    """Every (threshold sign x integrality x in/out of int32 range)
    bucket against the exact float64 comparison — through the XLA row
    mask, ``execute_ref``, AND the Pallas kernel's in-register
    predicate (as a count aggregation)."""
    cols = {"x": jnp.asarray(_X_EDGE),
            "g": jnp.zeros(len(_X_EDGE), jnp.int32)}
    cols_np = {k: np.asarray(v) for k, v in cols.items()}
    n = len(_X_EDGE)
    cmp = Q._CMP[op]
    cache0 = Q.compile_cache_size()
    for v in _THRESHOLDS:
        want = cmp(_X_EDGE.astype(np.float64), np.float64(v))
        fplan = (Filter("x", op, v),)
        _, mask = Q._run_plan(cols, jnp.int32(n),
                             Q.normalize(fplan)[1], spec=Q.normalize(
                                 fplan)[0])
        np.testing.assert_array_equal(
            np.asarray(mask), want, err_msg=f"XLA {op} {v!r}")
        _, rmask = execute_ref(cols_np, n, fplan)
        np.testing.assert_array_equal(rmask, want,
                                      err_msg=f"ref {op} {v!r}")
        gplan = fplan + (GroupBy("g", "x", agg="count", num_groups=1),)
        table, _ = execute((cols, n), gplan, use_pallas=True)
        assert int(np.asarray(table["count"])[0]) == int(want.sum()), \
            f"pallas {op} {v!r}"
    # thresholds are dynamic operands: the sweep must not recompile
    # (2 XLA plan shapes + 1 Pallas shape for this op, compiled once)
    assert Q.compile_cache_size() - cache0 <= 3


# ---------------------------------------------------------------------------
# top-k tie handling (satellite: lax.top_k vs argsort order)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("largest", (True, False))
def test_topk_duplicate_scores(largest):
    """Duplicate scores — including the +0.0/-0.0 pair, which IEEE
    total order (lax.top_k) ranks but plain argsort(-score) treats as
    equal — must give identical indices, values, and masks."""
    q = np.asarray([0.5, -0.0, 0.0, 0.5, -0.0, 1.0, 0.0, 0.5, -1.0,
                    1.0, -0.0, 0.25], np.float32)
    n = len(q)
    cols = {"quality": jnp.asarray(q),
            "t": jnp.arange(n, dtype=jnp.int32)}
    cols_np = {k: np.asarray(v) for k, v in cols.items()}
    plan = (TopK(6, by="quality", largest=largest),)
    ref, rmask = execute_ref(cols_np, n, plan)
    table, mask = execute((cols, n), plan)
    np.testing.assert_array_equal(np.asarray(table["index"]),
                                  ref["index"])
    np.testing.assert_array_equal(np.asarray(table["quality"]),
                                  ref["quality"])
    np.testing.assert_array_equal(np.asarray(mask), rmask)


def test_topk_ties_after_aggregation():
    """Equal aggregated scores (exact int sums) tie-break identically
    through a GroupBy -> TopK plan."""
    rows = _rows(120)
    rows["k"] = (np.arange(120, dtype=np.int32) % 3)
    rows["category"] = np.zeros(120, np.int32)  # 3 groups, equal counts
    store = SegmentStore(out_dim=3, chunk_rows=48)
    store.append_rows(rows)
    plan = (GroupBy("k", "category", agg="count", num_groups=6),
            TopK(4, by="category"))
    ref, rmask = execute_ref(store.host_rows(), store.n_rows, plan)
    for up in (False, True):
        table, mask = execute(store, plan, use_pallas=up)
        np.testing.assert_array_equal(np.asarray(table["index"]),
                                      ref["index"])
        np.testing.assert_array_equal(np.asarray(mask), rmask)


# ---------------------------------------------------------------------------
# dispatch, caching, and the scatter census
# ---------------------------------------------------------------------------

def test_pallas_no_recompile_across_thresholds():
    store = _store()
    plan0 = (Filter("quality", "ge", 0.2),
             GroupBy("category", "quality", agg="mean", num_groups=5))
    execute(store, plan0, use_pallas=True)
    cache0 = Q.compile_cache_size()
    for thr in (0.1, 0.35, 0.6, 0.9):
        plan = (Filter("quality", "ge", thr),
                GroupBy("category", "quality", agg="mean", num_groups=5))
        execute(store, plan, use_pallas=True)
    assert Q.compile_cache_size() == cache0


def test_unsupported_plans_fall_back():
    """use_pallas=True on plan shapes the fused kernel can't run (pure
    row plans, TopK reducers) silently uses the XLA path."""
    store = _store()
    n = store.n_rows
    for plan in ((Filter("quality", "ge", 0.5),),
                 (Filter("quality", "ge", 0.5), TopK(4, by="quality"))):
        ref, rmask = execute_ref(store.host_rows(), n, plan)
        table, mask = execute(store, plan, use_pallas=True)
        # row-level plans keep capacity padding (masked off); compare
        # the live prefix
        keep = len(rmask)
        np.testing.assert_array_equal(np.asarray(mask)[:keep], rmask)
        assert not np.asarray(mask)[keep:].any()
        np.testing.assert_array_equal(
            np.asarray(table["quality"], np.float32)[:keep],
            ref["quality"])


def test_auto_dispatch_is_xla_on_cpu():
    """The cost-based auto policy never picks interpret-mode Pallas on
    CPU (it is a correctness path, not a fast path)."""
    spec, _ = Q.normalize((GroupBy("category", "quality", num_groups=4),))
    pre, node, _ = Q.split_plan(spec)
    store = _store(n=10)
    assert Q._resolve_use_pallas(None, pre, node, store.columns) is False
    assert Q._resolve_use_pallas(True, pre, node, store.columns) is True


def test_scatter_census_zero_on_pallas_path():
    """THE floor-breaking claim: the groupby plan's XLA path executes
    >= 1 scatter; the identical plan on the Pallas path executes 0 —
    and stays clean on every other jaxpr invariant."""
    store = _store(n=40)
    spec, fvals = Q.normalize(
        (Filter("quality", "ge", 0.25),
         GroupBy("category", "quality", agg="mean", num_groups=5)))
    args = (store.columns, jnp.int32(store.n_rows), fvals)

    def xla(cols, n, fv):
        return Q._run_plan(cols, n, fv, spec=spec, use_pallas=False)

    def pallas(cols, n, fv):
        return Q._run_plan(cols, n, fv, spec=spec, use_pallas=True)

    v, census = lint_jaxpr(trace_closed_jaxpr(xla, args, {}),
                           DEFAULT_INVARIANTS)
    assert census["totals"]["scatter_executed"] >= 1
    v, census = lint_jaxpr(trace_closed_jaxpr(pallas, args, {}),
                           DEFAULT_INVARIANTS)
    assert [x["check"] for x in v] == []
    assert census["totals"]["scatter_executed"] == 0


# ---------------------------------------------------------------------------
# sharded: fused partials inside the shard_map dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg", AGGS)
def test_sharded_pallas_partials(agg):
    """Per-shard fused kernels + the unchanged psum/pmax merge: stacked
    fallback on 1 device, real collectives on the forced-8-device leg —
    including a shard whose rows are ALL filtered out (the ∓inf
    sentinel must survive the cross-shard merge, then zero-fill)."""
    rows = _rows(160)
    store = ShardedStore(out_dim=3, n_shards=4, chunk_rows=48)
    store.append_rows(rows)
    single = SegmentStore(out_dim=3, chunk_rows=48)
    single.append_rows(rows)
    plan = (Filter("on_core_s", "gt", 12.0),
            GroupBy("stream_id", "on_core_s", agg=agg, num_groups=8))
    ref, rmask = execute_ref(single.host_rows(), single.n_rows, plan)
    table, mask = store.query(plan, use_pallas=True)
    exact = agg in ("count", "max", "min")
    _check(table, mask, ref, rmask, "on_core_s", agg, exact_val=exact)

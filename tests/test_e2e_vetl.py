"""End-to-end V-ETL system behaviour (the paper's headline claims on a
scaled-down stream): Skyscraper beats static at equal provisioning, obeys
the buffer everywhere, respects the cloud budget, and the user-facing
API drives a real UDF."""
import numpy as np
import pytest

from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.data.stream import generate


@pytest.fixture(scope="module")
def fitted():
    return fit(COVID, n_cores=8, days_unlabeled=4.0, n_categories=4, seed=0)


@pytest.fixture(scope="module")
def stream():
    return generate(COVID, days=1.0, seed=42)


def test_skyscraper_beats_static(fitted, stream):
    sky = IG.run_skyscraper(fitted, stream, n_cores=8,
                            cloud_budget_core_s=10_000.0, plan_days=0.25)
    k = IG.best_static_config(fitted, 8)
    st = IG.run_static(fitted, stream, k, n_cores=8)
    assert sky.quality_pct > st.quality_pct + 2.0
    assert not sky.overflow


def test_buffer_and_cloud_limits(fitted, stream):
    sky = IG.run_skyscraper(fitted, stream, n_cores=8,
                            cloud_budget_core_s=500.0, buffer_gb=0.5,
                            plan_days=0.25)
    assert sky.buffer_peak_s <= 0.5 * 1e9 / 90e3 + 1e-3
    assert sky.cloud_core_s <= 500.0 + 1e-3


def test_close_to_optimum(fitted, stream):
    sky = IG.run_skyscraper(fitted, stream, n_cores=8,
                            cloud_budget_core_s=10_000.0, plan_days=0.25)
    opt = IG.run_optimum(fitted, stream, n_cores=8,
                         cloud_budget_core_s=10_000.0)
    assert sky.quality_pct > opt.quality_pct - 6.0, (
        sky.quality_pct, opt.quality_pct)


def test_chameleon_star_overflows_small_hw():
    f4 = fit(COVID, n_cores=4, days_unlabeled=4.0, n_categories=4, seed=0)
    s = generate(COVID, days=1.0, seed=7)
    ch = IG.run_chameleon_star(f4, s, n_cores=4, buffer_gb=0.02)
    sky = IG.run_skyscraper(f4, s, n_cores=4, buffer_gb=0.02,
                            plan_days=0.25)
    assert ch.overflow          # paper: Chameleon* crashes on small hw
    assert not sky.overflow     # Skyscraper's guarantee holds


def test_quality_monotone_in_resources(fitted, stream):
    """More budget can never hurt: quality is (weakly) monotone in the
    cloud budget at fixed provisioning — a basic sanity invariant of the
    planner+switcher pipeline."""
    q = []
    for cloud in (0.0, 5_000.0, 50_000.0):
        r = IG.run_skyscraper(fitted, stream, n_cores=8,
                              cloud_budget_core_s=cloud, plan_days=0.25)
        q.append(r.quality_pct)
    assert q[1] >= q[0] - 0.5 and q[2] >= q[1] - 0.5, q


def test_api_end_to_end():
    """Appendix-F API driving a real (toy) UDF whose cost scales with
    the knob, under a budget that cannot afford the best config always."""
    from repro.core.api import Skyscraper

    rng = np.random.default_rng(0)
    mat = rng.normal(0, 1, (96, 96)).astype(np.float32)
    segments = [{"x": rng.normal(0, 1, (8, 16)).astype(np.float32),
                 "difficulty": float(d)}
                for d in np.concatenate([np.linspace(0, 1, 30),
                                         np.linspace(1, 0, 30)])]

    def proc(seg, knobs):
        n = knobs["samples"]
        acc = mat
        for _ in range(4 * n):              # cost grows with the knob
            acc = acc @ mat
        y = float(np.tanh(seg["x"][:max(n // 2, 1)]).mean())
        qual = 1.0 - seg["difficulty"] * (1.0 - 0.85 * n / 8.0)
        return y, qual

    sky = Skyscraper(segment_seconds=1.0, n_categories=3)
    sky.set_resources(num_cores=1, buffer_gb=0.1)
    sky.register_knob("samples", [1, 2, 4, 8])
    sky.fit(segments, proc, plan_segments=30, profile_repeats=3)
    assert len(sky.configs) >= 2
    # budget strictly inside the config cost range -> planner must mix
    sky.set_budget(0.5 * (float(sky.cost.min()) + float(sky.cost.max())))
    ks = []
    for seg in segments:
        info, out = sky.process(seg)
        ks.append(info["k"])
    assert len(set(ks)) > 1, "switcher never adapted"

"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
this suite uses (``given``/``settings``/``strategies``), activated by
conftest.py ONLY when the real package is not installed (the CI image
installs requirements-dev.txt and gets the real thing; hermetic
containers without network fall back to this).

It is a genuine property runner, not a stub: each ``@given`` test is
executed ``max_examples`` times with values drawn from a deterministic
PRNG, and a failure reports the falsifying example. It implements none
of hypothesis' shrinking or coverage-guided generation — keep using the
real package where available (see requirements-dev.txt).
"""
from __future__ import annotations

import functools
import inspect
import random
import types

__version__ = "0.0-fallback"


class SearchStrategy:
    def __init__(self, draw_fn, label="strategy"):
        self._draw = draw_fn
        self._label = label

    def draw(self, rng):
        return self._draw(rng)

    def __repr__(self):
        return self._label


def _integers(min_value, max_value):
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          f"integers({min_value}, {max_value})")


def _floats(min_value, max_value):
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          f"floats({min_value}, {max_value})")


def _booleans():
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")


def _sampled_from(elements):
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements), "sampled_from")


def _tuples(*strats):
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strats),
                          f"tuples({', '.join(map(repr, strats))})")


def _lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        return [elements.draw(rng) for _ in range(rng.randint(min_size, hi))]

    return SearchStrategy(draw, f"lists({elements!r})")


def _composite(fn):
    @functools.wraps(fn)
    def make(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strat: strat.draw(rng), *args, **kwargs)

        return SearchStrategy(draw_value, f"composite:{fn.__name__}")

    return make


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, booleans=_booleans,
    sampled_from=_sampled_from, tuples=_tuples, lists=_lists,
    composite=_composite, SearchStrategy=SearchStrategy)

_DEFAULT_MAX_EXAMPLES = 50


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                values = [s.draw(rng) for s in strats]
                try:
                    fn(*args, *values, **kwargs)
                except Exception:
                    print(f"Falsifying example ({i + 1}/{n}): "
                          f"{fn.__name__}(*{values!r})")
                    raise

        # hide the strategy-filled parameters from pytest's fixture
        # resolution; strategies fill the RIGHTMOST parameters (values
        # are appended after fixture args), so keep the leading ones
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[:len(params) - len(strats)]
        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


class assume:  # pragma: no cover - parity hook, unused by this suite
    def __new__(cls, condition):
        if not condition:
            raise AssertionError("assume() failed (fallback treats as error)")
        return True

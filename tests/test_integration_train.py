"""End-to-end training integration: loss decreases, checkpoint/restart
(failure injection), and elastic resharding across device counts."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_train_loss_decreases():
    from repro.launch.train import main
    losses = main(["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "60",
                   "--batch", "8", "--seq", "64", "--lr", "3e-3",
                   "--log-every", "10"])
    assert losses[-1] < losses[0] - 0.3, losses


def test_failure_injection_and_resume(tmp_path):
    """Kill training at step 30, relaunch, verify resume + completion —
    the fault-tolerance loop a cluster scheduler would drive."""
    env = dict(os.environ, PYTHONPATH=SRC)
    ckpt = str(tmp_path / "ck")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1.5-0.5b", "--reduced", "--steps", "60", "--batch", "4",
           "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "10",
           "--log-every", "10"]
    p1 = subprocess.run(cmd + ["--simulate-failure", "35"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p1.returncode == 42, p1.stdout + p1.stderr
    assert "SIMULATED FAILURE" in p1.stdout
    from repro.checkpoint import ckpt as CK
    assert CK.latest_step(ckpt) == 30
    p2 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                        timeout=600)
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "resumed from step 30" in p2.stdout
    assert CK.latest_step(ckpt) == 60


def test_elastic_reshard_across_device_counts(tmp_path):
    """Save on an 8-device (4,2) mesh, restore+step on a 4-device (2,2)
    mesh — simulated node loss. Runs in subprocesses because the forced
    host device count is fixed per process."""
    env = dict(os.environ, PYTHONPATH=SRC)
    ckpt = str(tmp_path / "ck")
    prog = r'''
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp
from repro.configs.base import get
from repro.models.model import Model
from repro.models.options import RunOptions
from repro.runtime.steps import (init_train_state, make_train_step,
                                 train_state_shardings)
from repro.runtime.elastic import make_mesh_from, restore_elastic
from repro.checkpoint import ckpt as CK
from repro.distribution import sharding as shd
from repro.data.tokens import make_batch_iter

cfg = get("qwen1.5-0.5b").reduced()
opts = RunOptions(remat="none", layer_loop="scan", compute_dtype="float32",
                  q_chunk=16, kv_chunk=16)
model = Model(cfg, opts)
mesh = make_mesh_from(jax.devices()[:%d], model_axis=2)
with shd.use_mesh(mesh, opts.rules()):
    sh = train_state_shardings(model, mesh)
    if "%s" == "save":
        state = init_train_state(model, jax.random.PRNGKey(0))
        state = jax.device_put(state, sh)
        CK.save("%s", jax.device_get(state), step=1)
        print("SAVED", len(jax.devices()))
    else:
        state, step = restore_elastic("%s", model, mesh)
        assert step == 1
        step_fn = jax.jit(make_train_step(model),
                          in_shardings=(sh, None), out_shardings=(sh, None))
        it = make_batch_iter(cfg, global_batch=4, seq_len=32)
        state, m = step_fn(state, next(it))
        assert bool(jnp.isfinite(m["loss"]))
        print("RESTORED_OK", len(jax.devices()), float(m["loss"]))
'''
    p1 = subprocess.run([sys.executable, "-c",
                         prog % (8, 8, "save", ckpt, ckpt)],
                        env=env, capture_output=True, text=True, timeout=600)
    assert "SAVED 8" in p1.stdout, p1.stdout + p1.stderr
    p2 = subprocess.run([sys.executable, "-c",
                         prog % (4, 4, "load", ckpt, ckpt)],
                        env=env, capture_output=True, text=True, timeout=600)
    assert "RESTORED_OK 4" in p2.stdout, p2.stdout + p2.stderr

"""The flight recorder's overhead contract: ``telemetry=True`` is ONE
extra jit cache entry (still one dispatch, zero warm recompiles), and
``telemetry=False`` lowers to the EXACT pre-telemetry program — pinned
by jaxpr-census equality against the committed ``ANALYSIS.json``."""

import json
import os

import jax
import pytest

from repro.analysis import examples as EX
from repro.analysis.jaxpr_lint import lint_jaxpr, trace_closed_jaxpr
from repro.core.ingest import _fused_run, _fused_run_multi

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _warm(ex):
    jax.block_until_ready(ex.fn(*ex.args, **ex.kwargs))


@pytest.mark.parametrize("builder,builder_tel,fn", [
    (EX.fused_single, EX.fused_single_telemetry, lambda: _fused_run),
    (EX.fused_multi, EX.fused_multi_telemetry, lambda: _fused_run_multi),
])
def test_telemetry_flag_adds_at_most_one_executable(builder, builder_tel,
                                                    fn):
    """Warm no-telemetry engine; first telemetry call may compile ONE
    new executable; every call after that adds zero."""
    probe = fn()._cache_size
    ex, ext = builder(), builder_tel()
    _warm(ex)
    _warm(ex)
    p0 = probe()
    _warm(ex)                       # warm baseline: no growth
    assert probe() == p0
    _warm(ext)                      # the one telemetry cache entry
    p1 = probe()
    assert p1 - p0 <= 1
    _warm(ext)                      # telemetry path is warm too
    _warm(ext)
    assert probe() == p1
    _warm(ex)                       # and the False path stayed warm
    assert probe() == p1


def test_no_telemetry_census_matches_committed_baseline():
    """The telemetry=False jaxpr census equals the committed baseline's
    (op-for-op): the flag's False branch reconstructs the pre-flag
    program exactly, so runs that don't opt in pay literally nothing."""
    path = os.path.join(_ROOT, "ANALYSIS.json")
    with open(path) as fh:
        base = json.load(fh)
    if base["topology"]["n_devices"] != jax.device_count():
        pytest.skip("census baseline was generated on another topology")
    for name, builder in (("fused_single", EX.fused_single),
                          ("fused_multi", EX.fused_multi)):
        ex = builder()
        closed = trace_closed_jaxpr(ex.fn, ex.args, ex.kwargs)
        _, census = lint_jaxpr(closed, {})
        assert census == base["engines"][name]["jaxpr_census"], name


def test_telemetry_variant_is_single_dispatch():
    """The telemetry=True program itself is one executable, zero warm
    recompiles — the flight recorder can't fragment the fused run."""
    for builder, fn in ((EX.fused_single_telemetry, _fused_run),
                        (EX.fused_multi_telemetry, _fused_run_multi)):
        ex = builder()
        p0 = fn._cache_size()
        _warm(ex)
        p1 = fn._cache_size()
        _warm(ex)
        p2 = fn._cache_size()
        assert p1 - p0 <= 1 and p2 == p1

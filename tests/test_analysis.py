"""Static program auditor (repro.analysis): known-bad fixtures per
pass, the clean audit over the full engine registry, and the
baseline-compare regression gate.

Every fixture here is a program with exactly the defect the pass
claims to catch — if a lint rule rots, the fixture stops failing and
this file catches it. The sharded fixtures re-run for real under the
forced-8-device tier-1 leg.
"""
import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.analysis import registry
from repro.analysis.hlo_audit import audit_hlo
from repro.analysis.jaxpr_lint import lint_jaxpr, trace_closed_jaxpr
from repro.analysis.registry import DEFAULT_INVARIANTS as INV
from repro.analysis.run import audit_engine, compare, run_audit
from repro.analysis.source_lint import lint_source
from repro.launch.mesh import make_shard_mesh


def _jaxpr_checks(fn, args):
    v, census = lint_jaxpr(trace_closed_jaxpr(fn, args, {}), INV)
    return [x["check"] for x in v], census


# ---------------------------------------------------------------------------
# pass 1: jaxpr lint
# ---------------------------------------------------------------------------

def test_callback_under_scan_flagged():
    def step(c, x):
        jax.debug.callback(lambda v: None, x)
        return c + x, c

    fn = jax.jit(lambda xs: jax.lax.scan(step, jnp.float32(0), xs))
    checks, _ = _jaxpr_checks(fn, (jnp.ones(4, jnp.float32),))
    assert "host_callback" in checks


def test_f64_leak_flagged():
    with jax.experimental.enable_x64():
        fn = jax.jit(lambda x: x.astype(jnp.float64) * 2)
        checks, _ = _jaxpr_checks(fn, (jnp.ones(3, jnp.float32),))
    assert "f64" in checks


def test_clip_scatter_flagged_and_counted():
    fn = jax.jit(lambda x, i, u: x.at[i].set(u, mode="clip"))
    checks, census = _jaxpr_checks(
        fn, (jnp.zeros(8), jnp.array([2]), jnp.ones(1)))
    assert "scatter_mode" in checks
    assert census["totals"]["scatter_ops"] == 1


def test_default_drop_scatter_clean():
    # .at[].set() without mode defaults to FILL_OR_DROP — the semantics
    # ShardedStore's routed append relies on; must NOT be flagged
    fn = jax.jit(lambda x, i, u: x.at[i].set(u))
    checks, census = _jaxpr_checks(
        fn, (jnp.zeros(8), jnp.array([2]), jnp.ones(1)))
    assert checks == []
    assert census["totals"]["scatter_ops"] == 1


def test_weak_output_flagged():
    fn = jax.jit(lambda x: jnp.asarray(1.0) * 1.0)
    checks, _ = _jaxpr_checks(fn, (jnp.ones(3),))
    assert "weak_type_output" in checks


def test_scan_census_multiplies_trips():
    def step(c, x):
        return c.at[jnp.int32(0)].add(x), x

    fn = jax.jit(lambda xs: jax.lax.scan(step, jnp.zeros(2), xs))
    _, census = _jaxpr_checks(fn, (jnp.ones(7, jnp.float32),))
    t = census["totals"]
    assert t["scatter_ops"] == 1          # one scatter eqn in the body
    assert t["scatter_executed"] == 7     # executed once per scan trip


# ---------------------------------------------------------------------------
# pass 2: HLO audit
# ---------------------------------------------------------------------------

def test_hlo_host_callback_flagged():
    fn = jax.jit(lambda x: jax.pure_callback(
        lambda a: np.asarray(a) * 2,
        jax.ShapeDtypeStruct((3,), jnp.float32), x))
    hlo = fn.lower(jnp.ones(3, jnp.float32)).compile().as_text()
    v, _ = audit_hlo(hlo, INV)
    assert "host_transfer" in [x["check"] for x in v]


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >=2 devices")
def test_unbalanced_collective_flagged():
    mesh = make_shard_mesh(2)

    def body(x):
        return jax.lax.cond(x.sum() > 0,
                            lambda v: jax.lax.psum(v, "shard"),
                            lambda v: v * 2.0, x)

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("shard"),
                           out_specs=P("shard"), check_rep=False))
    hlo = fn.lower(jnp.ones((4, 2))).compile().as_text()
    v, _ = audit_hlo(hlo, INV)
    assert "unbalanced_collective" in [x["check"] for x in v]


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >=2 devices")
def test_balanced_collective_clean():
    mesh = make_shard_mesh(2)

    def body(x):
        return jax.lax.psum(x, "shard")   # unconditional: every shard

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("shard"),
                           out_specs=P(), check_rep=False))
    hlo = fn.lower(jnp.ones((4, 2))).compile().as_text()
    v, info = audit_hlo(hlo, INV)
    assert v == []
    assert sum(info["op_counts"]["collective_counts"].values()) >= 1


# ---------------------------------------------------------------------------
# pass 3: source lint
# ---------------------------------------------------------------------------

def _source_checks(text):
    v, _ = lint_source(text, "fixture")
    return [x["check"] for x in v]


def test_np_call_under_jit_flagged():
    assert "np_call_in_jit" in _source_checks(
        "import jax\nimport numpy as np\n"
        "@jax.jit\ndef f(x):\n    return np.sum(x)\n")


def test_np_call_under_scan_body_flagged():
    # reaches the traced set through lax.scan, not a jit decorator
    assert "np_call_in_jit" in _source_checks(
        "import jax\nimport numpy as np\n"
        "def step(c, x):\n    return c, np.log(x)\n"
        "@jax.jit\ndef f(xs):\n"
        "    return jax.lax.scan(step, 0.0, xs)\n")


def test_python_branch_on_operand_flagged():
    assert "python_branch_on_operand" in _source_checks(
        "import jax\n@jax.jit\ndef f(x):\n"
        "    if x > 0:\n        return x\n    return -x\n")


def test_branch_on_static_argname_clean():
    assert _source_checks(
        "import jax\nfrom functools import partial\n"
        "@partial(jax.jit, static_argnames=('mode',))\n"
        "def f(x, mode):\n"
        "    if mode == 2:\n        return x\n    return -x\n") == []


def test_string_compare_dispatch_clean():
    # `op == 'ge'` style trace-time dispatch (query._int_pred) is fine
    assert _source_checks(
        "import jax\n@jax.jit\ndef f(x, op):\n"
        "    if op == 'ge':\n        return x\n    return -x\n") == []


def test_global_in_jit_flagged():
    assert "global_in_jit" in _source_checks(
        "import jax\n@jax.jit\ndef f(x):\n"
        "    global _g\n    _g = x\n    return x\n")


def test_unhashable_static_default_flagged():
    assert "unhashable_static_default" in _source_checks(
        "import jax\nfrom functools import partial\n"
        "@partial(jax.jit, static_argnames=('cfg',))\n"
        "def f(x, cfg=[1]):\n    return x\n")


def test_static_name_missing_flagged():
    assert "static_name_missing" in _source_checks(
        "import jax\nfrom functools import partial\n"
        "@partial(jax.jit, static_argnames=('mode',))\n"
        "def f(x):\n    return x\n")


def test_missing_docstring_flagged_in_scoped_modules():
    src = ("def public(x):\n    return x\n"
           "class Thing:\n    pass\n"
           "def _private(x):\n    return x\n")
    v, _ = lint_source(src, "repro.core.fake")
    flagged = [x["path"] for x in v if x["check"] == "missing_docstring"]
    assert flagged == ["repro.core.fake:public:1", "repro.core.fake:Thing:3"]
    # unscoped modules don't get the rule
    v2, _ = lint_source(src, "repro.runtime.fake")
    assert [x for x in v2 if x["check"] == "missing_docstring"] == []


def test_docstring_present_clean():
    src = ('def public(x):\n    """Doc."""\n    return x\n'
           'class Thing:\n    """Doc."""\n')
    v, _ = lint_source(src, "repro.warehouse.fake")
    assert [x for x in v if x["check"] == "missing_docstring"] == []


def test_jit_defs_module_level_only():
    _, defs = lint_source(
        "import jax\n"
        "@jax.jit\ndef top(x):\n    return x\n"
        "def factory():\n"
        "    @jax.jit\n    def nested(x):\n        return x\n"
        "    return nested\n"
        "bound = jax.jit(factory)\n", "fixture")
    assert defs == {"fixture:top", "fixture:bound"}


# ---------------------------------------------------------------------------
# the registry + driver
# ---------------------------------------------------------------------------

def _toy_engine(**kw):
    inv = dict(INV)
    inv.update(kw.pop("invariants", {}))
    fn = jax.jit(lambda x: x * 2)
    return registry.Engine(
        "toy", kw.pop("build", lambda: registry.EngineExample(
            fn, (jnp.ones(3, jnp.float32),), {})),
        inv, kw.pop("probe", lambda: fn._cache_size()), ())


def test_missing_probe_is_violation():
    rec = audit_engine(_toy_engine(probe=None))
    assert "missing_probe" in [v["check"] for v in rec["violations"]]


def test_dispatch_cap_enforced():
    rec = audit_engine(_toy_engine(invariants={"max_new_executables": 0}))
    assert "dispatch_count" in [v["check"] for v in rec["violations"]]


def test_skip_engine_recorded():
    def build():
        raise registry.SkipEngine("needs 8 devices")

    rec = audit_engine(_toy_engine(build=build))
    assert rec["skipped"] == "needs 8 devices"
    assert rec["violations"] == []


def test_clean_audit_full_registry():
    """The tier-1 gate: every registered engine passes all three passes
    and every module-level jitted def in core/ / warehouse/ /
    distribution/ is covered by some engine."""
    report = run_audit()
    assert report["n_violations"] == 0, report["violations"]
    assert len(report["engines"]) >= 30
    # census actually quantifies the scatter floor per plan shape
    census = report["engines"]["warehouse_query_filter_groupby"][
        "jaxpr_census"]["totals"]
    assert census["scatter_ops"] >= 1


def test_compare_flags_dispatch_growth():
    old = {"topology": {"n_devices": 1}, "n_violations": 0,
           "engines": {"e": {"dispatch": {"new_executables": 1}}}}
    new = {"topology": {"n_devices": 1}, "n_violations": 0,
           "engines": {"e": {"dispatch": {"new_executables": 2}}}}
    assert any("dispatch count grew" in r for r in compare(new, old))
    assert compare(old, old) == []


def test_compare_flags_new_violations_and_lost_engines():
    old = {"topology": {"n_devices": 1}, "n_violations": 0,
           "engines": {"e": {"dispatch": {"new_executables": 1}}}}
    bad = {"topology": {"n_devices": 1}, "n_violations": 2,
           "engines": {"e": {"dispatch": {"new_executables": 1}}}}
    assert any("violations" in r for r in compare(bad, old))
    gone = {"topology": {"n_devices": 1}, "n_violations": 0, "engines": {}}
    assert any("disappeared" in r for r in compare(gone, old))


def test_compare_skips_dispatch_on_topology_change():
    old = {"topology": {"n_devices": 1}, "n_violations": 0,
           "engines": {"e": {"dispatch": {"new_executables": 1}}}}
    new = {"topology": {"n_devices": 8}, "n_violations": 0,
           "engines": {"e": {"dispatch": {"new_executables": 3}}}}
    assert compare(new, old) == []       # growth excused, not a lie:
    # violations still count under any topology
    new["n_violations"] = 1
    assert len(compare(new, old)) == 1

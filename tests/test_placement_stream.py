"""Placement simulator (App. M) + Pareto filtering + stream generator."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.workloads import COVID, MOSEI_HIGH, MOSEI_LONG, MOT
from repro.core.placement import (enumerate_placements, pareto_filter,
                                  simulate, tasks_from_dag)
from repro.data.stream import generate


def test_all_onprem_vs_cloud_tradeoff():
    tasks = tasks_from_dag(COVID.dag)
    rt_on, on_s, cl_on = simulate(tasks, [False] * len(tasks), n_cores=2)
    rt_cl, _, cl_cl = simulate(tasks, [True] * len(tasks), n_cores=2)
    assert cl_on == 0.0 and cl_cl > 0.0
    assert on_s > 0


def test_enumerate_placements_pareto_and_endpoints():
    tasks = tasks_from_dag(MOT.dag)
    out = enumerate_placements(tasks, n_cores=4)
    cls = [o[3] for o in out]
    rts = [o[1] for o in out]
    # sorted by cloud cost asc; paying more cloud must buy a faster
    # runtime (strictly decreasing along the frontier)
    assert cls == sorted(cls)
    for i in range(1, len(out)):
        assert rts[i] <= rts[i - 1] + 1e-9
    assert cls[0] == 0.0      # all-on-prem endpoint present


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                min_size=1, max_size=30))
def test_pareto_filter_property(pts):
    points = [(rt, cc, i) for i, (rt, cc) in enumerate(pts)]
    keep = pareto_filter(points)
    assert keep
    for i in keep:
        # nothing strictly dominates a kept point
        for j in range(len(pts)):
            if j == i:
                continue
            assert not (pts[j][0] < pts[i][0] - 1e-12
                        and pts[j][1] < pts[i][1] - 1e-12)


def test_stream_statistics_match_paper():
    for w, dwell in [(COVID, 42.0), (MOT, 43.0)]:
        s = generate(w, days=2.0, seed=0)
        # mean dwell time of latent runs ~ paper's reported values
        changes = np.flatnonzero(np.diff(s.latent) != 0)
        runs = np.diff(np.concatenate([[0], changes, [s.n_segments]]))
        mean_dwell_s = runs.mean() * w.segment_seconds
        assert 0.5 * dwell < mean_dwell_s < 2.5 * dwell
        assert s.difficulty.min() >= 0 and s.difficulty.max() <= 1


def test_mosei_spikes_present():
    hi = generate(MOSEI_HIGH, days=1.0, seed=0)
    lo = generate(MOSEI_LONG, days=1.0, seed=0)
    assert hi.arrival.max() >= 4.0           # short tall spikes
    assert (lo.arrival > 2.0).mean() > 0.15  # long sustained peak
    assert hi.arrival.min() >= 1.0


def test_quality_monotone_in_power():
    s = generate(COVID, days=0.2, seed=1)
    power = np.array([0.1, 0.5, 0.9])
    q = s.quality(power, noise_sigma=0.0)
    assert (np.diff(q, axis=1) >= -1e-9).all()

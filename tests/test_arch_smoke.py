"""Per-arch smoke tests (required deliverable f): every assigned
architecture instantiates a REDUCED config of the same family and runs
one forward/train step on CPU asserting output shapes + no NaNs, plus a
prefill->decode consistency check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import registry
from repro.models.model import Model
from repro.models.options import RunOptions

OPTS = RunOptions(remat="none", layer_loop="unroll", compute_dtype="float32",
                  q_chunk=16, kv_chunk=16, ssd_chunk=8, capacity_factor=8.0)
ARCHS = sorted(registry())


def make_batch(rc, key, B=2, S=24):
    if rc.family == "encdec":
        return {"frames": jax.random.normal(key, (B, S, rc.d_model)),
                "tokens": jax.random.randint(key, (B, rc.max_target_len),
                                             0, rc.vocab)}
    if rc.frontend_tokens:
        F = rc.frontend_tokens
        return {"embeds": jax.random.normal(key, (B, F, rc.d_model)),
                "tokens": jax.random.randint(key, (B, S - F), 0, rc.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, rc.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    rc = registry()[arch].reduced()
    model = Model(rc, OPTS)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(rc, key)
    logits = model.forward_logits(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape[0] == B
    assert logits.shape[-1] >= rc.vocab
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    # one gradient step must produce finite grads
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill == incremental full forward."""
    rc = registry()[arch].reduced()
    model = Model(rc, OPTS)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = make_batch(rc, key, B=2, S=17)
    cache_len = (rc.max_target_len if rc.family == "encdec" else 17) + 6
    nxt, cache = model.prefill(params, batch, cache_len=cache_len)
    gen = [nxt]
    for _ in range(2):
        nxt, cache = model.decode_step(params, cache, nxt)
        gen.append(nxt)
    seq = batch["tokens"]
    for step in range(3):
        b2 = dict(batch)
        b2["tokens"] = seq
        logits = model.forward_logits(params, b2)
        nt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        assert bool(jnp.all(gen[step] == nt)), (
            f"{arch} step {step}: {gen[step]} != {nt}")
        seq = jnp.concatenate([seq, nt[:, None]], axis=1)


def test_scan_matches_unroll():
    """layer_loop=scan and =unroll are numerically identical."""
    rc = registry()["llama3-8b"].reduced()
    key = jax.random.PRNGKey(2)
    batch = make_batch(rc, key)
    import dataclasses
    m_u = Model(rc, OPTS)
    m_s = Model(rc, dataclasses.replace(OPTS, layer_loop="scan"))
    params = m_u.init(key)
    lu = m_u.forward_logits(params, batch)
    ls = m_s.forward_logits(params, batch)
    np.testing.assert_allclose(np.asarray(lu), np.asarray(ls), atol=1e-5)


def test_remat_matches_no_remat():
    import dataclasses
    rc = registry()["mixtral-8x7b"].reduced()
    key = jax.random.PRNGKey(3)
    batch = make_batch(rc, key)
    m0 = Model(rc, OPTS)
    m1 = Model(rc, dataclasses.replace(OPTS, remat="full"))
    params = m0.init(key)
    l0, g0 = jax.value_and_grad(m0.loss)(params, batch)
    l1, g1 = jax.value_and_grad(m1.loss)(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), atol=1e-5)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)

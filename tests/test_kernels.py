"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernel bodies execute with jnp semantics on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,Sq,Skv,H,G,D,bq,bk,causal,win,dtype", [
    (2, 64, 64, 4, 2, 32, 16, 16, True, None, jnp.float32),
    (1, 100, 100, 4, 4, 64, 32, 32, True, None, jnp.float32),
    (2, 64, 64, 8, 2, 32, 16, 16, True, 24, jnp.float32),
    (1, 48, 48, 2, 1, 16, 16, 16, False, None, jnp.float32),
    (2, 40, 40, 4, 2, 32, 16, 8, True, 16, jnp.float32),
    (1, 64, 64, 4, 2, 64, 16, 16, True, None, jnp.bfloat16),
])
def test_flash_attention_sweep(B, Sq, Skv, H, G, D, bq, bk, causal, win,
                               dtype):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k0, (B, Sq, H, D), dtype)
    k = jax.random.normal(k1, (B, Skv, G, D), dtype)
    v = jax.random.normal(k2, (B, Skv, G, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              block_q=bq, block_k=bk)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 64, 4, 16, 2, 32, 8),
    (1, 48, 2, 8, 1, 16, 16),
    (2, 64, 4, 16, 2, 32, 64),
    (1, 33, 3, 8, 3, 16, 8),     # uneven seq / groups
])
def test_ssd_sweep(B, S, H, P, G, N, chunk):
    keys = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(keys[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(keys[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(keys[2], (H,)) * 0.3)
    Bm = jax.random.normal(keys[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(keys[4], (B, S, G, N)) * 0.3
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    expected = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-3)


@pytest.mark.parametrize("shape,factor,block", [
    ((2, 96, 128, 3), 2, 16),
    ((1, 64, 64, 8), 4, 8),
    ((3, 32, 48, 1), 2, 32),
])
def test_downsample_sweep(shape, factor, block):
    f = jax.random.normal(jax.random.PRNGKey(2), shape)
    out = ops.downsample(f, factor=factor, block=block)
    expected = ref.downsample_ref(f, factor)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-5)


def test_tile_frames_roundtrip_counts():
    f = jnp.arange(2 * 8 * 8 * 3, dtype=jnp.float32).reshape(2, 8, 8, 3)
    t = ops.tile_frames(f, 4)
    assert t.shape == (8, 4, 4, 3)
    np.testing.assert_allclose(float(t.sum()), float(f.sum()))


def test_chunked_ssd_matches_models_path():
    """kernels.ssd (Pallas) vs models.ssd.ssd_scan (jnp chunked) —
    two independent implementations of the same math."""
    from repro.models.ssd import ssd_scan as jnp_ssd
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, G, N = 2, 64, 4, 16, 2, 32
    x = jax.random.normal(keys[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(keys[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(keys[2], (H,)) * 0.3)
    Bm = jax.random.normal(keys[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(keys[4], (B, S, G, N)) * 0.3
    out_pallas = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    out_jnp, _ = jnp_ssd(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(out_pallas), np.asarray(out_jnp),
                               atol=2e-3)

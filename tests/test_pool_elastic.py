"""Elastic serving pool: admit/retire lifecycle vs a per-stream oracle,
priority shedding, the zero-warm-recompile-within-a-bucket contract,
admission control, and live shard rebalancing.

The property test drives RANDOM interleavings of admit / retire / tick
(with random priorities and arrival multipliers) through the pool and
checks every stream's decision trajectory bit-exactly against running
that stream ALONE through the single-stream switcher — the elastic
slot machinery (masks, slot reuse, capacity growth) must be invisible
to the decisions. Runs through real ``hypothesis`` when installed,
else the bundled deterministic fallback (tests/_hypothesis_fallback.py).

The rebalance tests pin the 1-shard == N-shard property contract across
a repartition: row sets bit-identical, ownership law restored, standing
registrations replayed handle-stably. On the forced-8-device CI leg the
rebalance kernels run as real shard_map collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.api import AdmissionError, Skyscraper, SkyscraperPool
from repro.core.switcher import (compile_cache_sizes, init_state,
                                 switch_step)
from repro.runtime.elastic import rebalance
from repro.warehouse import (Filter, GroupBy, SegmentStore, ShardedStore,
                             StandingQueries)


@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches():
    # the bucket-growth tests compile the pool executables at several
    # capacities; start and end with empty caches so this module's
    # compile load doesn't stack on the rest of the suite
    jax.clear_caches()
    yield
    jax.clear_caches()


def _quality_of(knobs):
    return min(0.5 + 0.1 * knobs["q"], 1.0)


def _proc(seg, knobs):
    return ("out", _quality_of(knobs))


_SKY_CACHE = []


def _fitted_sky():
    if not _SKY_CACHE:
        rng = np.random.default_rng(0)
        s = Skyscraper(fps=2, segment_seconds=1.0, n_categories=2, seed=0)
        s.set_resources(num_cores=4, buffer_gb=1.0,
                        cloud_budget_core_s=0.0)
        s.register_knob("q", [1, 2, 3])
        s.fit([rng.random((3,)) for _ in range(12)], _proc)
        _SKY_CACHE.append(s)
    return _SKY_CACHE[0]


@pytest.fixture(scope="module")
def sky():
    return _fitted_sky()


# ---------------------------------------------------------------------------
# property: random admit/retire/priority interleavings vs per-stream oracle
# ---------------------------------------------------------------------------

@st.composite
def _schedules(draw):
    """A short op schedule over stream ids: each entry is
    ('admit', prio) / ('retire',) / ('tick', [arrival mults seed])."""
    ops = []
    n_ops = draw(st.integers(min_value=4, max_value=10))
    for _ in range(n_ops):
        kind = draw(st.sampled_from(["admit", "admit", "tick", "tick",
                                     "tick", "retire"]))
        if kind == "admit":
            ops.append(("admit", draw(st.floats(min_value=0.5,
                                                max_value=4.0))))
        elif kind == "retire":
            ops.append(("retire", draw(st.integers(min_value=0,
                                                   max_value=100))))
        else:
            ops.append(("tick", draw(st.integers(min_value=0,
                                                 max_value=10_000))))
    return ops


@settings(max_examples=10, deadline=None)
@given(_schedules())
def test_admit_retire_interleavings_match_per_stream_oracle(ops):
    sky = _fitted_sky()
    pool = SkyscraperPool(sky, n_streams=1, slot_chunk=2)
    plan_every0 = sky._plan_every
    sky._plan_every = 10_000               # plans pinned: oracle uses alpha0
    try:
        _run_oracle_case(sky, pool, ops)
    finally:
        sky._plan_every = plan_every0


def _run_oracle_case(sky, pool, ops):
    alpha0 = jnp.asarray(sky.alpha)
    # oracle: per-stream single-stream switcher state + pending quality
    ostate = {0: init_state(sky.tables)}
    opending = {0: None}
    next_sid = 1
    seg = np.zeros(3)
    for op in ops:
        if op[0] == "admit":
            pool.admit(next_sid, priority=op[1])
            ostate[next_sid] = init_state(sky.tables)
            opending[next_sid] = None
            next_sid += 1
        elif op[0] == "retire":
            if pool.V > 1:                 # keep at least one stream live
                sid = pool.streams[op[1] % pool.V]
                pool.retire(sid)
                del ostate[sid], opending[sid]
        else:
            rng = np.random.default_rng(op[1])
            mults = {s: 0.5 + rng.random() for s in pool.streams}
            statuses, _ = pool.process({s: seg for s in pool.streams},
                                       arrival_mults=mults)
            for stat in statuses:
                sid = stat["stream_id"]
                stt = dict(ostate[sid])
                if opending[sid] is not None:
                    stt["qual_prev"] = jnp.float32(opending[sid])
                stt, outs = switch_step(stt, jnp.zeros(len(sky.configs)),
                                        jnp.float32(mults[sid]), alpha0,
                                        sky.tables)
                ostate[sid] = stt
                assert stat["k"] == int(outs["k"]), (sid, stat)
                assert stat["category"] == int(outs["c"]), (sid, stat)
                np.testing.assert_array_equal(
                    np.float32(stat["buffer_s"]),
                    np.asarray(outs["buffer_s"], np.float32),
                    err_msg=f"stream {sid}")
                assert stat["dropped"] == bool(outs["dropped"])
                assert not stat["shed"]    # no capacity/watermark set
                opending[sid] = (None if stat["dropped"]
                                 else _quality_of(stat["config"]))


# ---------------------------------------------------------------------------
# priority shedding + alerts
# ---------------------------------------------------------------------------

def test_shed_order_respects_priority(sky):
    prios = [4.0, 3.0, 2.0, 1.0]
    pool = SkyscraperPool(sky, n_streams=4, priorities=prios,
                          telemetry=True)
    seg = np.zeros(3)
    # one unconstrained tick to measure per-stream planned demand (all
    # four streams see identical content, so all demands are equal)
    pool.process([seg] * pool.V)
    demand = float(pool.telemetry().counters["onprem_core_s"][0])
    assert demand > 0
    # capacity_core_s is a traced operand: set it between 2 and 3
    # stream-demands without touching any compiled program
    pool.capacity_core_s = demand * 2.5
    n_ticks = 6
    shed_count = np.zeros(4)
    for tick in range(n_ticks):
        statuses, results = pool.process([seg] * pool.V)
        shed = [s["shed"] for s in statuses]
        # the kept set is always a PREFIX of the priority order: a shed
        # stream never outranks a kept one
        for i in range(1, 4):
            assert not (shed[i - 1] and not shed[i]), (tick, shed)
        if tick == 0:
            # first constrained tick: identical demands, room for two
            assert shed == [False, False, True, True], shed
        for i, s in enumerate(shed):
            if s:
                assert results[i] is None
        shed_count += shed
    assert shed_count[0] == 0              # highest priority never shed
    assert shed_count[3] == n_ticks        # lowest priority always shed
    stats = pool.shed_stats()
    for sid, prio in enumerate(prios):
        assert stats[sid]["priority"] == prio
        assert stats[sid]["segments"] == n_ticks + 1
    # the flight recorder carries the shed fraction per stream
    tel = pool.telemetry()
    np.testing.assert_array_equal(tel.counters["seg_dropped"],
                                  shed_count)


def test_shed_surfaces_as_standing_alerts(sky):
    sink = SegmentStore(out_dim=len(sky.configs), chunk_rows=32)
    reg = StandingQueries(sink)
    # a shed stream's row lands with quality 0: alert on any stream
    # whose minimum recorded quality hits the floor
    reg.subscribe(
        [GroupBy("stream_id", "quality", agg="min", num_groups=8)],
        Filter("quality", "le", 0.0), name="shed-watch")
    pool = SkyscraperPool(sky, n_streams=3, priorities=[3.0, 2.0, 1.0],
                          sink=sink, telemetry=True)
    pool.process([np.zeros(3)] * pool.V)   # unconstrained: measure demand
    demand = float(pool.telemetry().counters["onprem_core_s"][0])
    pool.capacity_core_s = demand * 1.5    # room for one stream
    for _ in range(3):
        pool.process([np.zeros(3)] * pool.V)
    assert len(pool.alerts) == 1 and pool.alerts[0].name == "shed-watch"
    fired = pool.alerts[0].fired
    assert not fired[0]                    # highest priority never shed
    assert fired[2]                        # lowest priority shed -> alert


def test_admission_control_refuses_infeasible(sky):
    cost_min = float(np.min(np.asarray(sky.tables.cost)))
    pool = SkyscraperPool(sky, n_streams=2,
                          capacity_core_s=cost_min * 3.5)
    pool.admit(77)                         # 3 streams fit at min cost
    with pytest.raises(AdmissionError):
        pool.admit(79)                     # a 4th cannot, even degraded
    assert 79 not in pool.streams
    pool.admit(79, force=True)             # explicit override admits
    assert 79 in pool.streams
    pool.retire(79)
    pool.retire(77)
    pool.admit(78)                         # back under the bar: admitted
    with pytest.raises(ValueError):
        pool.admit(78)                     # duplicate id refused


def test_joint_plan_weights_priorities(sky):
    pool = SkyscraperPool(sky, n_streams=3, priorities=[3.0, 2.0, 1.0],
                          joint_plan=True)
    for _ in range(2 * sky._plan_every):
        pool.process([np.zeros(3)] * pool.V)
    alpha = np.asarray(pool._alpha)
    active = np.asarray(pool._active)
    # every ACTIVE stream's plan stays a per-category simplex
    np.testing.assert_allclose(alpha[active].sum(-1), 1.0, atol=1e-5)
    assert np.isfinite(alpha).all()


# ---------------------------------------------------------------------------
# zero warm recompiles within a capacity bucket, across >= 3 buckets
# ---------------------------------------------------------------------------

def test_zero_warm_recompiles_within_bucket_across_three_buckets(sky):
    rng = np.random.default_rng(1)
    pool = SkyscraperPool(sky, n_streams=2, telemetry=True)
    sid = [1000]

    def admit_n(n):
        for _ in range(n):
            sid[0] += 1
            pool.admit(sid[0], priority=float(sid[0] % 5))

    def warm_bucket():
        # touch every executable once at this capacity: admit, retire,
        # tick, and a replan window
        admit_n(1)
        pool.retire(sid[0])
        for _ in range(2 * sky._plan_every):
            pool.process({s: rng.random(3) for s in pool.streams})

    seen_buckets = []
    for target_extra in (3, 7, 14):        # drives cap through 8, 16, 32
        warm_bucket()
        cap0 = pool.cap
        warm = compile_cache_sizes()
        # churn admits/retires/ticks INSIDE the bucket
        admit_n(target_extra)
        pool.retire(pool.streams[0])
        for _ in range(2 * sky._plan_every):
            pool.process({s: rng.random(3) for s in pool.streams})
        after = compile_cache_sizes()
        grew = {k: (warm.get(k, 0), v) for k, v in after.items()
                if v != warm.get(k, 0)}
        # churn that crossed into a NEW bucket is allowed its one
        # compile per executable; within the bucket, zero growth
        if pool.cap == cap0:
            assert not grew, (cap0, grew)
        seen_buckets.append(pool.cap)
    assert len(set(seen_buckets)) >= 2 and pool.cap >= 32
    # and the largest bucket itself holds the contract after warmup
    warm_bucket()
    warm = compile_cache_sizes()
    admit_n(2)
    pool.retire(pool.streams[-1])
    for _ in range(2 * sky._plan_every):
        pool.process({s: rng.random(3) for s in pool.streams})
    grew = {k: (warm.get(k, 0), v)
            for k, v in compile_cache_sizes().items()
            if v != warm.get(k, 0)}
    assert not grew, grew


# ---------------------------------------------------------------------------
# live shard rebalancing
# ---------------------------------------------------------------------------

def _random_rows(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "stream_id": rng.integers(0, 11, n).astype(np.int32),
        "t": np.sort(rng.integers(0, 50, n)).astype(np.int32),
        "category": rng.integers(0, 4, n).astype(np.int32),
        "k": rng.integers(0, 4, n).astype(np.int32),
        "quality": rng.random(n).astype(np.float32),
        "on_core_s": rng.random(n).astype(np.float32),
        "cloud_core_s": rng.random(n).astype(np.float32),
        "buffer_s": rng.random(n).astype(np.float32),
        "out": rng.random((n, 3)).astype(np.float32),
    }


def _sorted_rows(hr):
    order = np.lexsort((np.asarray(hr["t"]), np.asarray(hr["quality"]),
                        np.asarray(hr["stream_id"])))
    return {k: np.asarray(v)[order] for k, v in hr.items()}


@pytest.mark.parametrize("s_old,s_new", [(2, 4), (2, 8), (4, 2), (3, 1)])
def test_rebalance_rows_bit_identical(s_old, s_new):
    store = ShardedStore(out_dim=3, n_shards=s_old, chunk_rows=8)
    store.append_rows(
        {k: jnp.asarray(v) for k, v in _random_rows(57).items()})
    new = rebalance(store, s_new)
    assert new.n_rows == store.n_rows
    a, b = _sorted_rows(store.host_rows()), _sorted_rows(new.host_rows())
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    # ownership law restored under the new shard count
    ids = np.asarray(new.columns["stream_id"])
    for s in range(s_new):
        nn = int(new.n_rows_by_shard[s])
        assert (ids[s, :nn] % s_new == s).all()
    # the source store is untouched
    assert store.n_shards == s_old and len(store) == 57


def test_rebalance_preserves_queries_and_standing():
    store = ShardedStore(out_dim=3, n_shards=2, chunk_rows=8)
    reg = StandingQueries(store)
    h = reg.register(
        [GroupBy("category", "quality", agg="sum", num_groups=4)])
    reg.subscribe([GroupBy("k", "quality", agg="sum", num_groups=4)],
                  Filter("quality", "gt", 0.5), name="hot-k")
    store.append_rows(
        {k: jnp.asarray(v) for k, v in _random_rows(43, seed=3).items()})
    t0, m0 = reg.answer(h)
    new = rebalance(store, 4)
    # standing registry replays handle-stably on the new store
    t1, m1 = new.standing.answer(h)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(t0["count"]),
                                  np.asarray(t1["count"]))
    np.testing.assert_allclose(np.asarray(t0["quality"]),
                               np.asarray(t1["quality"]),
                               rtol=1e-5, atol=1e-5)
    alerts = new.standing.poll()
    assert [a.name for a in alerts] == ["hot-k"]
    # ad-hoc queries obey the 1-shard == N-shard contract across the move
    plan = [Filter("quality", "gt", 0.3),
            GroupBy("category", "quality", agg="mean", num_groups=4)]
    tbl_old, mask_old = store.query(plan)
    tbl_new, mask_new = new.query(plan)
    np.testing.assert_array_equal(np.asarray(mask_old),
                                  np.asarray(mask_new))
    np.testing.assert_array_equal(np.asarray(tbl_old["count"]),
                                  np.asarray(tbl_new["count"]))
    np.testing.assert_allclose(np.asarray(tbl_old["quality"]),
                               np.asarray(tbl_new["quality"]),
                               rtol=1e-5, atol=1e-5)


def test_rebalance_roundtrip_through_one_shard():
    store = ShardedStore(out_dim=3, n_shards=4, chunk_rows=8)
    store.append_rows(
        {k: jnp.asarray(v) for k, v in _random_rows(29, seed=5).items()})
    down = rebalance(store, 1)
    back = rebalance(down, 4)
    a, b = _sorted_rows(store.host_rows()), _sorted_rows(back.host_rows())
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    # identical partitioning law => identical per-shard counts
    np.testing.assert_array_equal(store.n_rows_by_shard,
                                  back.n_rows_by_shard)


def test_pool_sink_rebalance_end_to_end(sky):
    """admit -> tick -> retire -> rebalance: rows carry REAL stream ids
    so the repartition groups each stream's history onto its new
    owner."""
    sink = ShardedStore(out_dim=len(sky.configs), n_shards=2,
                        chunk_rows=32)
    pool = SkyscraperPool(sky, n_streams=2, sink=sink)
    pool.admit(9)
    for _ in range(4):
        pool.process([np.zeros(3)] * pool.V)
    pool.retire(1)
    for _ in range(2):
        pool.process([np.zeros(3)] * pool.V)
    assert len(sink) == 3 * 4 + 2 * 2
    new = rebalance(sink, 4)
    hr = new.host_rows()
    assert set(np.asarray(hr["stream_id"]).tolist()) == {0, 1, 9}
    a, b = _sorted_rows(sink.host_rows()), _sorted_rows(hr)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)

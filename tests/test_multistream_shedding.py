"""Multi-stream joint planning (App. D) + overload shedding semantics +
MoE group-size equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import plan_value, solve_multi_stream
from repro.core.switcher import init_state, run_window
from test_switcher import make_tables


def test_multi_stream_budget_shared_fairly():
    """The joint plan spends the shared budget where it buys the most
    quality: the harder stream gets the expensive configs (App. D)."""
    K = 3
    cost = np.array([1.0, 4.0, 10.0], np.float32)
    easy = np.array([[0.9, 0.95, 1.0]], np.float32)    # 1 category
    hard = np.array([[0.2, 0.6, 1.0]], np.float32)
    rs = [np.ones(1, np.float32), np.ones(1, np.float32)]
    budget = 8.0   # enough for one expensive + one cheap
    a_easy, a_hard = solve_multi_stream([easy, hard], cost, rs, budget)
    spend_easy = float((a_easy * cost).sum())
    spend_hard = float((a_hard * cost).sum())
    assert spend_hard > spend_easy
    assert spend_easy + spend_hard <= budget + 1e-3
    # vs. naive per-stream split (budget/2 each): joint must be >= equal
    from repro.core.planner import solve_lp_lagrangian
    ae = solve_lp_lagrangian(jnp.asarray(easy), jnp.asarray(cost),
                             jnp.ones((1,)), budget / 2)
    ah = solve_lp_lagrangian(jnp.asarray(hard), jnp.asarray(cost),
                             jnp.ones((1,)), budget / 2)
    q_joint = float((a_easy * easy).sum() + (a_hard * hard).sum())
    q_split = float((np.asarray(ae) * easy).sum()
                    + (np.asarray(ah) * hard).sum())
    assert q_joint >= q_split - 1e-4


def test_shedding_under_overload():
    """Arrival spike beyond peak provisioning: segments are dropped
    (quality 0) and the buffer STILL never overflows (Eq. 1)."""
    tables = make_tables(cap=5.0, cloud=0.0)
    C, K = tables.n_categories, tables.n_configs
    alpha = jnp.ones((C, K)) / K
    T = 200
    rng = np.random.default_rng(0)
    quals = jnp.asarray(rng.random((T, K)), jnp.float32)
    arrivals = jnp.full((T,), 50.0, jnp.float32)   # extreme overload
    state = init_state(tables)
    state, outs = run_window(state, quals, arrivals, alpha, tables)
    assert bool(np.asarray(outs["dropped"]).any())
    assert float(np.asarray(outs["buffer_s"]).max()) <= 5.0 + 1e-4
    # dropped segments contribute zero quality
    d = np.asarray(outs["dropped"])
    assert np.allclose(np.asarray(outs["qual"])[d], 0.0)


def test_multi_stream_ingestion_end_to_end():
    """App. D scenario 1: two streams, joint plan, shared cloud budget."""
    from repro.configs.workloads import COVID
    from repro.core import ingest as IG
    from repro.core.offline import fit
    from repro.data.stream import generate
    f = fit(COVID, n_cores=8, days_unlabeled=3.0, n_categories=3, seed=0)
    s1 = generate(COVID, days=0.2, seed=5)
    s2 = generate(COVID, days=0.2, seed=17)
    res = IG.run_skyscraper_multi([f, f], [s1, s2], n_cores_each=8,
                                  cloud_budget_core_s=2000.0)
    assert res["quality_pct"] > 80.0
    assert len(res["per_stream_pct"]) == 2


def test_fp8_kv_cache_decode():
    """fp8 KV cache: structurally sound decode with halved cache bytes."""
    import dataclasses
    from repro.configs.base import registry
    from repro.models.model import Model
    from repro.models.options import RunOptions
    opts = RunOptions(remat="none", layer_loop="unroll",
                      compute_dtype="float32", q_chunk=16, kv_chunk=16,
                      kv_cache_dtype="float8_e4m3fn")
    rc = registry()["llama3-8b"].reduced()
    m = Model(rc, opts)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    toks = jax.random.randint(key, (2, 12), 0, rc.vocab)
    nxt, cache = m.prefill(params, {"tokens": toks}, cache_len=20)
    assert str(cache["layers"]["k"].dtype) == "float8_e4m3fn"
    for _ in range(2):
        nxt, cache = m.decode_step(params, cache, nxt)
    assert bool((nxt >= 0).all())
    meta = m.cache_meta(2, 12)
    assert meta["layers"]["k"].dtype == "float8_e4m3fn"


def test_moe_group_size_preserves_results_without_drops():
    """With generous capacity, grouped dispatch == ungrouped dispatch."""
    from repro.models.moe import moe_ffn
    key = jax.random.PRNGKey(0)
    B, S, d, E, f = 2, 32, 16, 4, 32
    x = jax.random.normal(key, (B, S, d))
    p = {"router": jax.random.normal(key, (d, E)) * 0.1,
         "w_gate": jax.random.normal(key, (E, d, f)) / np.sqrt(d),
         "w_up": jax.random.normal(key, (E, d, f)) / np.sqrt(d),
         "w_down": jax.random.normal(key, (E, f, d)) / np.sqrt(f)}
    y0, _ = moe_ffn(p, x, n_experts=E, top_k=2, capacity_factor=8.0,
                    group_size=0)
    y1, _ = moe_ffn(p, x, n_experts=E, top_k=2, capacity_factor=8.0,
                    group_size=8)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)

"""Standing queries: incremental partial maintenance fused into the
ingest dispatch. Registration + backfill, same-shape query batching
into power-of-two buckets, alert subscriptions, spill invariance, the
Pallas delta path, and the zero-warm-recompile pins (standing folds AND
the bucketed capacity ladder).

``scripts/tier1.sh`` re-runs this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the sharded
legs execute on a real mesh."""

import numpy as np
import pytest

from repro.core.switcher import compile_cache_sizes
from repro.warehouse import (Filter, GroupBy, MultiGroupBy, SegmentStore,
                             ShardedStore, ShardedTieredStore,
                             StandingQueries, TieredStore, TopK,
                             WindowAgg, execute_ref)
from repro.warehouse.store import _bucket_cap
from test_warehouse import _host_cols, _random_rows

D = 3


def _eq(a, b, **kw):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), **kw)


def _close(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **kw)


def _ref(store, plan):
    return execute_ref(_host_cols(store), store.n_rows, plan)


# ---------------------------------------------------------------------------
# single store: registration, backfill, incremental answers
# ---------------------------------------------------------------------------

def test_register_then_ingest_matches_rescan_bit_exact():
    """Backfill over existing rows + incremental folds over later
    appends equals a full rescan BIT-exactly (fp32 sums included): the
    fold continues each group's addition sequence in ingest order."""
    store = SegmentStore(out_dim=D, chunk_rows=256)
    store.append_rows(_random_rows(500, D, seed=1))
    reg = StandingQueries(store)
    plans = [
        (Filter("quality", "ge", 0.25),
         GroupBy("category", "quality", agg="sum", num_groups=4)),
        (GroupBy("category", "quality", agg="max", num_groups=4),
         TopK(2, by="quality")),
        (WindowAgg(window=128, value="on_core_s", agg="mean",
                   num_windows=8),),
        (MultiGroupBy(keys=("k", "category"), value="quality", agg="sum",
                      nums=(D, 4), windows=(0, 0)),),
    ]
    handles = [reg.register(p) for p in plans]
    store.append_rows(_random_rows(300, D, seed=2, t0=500))
    store.append_rows(_random_rows(200, D, seed=3, t0=800))
    for h, plan in zip(handles, plans):
        table, mask = reg.answer(h)
        ref, rmask = _ref(store, plan)
        _eq(mask, rmask)
        for k in ref:
            _eq(table[k], ref[k], err_msg=f"{plan}:{k}")


def test_registration_after_ingest_and_empty_store_seed():
    """Registering on an EMPTY store skips the backfill (init state is
    the seed) and folds catch every later row; registering mid-stream
    backfills exactly the rows already present."""
    store = SegmentStore(out_dim=D, chunk_rows=128)
    reg = StandingQueries(store)
    plan = (Filter("quality", "lt", 0.5),
            GroupBy("category", "quality", agg="mean", num_groups=4))
    h_empty = reg.register(plan)
    store.append_rows(_random_rows(200, D, seed=4))
    h_mid = reg.register(plan)            # same shape: joins the group
    store.append_rows(_random_rows(150, D, seed=5, t0=200))
    ref, rmask = _ref(store, plan)
    for h in (h_empty, h_mid):
        table, mask = reg.answer(h)
        _eq(mask, rmask)
        _eq(table["quality"], ref["quality"])
        _eq(table["count"], ref["count"])
    assert len(reg._groups) == 1          # one vmapped group, two slots


def test_same_shape_thresholds_batch_one_group_zero_warm_recompiles():
    """Queries of one plan SHAPE share a single vmapped fold: operands
    stack, state buckets to powers of two, and once a bucket is warm,
    further ingests and registrations inside it add ZERO executables."""
    store = SegmentStore(out_dim=D, chunk_rows=2048)   # capacity fixed:
    store.append_rows(_random_rows(256, D, seed=6))    # growth recompiles
    reg = StandingQueries(store)                       # tested elsewhere

    def plan(thr):
        return (Filter("quality", "ge", thr),
                GroupBy("category", "quality", agg="sum", num_groups=4))

    handles = {thr: reg.register(plan(thr)) for thr in (0.2, 0.5)}
    store.append_rows(_random_rows(256, D, seed=7, t0=256))
    reg.answer(handles[0.2])
    warm = sum(compile_cache_sizes().values())
    # same batch shape again: the fold is warm
    store.append_rows(_random_rows(256, D, seed=8, t0=512))
    # two more registrations land inside the qb=4 bucket
    handles[0.8] = reg.register(plan(0.8))
    handles[0.05] = reg.register(plan(0.05))
    store.append_rows(_random_rows(256, D, seed=9, t0=768))
    for thr, h in handles.items():
        table, mask = reg.answer(h)
        ref, rmask = _ref(store, plan(thr))
        _eq(mask, rmask)
        _eq(table["quality"], ref["quality"])
    g = next(iter(reg._groups.values()))
    assert g.q == 4 and g.qb == 4         # power-of-two bucket
    grew = sum(compile_cache_sizes().values()) - warm
    # bucket 1->2->4 growth re-traces the fold + answer once per
    # crossing; the second registration in the bucket and every warm
    # ingest/answer add nothing
    assert grew <= 4, f"{grew} new executables after warm point"
    before = sum(compile_cache_sizes().values())
    store.append_rows(_random_rows(256, D, seed=10, t0=1024))
    reg.answer(handles[0.8])
    assert sum(compile_cache_sizes().values()) == before, \
        "warm standing refresh recompiled"


def test_answer_is_rescan_free():
    """``answer`` never touches the stored rows: growing the store by
    10x between answers does not change the answer executable, and the
    un-refreshed answer still reflects only folded rows."""
    store = SegmentStore(out_dim=D, chunk_rows=64)
    store.append_rows(_random_rows(64, D, seed=11))
    reg = StandingQueries(store)
    h = reg.register((GroupBy("category", "quality", agg="sum",
                              num_groups=4),))
    t1, _ = reg.answer(h)
    ref1, _ = _ref(store, (GroupBy("category", "quality", agg="sum",
                                   num_groups=4),))
    _eq(t1["quality"], ref1["quality"])
    g = reg._group_of(reg._queries[h])
    frozen = {k: np.asarray(v) for k, v in g.state.items()}
    store.append_rows(_random_rows(640, D, seed=12, t0=64))
    t2, _ = reg.answer(h)
    ref2, _ = _ref(store, (GroupBy("category", "quality", agg="sum",
                                   num_groups=4),))
    _eq(t2["quality"], ref2["quality"])   # folds kept it current
    # and the state really is the only input: restoring it restores t1
    import jax.numpy as jnp
    g.state = {k: jnp.asarray(v) for k, v in frozen.items()}
    t3, _ = reg.answer(h)
    _eq(t3["quality"], t1["quality"])


# ---------------------------------------------------------------------------
# alerts
# ---------------------------------------------------------------------------

def test_subscription_fires_fixed_shape_and_counts():
    store = SegmentStore(out_dim=D, chunk_rows=128)
    reg = StandingQueries(store)
    plan = (GroupBy("category", "quality", agg="count", num_groups=4),)
    sid = reg.subscribe(plan, Filter("count", "ge", 120),
                        name="hot-category")
    assert reg.has_subscriptions
    store.append_rows(_random_rows(100, D, seed=13))
    quiet = reg.poll()
    assert len(quiet) == 1 and quiet[0].fired.shape == (4,)
    assert quiet[0].n_fired == 0 and quiet[0].sub == sid
    rows = _random_rows(400, D, seed=14, t0=100)
    rows["category"][:] = 2               # slam one group
    store.append_rows(rows)
    (alert,) = reg.poll()
    assert alert.fired.shape == (4,)      # fixed shape every tick
    assert alert.n_fired == 1 and bool(alert.fired[2])
    assert alert.table["count"][2] >= 120
    tel = store.telemetry()
    assert tel.alerts_checked == 2 and tel.alerts_fired == 1
    assert tel.standing_queries == 1 and tel.standing_refreshes == 2
    assert "alerts=1/2" in tel.summary()


def test_alert_on_float_column_and_predicate_validation():
    store = SegmentStore(out_dim=D, chunk_rows=128)
    reg = StandingQueries(store)
    plan = (WindowAgg(window=64, value="on_core_s", agg="sum",
                      num_windows=4),)
    reg.subscribe(plan, Filter("on_core_s", "gt", 100.0))
    with pytest.raises(AssertionError):
        reg.subscribe(plan, predicate=TopK(3, by="on_core_s"))
    store.append_rows(_random_rows(256, D, seed=15))
    (alert,) = reg.poll()
    ref, rmask = _ref(store, plan)
    want = rmask & (ref["on_core_s"] > 100.0)
    _eq(alert.fired, want)


# ---------------------------------------------------------------------------
# validation / attachment
# ---------------------------------------------------------------------------

def test_register_rejects_non_aggregating_and_unknown_columns():
    store = SegmentStore(out_dim=D, chunk_rows=64)
    reg = StandingQueries(store)
    with pytest.raises(ValueError, match="aggregating reducer"):
        reg.register((Filter("quality", "ge", 0.5), TopK(3, "quality")))
    with pytest.raises(ValueError, match="unknown column"):
        reg.register((Filter("nope", "ge", 0.5),
                      GroupBy("category", "quality", agg="sum",
                              num_groups=4)))
    with pytest.raises(ValueError, match="unknown columns"):
        reg.register((GroupBy("category", "latency", agg="mean",
                              num_groups=4),))
    with pytest.raises(AssertionError, match="already has"):
        StandingQueries(store)            # one registry per store
    assert len(reg) == 0


# ---------------------------------------------------------------------------
# tiering: spills never change a standing answer
# ---------------------------------------------------------------------------

def test_spill_invariance_single():
    """Every row's exact fp32 contribution is folded at INGEST, so
    demoting rows to the int8 cold tier afterwards cannot move a
    standing answer — while a rescan of the same store drifts."""
    store = SegmentStore(out_dim=D, chunk_rows=256)
    ts = TieredStore(store, seed=2)
    reg = StandingQueries(ts)
    assert ts.standing is reg             # tiered wrapper forwards
    plan = (Filter("quality", "ge", 0.1),
            GroupBy("category", "quality", agg="sum", num_groups=4))
    h = reg.register(plan)
    store.append_rows(_random_rows(2048, D, seed=16))
    before_t, before_m = reg.answer(h)
    before = {k: np.asarray(v) for k, v in before_t.items()}
    spilled = ts.spill(keep_hot=512)
    assert spilled > 0
    after_t, after_m = reg.answer(h)
    _eq(after_m, before_m)
    for k in before:
        _eq(after_t[k], before[k], err_msg=k)
    # the rescan over the two-tier view is only tolerance-close
    rescan, _ = ts.query(plan)
    _close(rescan["quality"], before["quality"],
           atol=ts.max_cold_scale() * 2048 + 1e-6)
    # and folds after the spill stay exact vs pre-quantization history
    store.append_rows(_random_rows(256, D, seed=17, t0=2048))
    ref_rows = _random_rows(2048, D, seed=16)
    new_rows = _random_rows(256, D, seed=17, t0=2048)
    full = {k: np.concatenate([ref_rows[k], new_rows[k]]) for k in ref_rows}
    ref, rmask = execute_ref(full, 2048 + 256, plan)
    got_t, got_m = reg.answer(h)
    _eq(got_m, rmask)
    _eq(got_t["quality"], ref["quality"])


def test_spill_invariance_sharded():
    hot = ShardedStore(out_dim=D, n_shards=2, chunk_rows=128)
    ts = ShardedTieredStore(hot, seed=3)
    reg = StandingQueries(ts)
    plan = (GroupBy("category", "quality", agg="max", num_groups=4),)
    h = reg.register(plan)
    hot.append_rows(_random_rows(1024, D, seed=18))
    before_t, before_m = reg.answer(h)
    before = np.asarray(before_t["quality"])
    assert ts.spill(keep_hot=256) > 0
    after_t, after_m = reg.answer(h)
    _eq(after_m, before_m)
    _eq(after_t["quality"], before)       # max: bit-exact across spill


# ---------------------------------------------------------------------------
# Pallas delta path
# ---------------------------------------------------------------------------

def test_pallas_delta_fold_matches_ref():
    """use_pallas=True folds via the fused zero-scatter delta kernel;
    max/count stay exact (the documented Pallas trade applies only to
    float sums)."""
    store = SegmentStore(out_dim=D, chunk_rows=256)
    store.append_rows(_random_rows(300, D, seed=19))
    reg = StandingQueries(store)
    plan = (Filter("k", "gt", 0.5),
            GroupBy("category", "quality", agg="max", num_groups=4))
    h = reg.register(plan, use_pallas=True)
    assert reg._group_of(reg._queries[h]).use_pallas
    store.append_rows(_random_rows(300, D, seed=20, t0=300))
    table, mask = reg.answer(h)
    ref, rmask = _ref(store, plan)
    _eq(mask, rmask)
    _eq(table["quality"], ref["quality"])
    _eq(table["count"], ref["count"])


def test_pallas_flag_ignored_on_sharded():
    store = ShardedStore(out_dim=D, n_shards=2, chunk_rows=128)
    reg = StandingQueries(store)
    h = reg.register((GroupBy("category", "quality", agg="max",
                              num_groups=4),), use_pallas=True)
    assert not reg._group_of(reg._queries[h]).use_pallas


# ---------------------------------------------------------------------------
# sharded stores
# ---------------------------------------------------------------------------

def test_sharded_standing_matches_rescan():
    """Sharded folds run inside the one shard_map ingest dispatch;
    answers match the rescan under the sharded-merge contract (counts /
    max exact, float sums tolerance-bounded)."""
    store = ShardedStore(out_dim=D, n_shards=2, chunk_rows=256)
    store.append_rows(_random_rows(400, D, seed=21))
    reg = StandingQueries(store)
    plans = [
        (Filter("quality", "ge", 0.3),
         GroupBy("category", "quality", agg="sum", num_groups=4)),
        (GroupBy("category", "quality", agg="max", num_groups=4),),
        (WindowAgg(window=128, value="on_core_s", agg="count",
                   num_windows=8),),
    ]
    handles = [reg.register(p) for p in plans]
    store.append_rows(_random_rows(300, D, seed=22, t0=400))
    store.append_rows(_random_rows(300, D, seed=23, t0=700))
    flat = store.host_rows()              # shard-major row order: fine
    for h, plan in zip(handles, plans):   # under the merge contract
        table, mask = reg.answer(h)
        ref, rmask = execute_ref(flat, store.n_rows, plan)
        _eq(mask, rmask, err_msg=str(plan))
        agg = plan[-1].agg
        val = plan[-1].value
        if agg in ("max", "count"):
            _eq(table[val], ref[val], err_msg=str(plan))
        else:
            _close(table[val], ref[val], rtol=2e-6, atol=1e-4)
        _eq(table["count"], ref["count"], err_msg=str(plan))


def test_sharded_one_shard_equals_single_store():
    """n_shards=1 standing answers equal the unsharded store's BIT-
    exactly — the per-shard fold is the single-store fold."""
    rows0 = _random_rows(200, D, seed=24)
    rows1 = _random_rows(150, D, seed=25, t0=200)
    plan = (Filter("quality", "lt", 0.7),
            GroupBy("category", "quality", agg="sum", num_groups=4))
    answers = []
    for store in (SegmentStore(out_dim=D, chunk_rows=128),
                  ShardedStore(out_dim=D, n_shards=1, chunk_rows=128)):
        store.append_rows(rows0)
        reg = StandingQueries(store)
        h = reg.register(plan)
        store.append_rows(rows1)
        answers.append(reg.answer(h))
    (t_single, m_single), (t_shard, m_shard) = answers
    _eq(m_single, m_shard)
    for k in t_single:
        _eq(t_single[k], t_shard[k], err_msg=k)


# ---------------------------------------------------------------------------
# capacity ladder: growth without warm recompiles
# ---------------------------------------------------------------------------

def test_bucket_cap_ladder():
    assert _bucket_cap(1, 64) == 64
    assert _bucket_cap(64, 64) == 64
    assert _bucket_cap(65, 64) == 128
    assert _bucket_cap(129, 64) == 256
    assert _bucket_cap(1000, 64) == 1024
    for need in range(1, 2000, 37):
        cap = _bucket_cap(need, 64)
        assert cap >= need and cap % 64 == 0
        assert (cap // 64) & (cap // 64 - 1) == 0    # power-of-two units


def test_capacity_growth_is_bucketed_zero_warm_recompiles():
    """Growing 0 -> ~5k rows touches only ladder capacities
    {chunk * 2^j} — O(log) compiles — and a SECOND store grown the same
    way reuses every executable."""
    def grow(chunk=64, batches=40, n=128, seed0=30):
        store = SegmentStore(out_dim=D, chunk_rows=chunk)
        caps = set()
        t0 = 0
        for i in range(batches):
            store.append_rows(_random_rows(n, D, seed=seed0 + i, t0=t0))
            t0 += n
            caps.add(store.capacity)
        return store, caps

    store, caps = grow()
    assert store.n_rows == 40 * 128
    assert all(c % 64 == 0 and ((c // 64) & (c // 64 - 1)) == 0
               for c in caps)
    assert len(caps) <= 8                 # ladder, not per-batch growth
    warm = sum(compile_cache_sizes().values())
    store2, caps2 = grow(seed0=70)
    assert caps2 == caps
    assert sum(compile_cache_sizes().values()) == warm, \
        "regrowth recompiled despite bucketed capacities"
    h1, h2 = store.host_rows(), store2.host_rows()
    assert h1["t"].shape == h2["t"].shape == (40 * 128,)


def test_sharded_capacity_growth_bucketed():
    def grow(seed0):
        store = ShardedStore(out_dim=D, n_shards=2, chunk_rows=64)
        caps = set()
        for i in range(12):
            store.append_rows(_random_rows(96, D, seed=seed0 + i,
                                           t0=96 * i))
            caps.add(store.capacity)
        return store, caps

    s1, caps = grow(100)
    assert all(c % 64 == 0 and ((c // 64) & (c // 64 - 1)) == 0
               for c in caps)
    warm = sum(compile_cache_sizes().values())
    s2, caps2 = grow(200)
    assert caps2 == caps and s2.n_rows == s1.n_rows == 12 * 96
    assert sum(compile_cache_sizes().values()) == warm, \
        "sharded regrowth recompiled"

"""Sharding layer + HLO analyzer: divisibility fallbacks, and the
trip-count-aware parser agreeing with cost_analysis on unrolled lowers
(where cost_analysis is exact) — run on a forced 8-device subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import (ParamMeta, shard, spec_for,
                                         use_mesh)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_shard_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard(x, "batch", "tensor")
    assert y is x


def test_spec_for_drops_nondivisible():
    prog = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.distribution.sharding import spec_for
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
# divisible -> sharded
s1 = spec_for((16, 8), ("fsdp", "tensor"), mesh)
assert s1 == P("data", "model"), s1
# vocab 92553 not divisible by 4 -> dropped
s2 = spec_for((92553, 16), ("vocab", "fsdp"), mesh)
assert s2 == P(None, "data"), s2
# heads 25 not divisible -> dropped
s3 = spec_for((4, 25, 64), (None, "tensor", None), mesh)
assert s3 == P(None, None, None), s3
print("SPEC_OK")
'''
    p = subprocess.run([sys.executable, "-c", prog],
                       env=dict(os.environ, PYTHONPATH=SRC),
                       capture_output=True, text=True, timeout=300)
    assert "SPEC_OK" in p.stdout, p.stdout + p.stderr


def test_hlo_parser_matches_cost_analysis_unrolled():
    """On an UNROLLED program cost_analysis is exact; the parser's
    dot-flops (x trip counts) must agree within a few % AND the scan
    version must parse to the same total."""
    prog = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
L, d, ff = 6, 128, 256
params = {"w1": jax.ShapeDtypeStruct((L, d, ff), jnp.float32),
          "w2": jax.ShapeDtypeStruct((L, ff, d), jnp.float32)}
ps = {"w1": NamedSharding(mesh, P(None, "data", "model")),
      "w2": NamedSharding(mesh, P(None, "model", "data"))}
x = jax.ShapeDtypeStruct((8, 32, d), jnp.float32)
xs = NamedSharding(mesh, P("data", None, None))

def run(unroll):
    def step(p, x):
        def body(h, w):
            h = h @ w["w1"]
            h = jax.nn.relu(h) @ w["w2"]
            return h, ()
        h, _ = jax.lax.scan(body, x, p, unroll=L if unroll else 1)
        return h.mean()
    co = jax.jit(step, in_shardings=(ps, xs)).lower(params, x).compile()
    flops_ca = HA.cost_analysis_dict(co).get("flops", 0.0)
    parsed = HA.analyze(co.as_text())
    return flops_ca, parsed["dot_flops"]

ca_u, p_u = run(True)
ca_s, p_s = run(False)
# unrolled: parser ~= cost_analysis (both exact)
assert abs(p_u - ca_u) / ca_u < 0.05, (p_u, ca_u)
# scan: cost_analysis undercounts by ~L; parser must match the unrolled
assert abs(p_s - p_u) / p_u < 0.05, (p_s, p_u)
assert ca_s < ca_u / 2
print("HLO_OK", ca_u, p_u, ca_s, p_s)
'''
    p = subprocess.run([sys.executable, "-c", prog],
                       env=dict(os.environ, PYTHONPATH=SRC),
                       capture_output=True, text=True, timeout=600)
    assert "HLO_OK" in p.stdout, p.stdout + p.stderr


def test_param_meta_tree_roundtrip():
    from repro.distribution.sharding import abstract_tree, init_tree
    meta = {"a": ParamMeta((4, 8), ("fsdp", "tensor")),
            "n": ParamMeta((8,), (None,), "ones")}
    tree = init_tree(meta, jax.random.PRNGKey(0))
    ab = abstract_tree(meta)
    assert tree["a"].shape == ab["a"].shape == (4, 8)
    np.testing.assert_allclose(np.asarray(tree["n"]), 1.0)

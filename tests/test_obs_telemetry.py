"""Flight-recorder telemetry: the on-device counters carried through
the fused scans must match the sequential numpy float32 mirror
(``repro.obs.telemetry_ref``) BIT-exactly, across forecast modes,
ragged window tails, multi-stream batching, and both store flavors —
plus the host-side pool and warehouse counters."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from benchmarks.fused_ingest_bench import _synthetic_fitted
from repro.analysis import examples as EX
from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.forecaster import init_forecaster
from repro.core.ingest import _fused_run, _fused_run_multi, _window_layout
from repro.core.switcher import init_state, init_state_multi, stack_tables
from repro.data.stream import generate
from repro.obs import TEL_KEYS, Telemetry, telemetry_ref
from repro.warehouse import SegmentStore, ShardedStore, TieredStore

TRACE_KEYS = ("k", "dropped", "buffer_s", "on_s", "cl_s")
N_SPLIT, INTERVAL = 2, 3


def _k0(tables) -> int:
    """Boot config of a switcher state: the most qualitative one."""
    return int(np.argmin(np.asarray(tables.rank_pos)))


def _run_single_tel(T, W, seed, mode):
    """A toy single-stream fused run with telemetry; returns the
    Telemetry plus the flattened per-segment traces."""
    rng = np.random.default_rng(seed)
    t = EX.demo_tables(seed=seed)
    n_w, pad, wts, fracs = _window_layout(T, W)
    K = t.cost.shape[0]
    quals = jnp.asarray(rng.random((T, K)), jnp.float32)
    quals_w = jnp.pad(quals, ((0, pad), (0, 0))).reshape(n_w, W, K)
    arrs_w = jnp.ones((n_w, W), jnp.float32)
    valid_w = (jnp.arange(n_w * W) < T).reshape(n_w, W)
    params = init_forecaster(jax.random.PRNGKey(seed), N_SPLIT,
                             t.centers.shape[0])
    _, outs, _, _, tels = _fused_run(
        init_state(t), jnp.zeros((N_SPLIT * INTERVAL,), jnp.int32),
        quals_w, arrs_w, valid_w, jnp.asarray(wts), jnp.asarray(fracs),
        t, t.centers, t.cost, params, jnp.float32(8.0),
        jnp.float32(50.0), mode=mode, n_split=N_SPLIT,
        interval=INTERVAL, telemetry=True)
    traces = {k: np.asarray(outs[k]).reshape(-1)[:T] for k in TRACE_KEYS}
    return Telemetry.from_device(tels), traces, _k0(t)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(2, 8), st.integers(0, 10_000),
       st.sampled_from(["oracle", "model", "uniform"]))
def test_single_stream_counters_bit_exact(T, W, seed, mode):
    """Property: device counters == sequential float32 replay of the
    run's own traces, for any run length / window size (including the
    ragged last window whose padding must be an exact no-op)."""
    tel, traces, k0 = _run_single_tel(T, W, seed, mode)
    ref = telemetry_ref(traces, k0)
    for key in TEL_KEYS:
        np.testing.assert_array_equal(
            np.asarray(tel.counters[key]), ref[key], err_msg=key)
    # window snapshots are cumulative: final row == counters
    n_w = _window_layout(T, W)[0]
    for key in TEL_KEYS:
        assert tel.per_window[key].shape[0] == n_w


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 24), st.integers(2, 6), st.integers(1, 3),
       st.integers(0, 10_000))
def test_multi_stream_counters_bit_exact(T, W, V, seed):
    """Property: per-stream (V,) counters of the batched engine match a
    per-stream float32 replay (each stream boots on its own k0)."""
    rng = np.random.default_rng(seed)
    ts = [EX.demo_tables(seed=seed + s) for s in range(V)]
    K = ts[0].cost.shape[0]
    n_w, pad, wts, _ = _window_layout(T, W)
    quals_w = jnp.asarray(rng.random((n_w, V, W, K)), jnp.float32)
    arrs_w = jnp.ones((n_w, V, W), jnp.float32)
    valid_w = jnp.broadcast_to(
        (jnp.arange(n_w * W) < T).reshape(n_w, 1, W), (n_w, V, W))
    _, (res, tels) = _fused_run_multi(
        init_state_multi(ts), quals_w, arrs_w, valid_w,
        jnp.asarray(wts), stack_tables(ts), ts[0].cost,
        jnp.float32(16.0), jnp.float32(0.5),
        with_traces=True, telemetry=True)
    tel = Telemetry.from_device(tels)
    traces = {k: np.asarray(res[k]).transpose(1, 0, 2).reshape(V, -1)[:, :T]
              for k in TRACE_KEYS}
    ref = telemetry_ref(traces, np.asarray([_k0(t) for t in ts]))
    for key in TEL_KEYS:
        assert np.asarray(tel.counters[key]).shape == (V,)
        np.testing.assert_array_equal(
            np.asarray(tel.counters[key]), ref[key], err_msg=key)


def test_run_skyscraper_fused_telemetry_end_to_end():
    """The public entry point: telemetry lands on the RunResult and
    replays bit-exactly from the rows the warehouse sink captured."""
    fitted = _synthetic_fitted()
    stream = generate(COVID, days=0.01, seed=5)
    T = stream.n_segments
    tau = fitted.workload.segment_seconds
    store = SegmentStore(out_dim=len(fitted.configs), chunk_rows=512)
    res = IG.run_skyscraper_fused(
        fitted, stream, n_cores=8, cloud_budget_core_s=5_000.0,
        plan_days=64.5 * tau / 86400, forecast_mode="model",
        sink=store, telemetry=True)
    tel = res.telemetry
    assert tel is not None and tel.segments == T
    assert tel.buffer_hwm_s == float(np.max(res.buffer_trace))
    # no drops in this generous-budget config -> the store rows carry
    # every input needed for the full-fidelity replay
    assert tel.dropped == 0.0
    h = store.host_rows()
    assert (h["t"] == np.arange(T)).all()
    k0 = int(np.argmax(fitted.power))       # argmin(rank_pos)
    ref = telemetry_ref(
        {"k": h["k"], "dropped": np.zeros(T, np.float32),
         "buffer_s": h["buffer_s"], "on_s": h["on_core_s"],
         "cl_s": h["cloud_core_s"]}, k0)
    for key in TEL_KEYS:
        np.testing.assert_array_equal(
            np.asarray(tel.counters[key]), ref[key], err_msg=key)
    # telemetry=False keeps the field empty
    res2 = IG.run_skyscraper_fused(
        fitted, stream, n_cores=8, cloud_budget_core_s=5_000.0,
        plan_days=64.5 * tau / 86400, forecast_mode="model")
    assert res2.telemetry is None


def test_run_skyscraper_multi_telemetry_with_sharded_sink():
    """Multi-stream entry point: per-stream counters + sharded-store
    ingest lag, with empty shards reporting a finite imbalance."""
    fitteds = [_synthetic_fitted(seed=s) for s in range(2)]
    streams = [generate(COVID, days=0.005, seed=s) for s in range(2)]
    T = min(s.n_segments for s in streams)
    tau = fitteds[0].workload.segment_seconds
    store = ShardedStore(out_dim=len(fitteds[0].configs), n_shards=4,
                         chunk_rows=256)
    out = IG.run_skyscraper_multi(
        fitteds, streams, n_cores_each=8, cloud_budget_core_s=900.0,
        plan_days=64 * tau / 86400, sink=store, telemetry=True)
    tel = out["telemetry"]
    assert np.asarray(tel.counters["seg_total"]).shape == (2,)
    assert tel.segments == 2 * T
    stel = store.telemetry()
    assert stel.n_rows == 2 * T
    # streams 0,1 hash to shards 0,1 -> shards 2,3 stay empty
    assert len(stel.rows_by_shard) == 4
    assert (stel.rows_by_shard == 0).sum() == 2
    assert stel.imbalance == 2.0
    # fused batch: row t waited T-1-t ticks; mean (T-1)/2 over 2T rows
    assert stel.ingest_dispatches == 1
    assert stel.lag_max_ticks == T - 1
    assert stel.lag_rows == 2 * T
    assert stel.lag_sum_ticks == 2 * (T * (T - 1) // 2)
    np.testing.assert_allclose(stel.lag_mean_ticks, (T - 1) / 2)


def test_store_counters_tick_vs_batch_lag():
    """append_rows is tick ingest (lag 0); ingest_fused is a batch
    (lag 0..T-1); queries count; empty store is balanced by fiat."""
    store = SegmentStore(out_dim=2, chunk_rows=64)
    empty = store.telemetry()
    assert empty.n_rows == 0 and empty.imbalance == 1.0
    assert empty.lag_mean_ticks == 0.0
    n = 50
    rng = np.random.default_rng(0)
    store.append_rows({
        "stream_id": np.zeros(n, np.int32),
        "t": np.arange(n, dtype=np.int32),
        "category": np.zeros(n, np.int32),
        "k": np.zeros(n, np.int32),
        "quality": rng.random(n).astype(np.float32),
        "on_core_s": rng.random(n).astype(np.float32),
        "cloud_core_s": rng.random(n).astype(np.float32),
        "buffer_s": rng.random(n).astype(np.float32),
        "out": rng.random((n, 2)).astype(np.float32),
    })
    stel = store.telemetry()
    assert stel.n_rows == n and stel.ingest_dispatches == 1
    assert stel.lag_rows == n and stel.lag_sum_ticks == 0
    assert stel.lag_max_ticks == 0 and stel.lag_mean_ticks == 0.0
    from repro.warehouse import Filter
    store.query((Filter("quality", "ge", 0.0),))
    store.query((Filter("quality", "ge", 0.5),))
    assert store.telemetry().query_dispatches == 2


def test_tiered_store_spill_and_dequantize_counters():
    """Tiering events: each spill and each cold-chunk materialization
    (cache miss) is counted; cache hits are not."""
    rng = np.random.default_rng(3)
    n, chunk = 2048, 256
    store = SegmentStore(out_dim=3, chunk_rows=chunk)
    store.append_rows({
        "stream_id": rng.integers(0, 4, n).astype(np.int32),
        "t": np.arange(n, dtype=np.int32),
        "category": rng.integers(0, 4, n).astype(np.int32),
        "k": rng.integers(0, 3, n).astype(np.int32),
        "quality": rng.random(n).astype(np.float32),
        "on_core_s": rng.random(n).astype(np.float32),
        "cloud_core_s": rng.random(n).astype(np.float32),
        "buffer_s": rng.random(n).astype(np.float32),
        "out": rng.random((n, 3)).astype(np.float32),
    })
    ts = TieredStore(store, seed=1)
    spilled = ts.spill(keep_hot=n // 2)
    stel = ts.telemetry()
    assert stel.spill_events == 1 and stel.spilled_rows == spilled
    assert stel.n_rows == n
    assert stel.dequantize_events == 0
    from repro.warehouse import GroupBy
    plan = (GroupBy("category", "quality", agg="mean", num_groups=4),)
    ts.query(plan)
    d1 = ts.telemetry().dequantize_events
    assert d1 >= 1
    ts.query(plan)                      # cold tier unchanged: cache hit
    assert ts.telemetry().dequantize_events == d1
    assert ts.telemetry().query_dispatches >= 1


def test_pool_host_telemetry_bit_exact_vs_sink_rows():
    """The serving pool's host-side accumulator replays bit-exactly
    from the per-tick rows its own sink captured, and counts ticks and
    replans."""
    from repro.core.api import Skyscraper, SkyscraperPool

    sky = Skyscraper(segment_seconds=2.0, n_categories=3)
    sky.set_resources(num_cores=4)
    sky.register_knob("det", [1, 5, 10])
    segs = list(np.linspace(0, 1, 40))

    def proc(seg, kv):
        return seg, float(np.clip(1 - seg * (1 - 1.0 / kv["det"]), 0, 1))

    sky.fit(segs, proc, plan_segments=16)
    V, n_ticks = 3, 16
    store = SegmentStore(out_dim=len(sky.configs), chunk_rows=64)
    pool = SkyscraperPool(sky, n_streams=V, sink=store, telemetry=True)
    rng = np.random.default_rng(7)
    for _ in range(n_ticks):
        pool.process(list(rng.random(V)))
    tel = pool.telemetry()
    assert tel.extras["ticks"] == n_ticks
    assert tel.extras["replans"] == 1.0          # tick 16 replanned
    assert tel.segments == V * n_ticks
    assert tel.dropped == 0.0
    h = store.host_rows()
    k0 = int(np.argmin(np.asarray(sky.tables.rank_pos)))
    order = np.lexsort((h["t"], h["stream_id"]))
    traces = {"k": h["k"][order].reshape(V, n_ticks),
              "dropped": np.zeros((V, n_ticks), np.float32),
              "buffer_s": h["buffer_s"][order].reshape(V, n_ticks),
              "on_s": h["on_core_s"][order].reshape(V, n_ticks),
              "cl_s": h["cloud_core_s"][order].reshape(V, n_ticks)}
    ref = telemetry_ref(traces, k0)
    for key in TEL_KEYS:
        np.testing.assert_array_equal(
            np.asarray(tel.counters[key]), ref[key], err_msg=key)
    # without the flag the pool reports nothing (and pays nothing)
    assert SkyscraperPool(sky, n_streams=V).telemetry() is None


def test_window_deltas_sum_back_to_counters():
    """Per-window deltas of the monotone counters telescope back to the
    cumulative totals (the gauges stay cumulative)."""
    tel, _, _ = _run_single_tel(T=23, W=5, seed=1, mode="uniform")
    deltas = tel.window_deltas()
    for key in TEL_KEYS:
        if key == "buffer_hwm_s":
            np.testing.assert_array_equal(deltas[key],
                                          tel.per_window[key])
        else:
            np.testing.assert_allclose(
                deltas[key].sum(axis=0), tel.counters[key],
                rtol=1e-6, err_msg=key)

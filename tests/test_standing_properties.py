"""Property test: for RANDOM plans, ingest interleavings, shard
counts, registration points, and spills, the INCREMENTAL standing
answer equals a full ``execute_ref`` rescan over the exact fp32 rows
in ingest order — bit-exact on the single-store path (float sums
included), counts / max / min / integer-valued sums exact with
float-sum tolerance across the sharded merge (the same contract
``execute_sharded`` has). Spills must never move a standing answer:
the case re-checks bit-equality across the spill and still compares
the final answer against the EXACT pre-quantization rows.

Runs through real ``hypothesis`` when installed, else the bundled
deterministic fallback runner (tests/_hypothesis_fallback.py). On the
forced-8-device CI leg the drawn shard counts get real meshes and the
standing folds run inside real shard_map ingest dispatches."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.warehouse import (Filter, GroupBy, MultiGroupBy, SegmentStore,
                             ShardedStore, ShardedTieredStore,
                             StandingQueries, TieredStore, WindowAgg,
                             execute_ref)

@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches():
    # this module compiles MANY one-off programs (random plan shapes x
    # shard counts x Q-buckets); late in the full suite the process
    # already holds hundreds of live executables and the CPU backend
    # can exhaust JIT code memory mid-compile (observed as a segfault
    # in backend_compile). Start from empty caches so the module's own
    # compile load — which passes standalone — is all that's live.
    jax.clear_caches()
    yield
    # the module's own one-off executables are dead weight for the rest
    # of the suite — drop them too
    jax.clear_caches()


_FLOAT_COLS = ("quality", "on_core_s", "buffer_s")
_INT_COLS = ("category", "k", "stream_id")
_OPS = ("eq", "ne", "lt", "le", "gt", "ge")


def _rows(n, rng, t0=0):
    return {
        "stream_id": rng.integers(0, 9, n).astype(np.int32),
        "t": (t0 + np.sort(rng.integers(0, 40, n))).astype(np.int32),
        "category": rng.integers(0, 5, n).astype(np.int32),
        "k": rng.integers(0, 3, n).astype(np.int32),
        "quality": rng.random(n).astype(np.float32),
        "on_core_s": (rng.random(n) * 20 - 5).astype(np.float32),
        "cloud_core_s": (rng.random(n) * 5).astype(np.float32),
        "buffer_s": (rng.random(n) * 40).astype(np.float32),
        "out": rng.random((n, 2)).astype(np.float32),
    }


@st.composite
def _cases(draw):
    n_shards = draw(st.sampled_from([0, 0, 1, 2, 3, 8]))  # 0 = single
    batches = draw(st.lists(st.integers(min_value=0, max_value=110),
                            min_size=1, max_size=3))
    reg_after = draw(st.integers(min_value=0, max_value=len(batches)))
    data_seed = draw(st.integers(min_value=0, max_value=10_000))
    plan = []
    for _ in range(draw(st.integers(min_value=0, max_value=2))):
        if draw(st.booleans()):
            col = draw(st.sampled_from(_FLOAT_COLS))
            val = draw(st.floats(min_value=-6.0, max_value=25.0))
        else:
            col = draw(st.sampled_from(_INT_COLS))
            val = float(draw(st.integers(min_value=-1, max_value=9)))
        plan.append(Filter(col, draw(st.sampled_from(_OPS)), val))
    kind = draw(st.sampled_from(["group", "window", "multi"]))
    agg = draw(st.sampled_from(["sum", "mean", "count", "max", "min"]))
    value = draw(st.sampled_from(_FLOAT_COLS + ("k",)))
    if kind == "group":
        plan.append(GroupBy(draw(st.sampled_from(_INT_COLS)), value,
                            agg=agg,
                            num_groups=draw(st.sampled_from([1, 6]))))
    elif kind == "window":
        plan.append(WindowAgg(window=draw(st.sampled_from([30, 80])),
                              value=value, agg=agg, num_windows=9))
    else:
        plan.append(MultiGroupBy(keys=("t", "category"), value=value,
                                 agg=agg, nums=(5, 5), windows=(40, 0)))
    # spill after this batch index (tiered wrapper), or no tiering
    spill_after = draw(st.sampled_from([-1, -1] +
                                       list(range(len(batches)))))
    use_pallas = draw(st.booleans())
    return (n_shards, tuple(batches), reg_after, data_seed, tuple(plan),
            spill_after, use_pallas)


@settings(max_examples=60, deadline=None)
@given(_cases())
def test_standing_answer_matches_full_rescan(case):
    (n_shards, batches, reg_after, data_seed, plan, spill_after,
     use_pallas) = case
    rng = np.random.default_rng(data_seed)
    sharded = n_shards > 0
    if sharded:
        hot = ShardedStore(out_dim=2, n_shards=n_shards, chunk_rows=48)
        store = ShardedTieredStore(hot, seed=1) if spill_after >= 0 \
            else hot
    else:
        hot = SegmentStore(out_dim=2, chunk_rows=48)
        store = TieredStore(hot, seed=1) if spill_after >= 0 else hot
    reg = StandingQueries(store)
    handle = None
    seen = []                     # exact fp32 rows, ingest order
    t0 = 0
    for i, n in enumerate(batches):
        if reg_after == i:
            handle = reg.register(plan, use_pallas=use_pallas)
        if n:
            rows = _rows(n, rng, t0=t0)
            t0 = int(rows["t"].max()) + 1
            hot.append_rows(rows)
            seen.append(rows)
        if spill_after == i and store.n_rows:
            pre_t, pre_m = reg.answer(handle) if handle is not None \
                else (None, None)
            store.spill(keep_hot=store.n_rows // 2)
            if handle is not None:     # spills never move an answer
                post_t, post_m = reg.answer(handle)
                np.testing.assert_array_equal(np.asarray(post_m),
                                              np.asarray(pre_m))
                for k in pre_t:
                    np.testing.assert_array_equal(np.asarray(post_t[k]),
                                                  np.asarray(pre_t[k]),
                                                  err_msg=f"spill:{k}")
    if handle is None:
        handle = reg.register(plan, use_pallas=use_pallas)

    n_total = sum(len(r["t"]) for r in seen)
    assert store.n_rows == n_total
    full = {k: np.concatenate([r[k] for r in seen])
            for k in _rows(0, rng)} if seen else _rows(0, rng)
    # a registration AFTER a spill backfills from dequantized cold rows
    # — the exact-rows oracle only applies when the registration saw
    # every row at fp32 (backfill before the spill, or folds only)
    backfill_exact = reg_after <= spill_after or spill_after < 0 \
        or sum(batches[:reg_after]) == 0
    if not backfill_exact:
        return
    ref, rmask = execute_ref(full, n_total, plan)
    table, mask = reg.answer(handle)
    np.testing.assert_array_equal(np.asarray(mask), rmask)
    node = plan[-1]
    value, agg = node.value, node.agg
    np.testing.assert_array_equal(np.asarray(table["count"]),
                                  ref["count"])
    for key in table:
        if key in ("count", value):
            continue
        np.testing.assert_array_equal(np.asarray(table[key]), ref[key],
                                      err_msg=key)
    got = np.asarray(table[value], np.float32)
    want = np.asarray(ref[value], np.float32)
    exact = (agg in ("count", "max", "min")
             or (np.issubdtype(full[value].dtype, np.integer)
                 and agg == "sum"))
    g = reg._group_of(reg._queries[handle])
    if not sharded and not g.use_pallas:
        # single-store XLA fold: bit-exact, float sums included
        np.testing.assert_array_equal(got, want)
    elif exact:
        # sharded merge / Pallas tile sums: order-independent aggs and
        # small-int f32 sums still land bit-exact
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

"""int8 gradient compression: quantization error bounds, unbiasedness of
stochastic rounding, and error-feedback convergence in a DP training
loop (run on a forced multi-device mesh in a subprocess where needed —
here single-process psum via shard_map on a 1-device mesh plus math
properties)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distribution.compression import (compressed_psum, dequantize,
                                            quantize_int8)


def test_quantization_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1000,)) * 3.0
    q, scale = quantize_int8(x, jax.random.PRNGKey(1))
    err = np.abs(np.asarray(dequantize(q, scale) - x))
    assert err.max() <= float(scale) + 1e-6


def test_stochastic_rounding_unbiased():
    key = jax.random.PRNGKey(0)
    x = jnp.full((20_000,), 0.3)
    q, scale = quantize_int8(x, key)
    mean = float(dequantize(q, scale).mean())
    np.testing.assert_allclose(mean, 0.3, rtol=2e-2)


def test_quantize_roundtrip_bound_per_chunk():
    """The warehouse cold tier quantizes PER CHUNK (vmapped
    quantize_int8 with one scale per chunk): every chunk's round-trip
    error is bounded by that chunk's own scale = max|x_chunk|/127, so a
    quiet chunk is not degraded by a loud one."""
    key = jax.random.PRNGKey(3)
    n_chunks, chunk = 8, 512
    # chunk c scaled by 10^c: dynamic ranges differ by 7 orders
    mags = 10.0 ** jnp.arange(n_chunks, dtype=jnp.float32)
    x = jax.random.normal(key, (n_chunks, chunk)) * mags[:, None]
    keys = jax.random.split(jax.random.PRNGKey(4), n_chunks)
    q, scales = jax.vmap(quantize_int8)(x, keys)
    assert q.dtype == jnp.int8 and scales.shape == (n_chunks,)
    deq = jax.vmap(dequantize)(q, scales)
    err = np.abs(np.asarray(deq - x))
    per_chunk_bound = np.asarray(scales) + 1e-6
    assert (err.max(axis=1) <= per_chunk_bound).all()
    # per-chunk scales: the quiet chunk's error stays ~1e7x below the
    # loud chunk's (a single shared scale would wipe the quiet chunk)
    assert err[0].max() <= float(scales[-1]) * 1e-5


def test_compressed_psum_error_feedback_unbiased_over_steps():
    """compressed_psum itself (through shard_map on a 1-device 'pod'
    mesh): carrying its error residual across steps makes the
    accumulated compressed reduction converge to the true accumulated
    mean — compression noise stays unbiased over steps."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1,), ("pod",))
    spec = P()
    # build + jit the shard_map ONCE (key is a traced operand) so the
    # 200-step loop reuses a single executable
    step = jax.jit(shard_map(
        lambda x, e, k: compressed_psum(x, "pod", k, e),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=(spec, spec)))

    key = jax.random.PRNGKey(5)
    true_sum = jnp.zeros((256,))
    comp_sum = jnp.zeros((256,))
    err = jnp.zeros((256,))
    for _ in range(200):
        key, k1, k2 = jax.random.split(key, 3)
        g = jax.random.normal(k1, (256,)) * 0.1
        red, err = step(g, err, k2)
        true_sum = true_sum + g          # psum mean over 1 pod == g
        comp_sum = comp_sum + red
    rel = float(jnp.linalg.norm(comp_sum - true_sum)
                / jnp.linalg.norm(true_sum))
    assert rel < 0.02, rel
    # the residual itself stays bounded by one quantization step
    assert float(jnp.abs(err).max()) < 0.1


def test_error_feedback_recovers_signal():
    """With error feedback, the accumulated compressed signal converges
    to the true accumulated signal (compression noise does not bias)."""
    key = jax.random.PRNGKey(2)
    true_sum = jnp.zeros((256,))
    comp_sum = jnp.zeros((256,))
    err = jnp.zeros((256,))
    for t in range(200):
        key, k1, k2 = jax.random.split(key, 3)
        g = jax.random.normal(k1, (256,)) * 0.1
        q, scale = quantize_int8(g + err, k2)
        deq = dequantize(q, scale)
        err = (g + err) - deq
        true_sum = true_sum + g
        comp_sum = comp_sum + deq
    rel = float(jnp.linalg.norm(comp_sum - true_sum)
                / jnp.linalg.norm(true_sum))
    assert rel < 0.02, rel

"""int8 gradient compression: quantization error bounds, unbiasedness of
stochastic rounding, and error-feedback convergence in a DP training
loop (run on a forced multi-device mesh in a subprocess where needed —
here single-process psum via shard_map on a 1-device mesh plus math
properties)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distribution.compression import dequantize, quantize_int8


def test_quantization_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1000,)) * 3.0
    q, scale = quantize_int8(x, jax.random.PRNGKey(1))
    err = np.abs(np.asarray(dequantize(q, scale) - x))
    assert err.max() <= float(scale) + 1e-6


def test_stochastic_rounding_unbiased():
    key = jax.random.PRNGKey(0)
    x = jnp.full((20_000,), 0.3)
    q, scale = quantize_int8(x, key)
    mean = float(dequantize(q, scale).mean())
    np.testing.assert_allclose(mean, 0.3, rtol=2e-2)


def test_error_feedback_recovers_signal():
    """With error feedback, the accumulated compressed signal converges
    to the true accumulated signal (compression noise does not bias)."""
    key = jax.random.PRNGKey(2)
    true_sum = jnp.zeros((256,))
    comp_sum = jnp.zeros((256,))
    err = jnp.zeros((256,))
    for t in range(200):
        key, k1, k2 = jax.random.split(key, 3)
        g = jax.random.normal(k1, (256,)) * 0.1
        q, scale = quantize_int8(g + err, k2)
        deq = dequantize(q, scale)
        err = (g + err) - deq
        true_sum = true_sum + g
        comp_sum = comp_sum + deq
    rel = float(jnp.linalg.norm(comp_sum - true_sum)
                / jnp.linalg.norm(true_sum))
    assert rel < 0.02, rel

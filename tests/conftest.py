import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS before importing jax) — do NOT force a device count here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))
# repo root, so tests can reuse benchmark fixtures (benchmarks.*)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# Property tests use hypothesis (requirements-dev.txt). In hermetic
# environments without it, fall back to the minimal deterministic
# property runner so the suite still collects and exercises the
# properties. The real package always wins when installed.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback as _hf
    sys.modules["hypothesis"] = _hf
    sys.modules["hypothesis.strategies"] = _hf.strategies

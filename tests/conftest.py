import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS before importing jax) — do NOT force a device count here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

"""Property test: for RANDOM plans, data, and shard counts, sharded
partial/merge execution matches the single-device engine — exact for
counts and integer-valued columns, fp32-regrouping-tolerant for float
sums — including empty shards, empty stores, and ragged last chunks.

Each case also draws a ``use_pallas`` axis: when True, the sharded
query runs its per-shard partials through the fused Pallas kernel
(interpret mode on CPU) AND the single-device Pallas path is checked
three-ways against the XLA engine and the numpy mirror under the same
exactness contract.

Runs through real ``hypothesis`` when installed, else the bundled
deterministic fallback runner (tests/_hypothesis_fallback.py). On the
forced-8-device CI leg the drawn shard counts get real meshes and the
property exercises the shard_map collective merge path."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.warehouse import (Filter, GroupBy, MultiGroupBy, SegmentStore,
                             ShardedStore, TopK, WindowAgg, execute,
                             execute_ref)

_FLOAT_COLS = ("quality", "on_core_s", "buffer_s")
_INT_COLS = ("category", "k", "stream_id")
_OPS = ("eq", "ne", "lt", "le", "gt", "ge")


def _rows(n, rng):
    return {
        "stream_id": rng.integers(0, 9, n).astype(np.int32),
        "t": np.sort(rng.integers(0, 400, n)).astype(np.int32),
        "category": rng.integers(0, 5, n).astype(np.int32),
        "k": rng.integers(0, 3, n).astype(np.int32),
        "quality": rng.random(n).astype(np.float32),
        "on_core_s": (rng.random(n) * 20 - 5).astype(np.float32),
        "cloud_core_s": (rng.random(n) * 5).astype(np.float32),
        "buffer_s": (rng.random(n) * 40).astype(np.float32),
        "out": rng.random((n, 2)).astype(np.float32),
    }


@st.composite
def _cases(draw):
    n = draw(st.integers(min_value=0, max_value=260))
    n_shards = draw(st.sampled_from([1, 2, 3, 4, 8]))
    data_seed = draw(st.integers(min_value=0, max_value=10_000))
    # chunk 48 never divides the row count evenly -> ragged last chunks
    # + capacity padding rows on every shard
    plan = []
    for _ in range(draw(st.integers(min_value=0, max_value=2))):
        if draw(st.booleans()):
            col = draw(st.sampled_from(_FLOAT_COLS))
            val = draw(st.floats(min_value=-6.0, max_value=25.0))
        else:
            col = draw(st.sampled_from(_INT_COLS))
            val = float(draw(st.integers(min_value=-1, max_value=9)))
        plan.append(Filter(col, draw(st.sampled_from(_OPS)), val))
    kind = draw(st.sampled_from(["group", "window", "multi", "topk"]))
    agg = draw(st.sampled_from(["sum", "mean", "count", "max", "min"]))
    value = draw(st.sampled_from(_FLOAT_COLS + ("k",)))
    use_pallas = draw(st.booleans())
    if kind == "group":
        key = draw(st.sampled_from(_INT_COLS))
        # num_groups=1 is the single-accumulator degenerate shape
        plan.append(GroupBy(key, value, agg=agg,
                            num_groups=draw(st.sampled_from([1, 6]))))
    elif kind == "window":
        plan.append(WindowAgg(window=draw(st.sampled_from([50, 130])),
                              value=value, agg=agg, num_windows=9))
    elif kind == "multi":
        plan.append(MultiGroupBy(keys=("t", "category"), value=value,
                                 agg=agg, nums=(5, 5), windows=(100, 0)))
    else:
        # row-level top-k only: top-k AFTER an aggregation is covered
        # deterministically in test_sharded_warehouse.py (near-tie float
        # sums could legitimately swap adjacent ranks across shard
        # regroupings, which a random-data property can't distinguish
        # from a bug)
        plan.append(TopK(draw(st.integers(min_value=1, max_value=12)),
                         by=value, largest=draw(st.booleans())))
    return n, n_shards, data_seed, tuple(plan), use_pallas


@settings(max_examples=60, deadline=None)
@given(_cases())
def test_sharded_matches_single_device(case):
    n, n_shards, data_seed, plan, use_pallas = case
    rows = _rows(n, np.random.default_rng(data_seed))
    single = SegmentStore(out_dim=2, chunk_rows=48)
    sharded = ShardedStore(out_dim=2, n_shards=n_shards, chunk_rows=48)
    if n:
        single.append_rows(rows)
        sharded.append_rows(rows)
    assert sharded.n_rows == single.n_rows == n
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    ref, rmask = execute_ref(cols, n, plan)
    table, mask = sharded.query(plan, use_pallas=use_pallas)
    m, rm = np.asarray(mask), np.asarray(rmask)

    reduce_node = next((nd for nd in plan
                        if not isinstance(nd, Filter)), None)
    if isinstance(reduce_node, TopK):
        # row-level top-k: same number of survivors, same score multiset
        assert m.sum() == rm.sum()
        by = reduce_node.by
        np.testing.assert_allclose(
            np.sort(np.asarray(table[by], np.float32)[m]),
            np.sort(np.asarray(ref[by], np.float32)[rm]),
            rtol=1e-5, atol=1e-5)
        return
    # aggregation plans: identical group axes and masks
    np.testing.assert_array_equal(m, rm)
    value, agg = reduce_node.value, reduce_node.agg
    np.testing.assert_array_equal(np.asarray(table["count"]),
                                  ref["count"])
    got = np.asarray(table[value], np.float32)
    want = np.asarray(ref[value], np.float32)
    exact = (agg in ("count", "max", "min")           # order-independent
             or np.issubdtype(rows[value].dtype, np.integer)
             and agg == "sum")                        # small-int f32 sums
    if exact:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    for key in table:
        if key in ("count", value, "index"):
            continue
        np.testing.assert_array_equal(np.asarray(table[key]), ref[key],
                                      err_msg=key)
    if use_pallas:
        # three-way: the single-device fused Pallas kernel must meet
        # the same contract vs the numpy mirror (and hence vs XLA)
        ptable, pmask = execute(single, plan, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(pmask), rm)
        np.testing.assert_array_equal(np.asarray(ptable["count"]),
                                      ref["count"])
        pgot = np.asarray(ptable[value], np.float32)
        if exact:
            np.testing.assert_array_equal(pgot, want)
        else:
            np.testing.assert_allclose(pgot, want, rtol=1e-5, atol=1e-4)

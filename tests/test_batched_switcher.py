"""Batched multi-stream switcher engine: the fused V-stream scan must be
bit-identical to V independent per-stream scans, padded tail windows must
be exact no-ops, and repeated fixed-length windows must never recompile."""
import jax.numpy as jnp
import numpy as np

from repro.core.switcher import (compile_cache_size, init_state,
                                 init_state_multi, pad_window, run_window,
                                 run_window_multi, stack_tables,
                                 switch_step_multi)
from test_switcher import make_tables

TRACE_KEYS = ("k", "p", "c", "qual", "on_s", "cl_s", "buffer_s", "rt",
              "dropped")


def _make_streams(V=4, T=160, seed=0):
    rng = np.random.default_rng(seed)
    tables = [make_tables(seed=v, cap=20.0 + 5 * v, cloud=40.0 + 10 * v)
              for v in range(V)]
    C, K = tables[0].n_categories, tables[0].n_configs
    alphas = rng.random((V, C, K)).astype(np.float32)
    alphas /= alphas.sum(-1, keepdims=True)
    quals = rng.random((V, T, K)).astype(np.float32)
    arrivals = (0.5 + 2.5 * rng.random((V, T))).astype(np.float32)
    return tables, jnp.asarray(alphas), jnp.asarray(quals), \
        jnp.asarray(arrivals)


def test_batched_scan_bit_identical_to_per_stream():
    """One fused scan over V streams == V independent run_window calls,
    bit for bit, on every trace and on the final state — including
    per-stream heterogeneous buffer caps and cloud budgets."""
    V, T = 4, 160
    tables, alphas, quals, arrivals = _make_streams(V, T)
    # reference: V independent per-stream scans
    ref_outs, ref_states = [], []
    for v in range(V):
        st, outs = run_window(init_state(tables[v]), quals[v], arrivals[v],
                              alphas[v], tables[v])
        ref_states.append(st)
        ref_outs.append(outs)
    # batched: single fused scan
    state, outs = run_window_multi(init_state_multi(tables), quals,
                                   arrivals, alphas, stack_tables(tables))
    for key in TRACE_KEYS:
        got = np.asarray(outs[key])
        for v in range(V):
            np.testing.assert_array_equal(
                got[v], np.asarray(ref_outs[v][key]),
                err_msg=f"trace {key!r} diverged for stream {v}")
    for key in ref_states[0]:
        got = np.asarray(state[key])
        for v in range(V):
            np.testing.assert_array_equal(
                got[v], np.asarray(ref_states[v][key]),
                err_msg=f"final state {key!r} diverged for stream {v}")


def test_padded_tail_window_masked_segments_are_noops():
    """A window padded from T to W must (a) reproduce the unpadded run on
    the real prefix, (b) contribute ZERO quality/work/cloud for the
    padding, and (c) leave the state exactly where the unpadded run did."""
    tables = make_tables(seed=3)
    K, C = tables.n_configs, tables.n_categories
    rng = np.random.default_rng(7)
    T, W = 110, 256
    alpha = jnp.asarray(rng.random((C, K)).astype(np.float32))
    quals = jnp.asarray(rng.random((T, K)), jnp.float32)
    arrivals = jnp.asarray(0.5 + rng.random(T), jnp.float32)

    st_ref, outs_ref = run_window(init_state(tables), quals, arrivals,
                                  alpha, tables)
    q_pad, a_pad, valid = pad_window(quals, arrivals, W)
    assert q_pad.shape == (W, K) and int(valid.sum()) == T
    st_pad, outs_pad = run_window(init_state(tables), q_pad, a_pad, alpha,
                                  tables, valid=valid)
    # (a) real prefix identical
    for key in TRACE_KEYS:
        np.testing.assert_array_equal(np.asarray(outs_pad[key])[:T],
                                      np.asarray(outs_ref[key]),
                                      err_msg=f"prefix {key!r}")
    # (b) padding contributes zero quality and zero work
    tail = {k: np.asarray(v)[T:] for k, v in outs_pad.items()}
    assert np.all(tail["qual"] == 0.0)
    assert np.all(tail["on_s"] == 0.0)
    assert np.all(tail["cl_s"] == 0.0)
    assert np.all(tail["rt"] == 0.0)
    assert not tail["dropped"].any()
    # buffer frozen at its end-of-data value (no drain, no fill)
    assert np.all(tail["buffer_s"] == np.asarray(st_ref["buffer_s"]))
    # (c) final state untouched by the padding
    for key in st_ref:
        np.testing.assert_array_equal(np.asarray(st_pad[key]),
                                      np.asarray(st_ref[key]),
                                      err_msg=f"state {key!r}")


def test_fixed_window_padding_compiles_once():
    """Many windows (including short tails) padded to one fixed W must
    reuse a single executable — zero recompiles after warmup."""
    tables = make_tables(seed=1)
    K, C = tables.n_configs, tables.n_categories
    rng = np.random.default_rng(1)
    W = 64
    alpha = jnp.asarray(rng.random((C, K)).astype(np.float32))
    state = init_state(tables)
    single0, _ = compile_cache_size()
    for T in (64, 64, 40, 64, 7):          # tails of varying length
        quals = jnp.asarray(rng.random((T, K)), jnp.float32)
        arrivals = jnp.ones((T,), jnp.float32)
        q, a, valid = pad_window(quals, arrivals, W)
        state, _ = run_window(state, q, a, alpha, tables, valid=valid)
    single1, _ = compile_cache_size()
    assert single1 - single0 <= 1, "padded windows must share one compile"


def test_switch_step_multi_matches_sequential_steps():
    """The single-dispatch batched decision (serving path) agrees with V
    independent switch_step calls."""
    from repro.core.switcher import switch_step
    V = 3
    tables = [make_tables(seed=v) for v in range(V)]
    K, C = tables[0].n_configs, tables[0].n_categories
    rng = np.random.default_rng(2)
    alphas = rng.random((V, C, K)).astype(np.float32)
    q_rows = rng.random((V, K)).astype(np.float32)
    arr = (0.5 + rng.random(V)).astype(np.float32)
    ref = [switch_step(init_state(tb), jnp.asarray(q_rows[v]),
                       jnp.float32(arr[v]), jnp.asarray(alphas[v]), tb)
           for v, tb in enumerate(tables)]
    state, outs = switch_step_multi(init_state_multi(tables),
                                    jnp.asarray(q_rows), jnp.asarray(arr),
                                    jnp.asarray(alphas),
                                    stack_tables(tables))
    for v, (st_v, out_v) in enumerate(ref):
        for key in out_v:
            np.testing.assert_array_equal(np.asarray(outs[key])[v],
                                          np.asarray(out_v[key]),
                                          err_msg=f"out {key!r} stream {v}")
        for key in st_v:
            np.testing.assert_array_equal(np.asarray(state[key])[v],
                                          np.asarray(st_v[key]),
                                          err_msg=f"state {key!r} stream {v}")

"""Knob planner: the jit Lagrangian solver must match scipy's LP exactly
(feasibility + optimal value) — property-based over random instances."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.planner import plan_value, solve_lp_lagrangian, solve_lp_scipy


@st.composite
def lp_instance(draw):
    C = draw(st.integers(2, 8))
    K = draw(st.integers(2, 10))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    qual = rng.random((C, K)).astype(np.float32)
    cost = (rng.random(K) * 10 + 0.05).astype(np.float32)
    r = rng.random(C).astype(np.float32) + 0.01
    r /= r.sum()
    budget = float(rng.random() * 12)
    return qual, cost, r, budget


@settings(max_examples=60, deadline=None)
@given(lp_instance())
def test_lagrangian_matches_scipy(inst):
    qual, cost, r, budget = inst
    a_ref = solve_lp_scipy(qual, cost, r, budget)
    a = np.asarray(solve_lp_lagrangian(jnp.asarray(qual), jnp.asarray(cost),
                                       jnp.asarray(r), budget))
    q_ref, s_ref = plan_value(jnp.asarray(a_ref), jnp.asarray(qual),
                              jnp.asarray(cost), jnp.asarray(r))
    q, s = plan_value(jnp.asarray(a), jnp.asarray(qual), jnp.asarray(cost),
                      jnp.asarray(r))
    # feasible (up to the scipy fallback when the budget is infeasible)
    assert s <= max(budget, s_ref) + 1e-3
    # optimal
    assert q >= q_ref - 1e-3
    # rows are distributions
    np.testing.assert_allclose(a.sum(1), 1.0, atol=1e-4)
    assert (a >= -1e-6).all()


@st.composite
def lp_corner_instance(draw):
    """Random instances biased onto the solver's corners: K=1 (nothing
    to plan), single-category C=1, and infeasible budgets (below the
    cheapest plan's spend)."""
    C = draw(st.integers(1, 8))
    K = draw(st.integers(1, 10))
    kind = draw(st.sampled_from(["feasible", "infeasible", "tight"]))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    qual = rng.random((C, K)).astype(np.float32)
    cost = np.sort(rng.random(K) * 10 + 0.1).astype(np.float32)
    r = rng.random(C).astype(np.float32) + 0.01
    r /= r.sum()
    if kind == "infeasible":
        budget = float(cost.min()) * float(rng.random() * 0.9)
    elif kind == "tight":
        # strictly between the cheapest and the unconstrained spend
        budget = float(cost.min()) + float(rng.random()) \
            * (float(cost.max()) - float(cost.min()))
    else:
        budget = float(cost.max()) * (1.0 + float(rng.random()))
    return qual, cost, r, budget, kind


@settings(max_examples=80, deadline=None)
@given(lp_corner_instance())
def test_lagrangian_matches_scipy_value_with_corners(inst):
    """Plan value parity within 1e-4 across random (C, K, r, budget)
    instances including infeasible budgets and the K=1 degenerate case
    (the satellite property for the fused engine's on-device planner)."""
    qual, cost, r, budget, kind = inst
    a_ref = solve_lp_scipy(qual, cost, r, budget)
    a = np.asarray(solve_lp_lagrangian(jnp.asarray(qual), jnp.asarray(cost),
                                       jnp.asarray(r), budget))
    q_ref, s_ref = plan_value(jnp.asarray(a_ref), jnp.asarray(qual),
                              jnp.asarray(cost), jnp.asarray(r))
    q, s = plan_value(jnp.asarray(a), jnp.asarray(qual), jnp.asarray(cost),
                      jnp.asarray(r))
    # rows are distributions
    np.testing.assert_allclose(a.sum(1), 1.0, atol=1e-4)
    assert (a >= -1e-6).all()
    if kind == "infeasible":
        # LP infeasible: scipy falls back to all-cheapest; the Lagrangian
        # min-spend endpoint is the same plan -> identical value
        assert abs(q - q_ref) <= 1e-4, (q, q_ref, kind)
        assert abs(s - s_ref) <= 1e-3, (s, s_ref, kind)
    else:
        # optimal value parity + budget feasibility
        assert abs(q - q_ref) <= 1e-4, (q, q_ref, kind)
        assert s <= budget + 1e-3, (s, budget, kind)
    if qual.shape[1] == 1:                 # K=1: only one possible plan
        np.testing.assert_allclose(a, 1.0, atol=1e-6)


def test_affordable_budget_picks_best():
    qual = np.array([[0.2, 0.9], [0.4, 0.8]], np.float32)
    cost = np.array([1.0, 2.0], np.float32)
    r = np.array([0.5, 0.5], np.float32)
    a = np.asarray(solve_lp_lagrangian(jnp.asarray(qual), jnp.asarray(cost),
                                       jnp.asarray(r), 100.0))
    assert a[0, 1] == 1.0 and a[1, 1] == 1.0


def test_infeasible_budget_degrades_to_cheapest():
    qual = np.array([[0.2, 0.9]], np.float32)
    cost = np.array([1.0, 2.0], np.float32)
    r = np.array([1.0], np.float32)
    a = np.asarray(solve_lp_lagrangian(jnp.asarray(qual), jnp.asarray(cost),
                                       jnp.asarray(r), 0.1))
    assert a[0, 0] == pytest.approx(1.0, abs=1e-5)

"""Knob switcher properties: the throughput guarantee (buffer can never
exceed capacity), cloud-budget enforcement, and plan adherence."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.switcher import SwitchTables, init_state, run_window


def make_tables(K=4, C=3, tau=2.0, cap=30.0, cloud=50.0, n_cores=4,
                seed=0):
    rng = np.random.default_rng(seed)
    power = np.sort(rng.random(K)).astype(np.float32)
    cost = np.sort(rng.random(K) * 20 + 0.5).astype(np.float32)
    cost[0] = min(cost[0], tau * n_cores * 0.9)   # guarantee config
    centers = np.sort(rng.random((C, K)), axis=0).astype(np.float32)
    P = 3
    rt = np.stack([cost / n_cores, cost / n_cores * 0.6,
                   cost / n_cores * 0.3], 1)
    cl = np.stack([np.zeros(K), cost * 0.4, cost * 0.7], 1)
    on = np.stack([cost, cost * 0.6, cost * 0.3], 1)
    return SwitchTables(
        centers=jnp.asarray(centers), power=jnp.asarray(power),
        cost=jnp.asarray(cost),
        place_rt=jnp.asarray(rt, jnp.float32),
        place_on=jnp.asarray(on, jnp.float32),
        place_cl=jnp.asarray(cl, jnp.float32),
        place_valid=jnp.ones((K, P), bool),
        rank_pos=jnp.asarray(np.argsort(np.argsort(-power)), jnp.int32),
        tau=tau, buffer_cap_s=cap, cloud_budget=cloud)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(20, 120),
       st.floats(0.5, 4.0))
def test_buffer_never_overflows(seed, T, arrival_peak):
    """Paper Eq. 1: the guarantee must hold for ANY content/arrival."""
    rng = np.random.default_rng(seed)
    tables = make_tables(seed=seed % 7)
    K = tables.n_configs
    alpha = rng.random((tables.n_categories, K)).astype(np.float32)
    alpha /= alpha.sum(1, keepdims=True)
    quals = jnp.asarray(rng.random((T, K)), jnp.float32)
    arrivals = jnp.asarray(
        1.0 + (arrival_peak - 1.0) * rng.random(T), jnp.float32)
    state = init_state(tables)
    state, outs = run_window(state, quals, arrivals, jnp.asarray(alpha),
                             tables)
    buf = np.asarray(outs["buffer_s"])
    assert (buf <= tables.buffer_cap_s + 1e-3).all(), buf.max()
    # cloud budget respected
    assert float(state["cloud_spent"]) <= tables.cloud_budget + 1e-3


def test_plan_adherence_when_unconstrained():
    """With a huge buffer/budget the realized per-category config mix
    must converge to the planned histogram (Eq. 6)."""
    tables = make_tables(cap=1e9, cloud=1e9)
    C, K = tables.n_categories, tables.n_configs
    rng = np.random.default_rng(0)
    alpha = np.zeros((C, K), np.float32)
    alpha[:, 1] = 0.25
    alpha[:, 3] = 0.75
    T = 4000
    quals = jnp.asarray(rng.random((T, K)), jnp.float32)
    arrivals = jnp.ones((T,), jnp.float32)
    state = init_state(tables)
    state, outs = run_window(state, quals, arrivals, jnp.asarray(alpha),
                             tables)
    used = np.asarray(state["used"])
    frac = used.sum(0) / used.sum()
    np.testing.assert_allclose(frac[3], 0.75, atol=0.05)
    np.testing.assert_allclose(frac[1], 0.25, atol=0.05)


def test_degrades_under_pressure():
    """Tiny buffer + no cloud -> must fall back to cheap configs, never
    overflow."""
    tables = make_tables(cap=1.0, cloud=0.0)
    C, K = tables.n_categories, tables.n_configs
    alpha = np.zeros((C, K), np.float32)
    alpha[:, K - 1] = 1.0   # plan demands the most expensive config
    rng = np.random.default_rng(1)
    T = 500
    quals = jnp.asarray(rng.random((T, K)), jnp.float32)
    arrivals = jnp.ones((T,), jnp.float32)
    state = init_state(tables)
    state, outs = run_window(state, quals, arrivals, jnp.asarray(alpha),
                             tables)
    assert float(np.asarray(outs["buffer_s"]).max()) <= 1.0 + 1e-4
    assert float(state["cloud_spent"]) == 0.0


def test_switch_latency_under_half_ms():
    """Paper §5.5: tuning decision < 0.5 ms. Ours is jit-compiled."""
    import time

    from repro.core.switcher import switch_step
    tables = make_tables()
    state = init_state(tables)
    alpha = jnp.ones((tables.n_categories, tables.n_configs)) / tables.n_configs
    q = jnp.ones((tables.n_configs,)) * 0.5
    s2, out = switch_step(state, q, jnp.float32(1.0), alpha, tables)  # warmup
    t0 = time.perf_counter()
    N = 200
    for _ in range(N):
        s2, out = switch_step(s2, q, jnp.float32(1.0), alpha, tables)
    _ = float(out["qual"])
    per_call = (time.perf_counter() - t0) / N
    assert per_call < 0.5e-3, f"{per_call * 1e6:.0f}us"

"""Checkpointing (atomic save/restore/retention) + optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CK
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               global_norm, warmup_cosine)


def tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.int32(7)},
            "lst": [jnp.zeros(3), jnp.ones(2)]}
    p = CK.save(str(tmp_path / "x.rsk"), tree)
    back = CK.restore(p)
    tree_eq(tree, back)
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_retention_and_latest(tmp_path):
    d = str(tmp_path / "ckpts")
    for step in [10, 20, 30, 40]:
        CK.save(d, {"w": jnp.full((2,), step)}, step=step, keep=2)
    assert CK.latest_step(d) == 40
    files = sorted(os.listdir(d))
    assert files == ["ckpt_00000030.rsk", "ckpt_00000040.rsk"]
    back = CK.restore(d, 40)
    assert float(back["w"][0]) == 40


def test_no_tmp_left_behind(tmp_path):
    d = str(tmp_path / "ckpts")
    CK.save(d, {"w": jnp.ones(3)}, step=1)
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_meta_roundtrip(tmp_path):
    """Plain-python metadata rides alongside the arrays (the warehouse
    persists row counts / chunking this way) and is invisible to
    readers that don't ask for it."""
    tree = {"w": jnp.arange(4, dtype=jnp.float32),
            "q": jnp.array([-3, 7], jnp.int8)}
    meta = {"n_rows": 12345, "chunk_rows": 512, "tag": "hot",
            "nested": {"seed": 3}}
    p = CK.save(str(tmp_path / "m.rsk"), tree, meta=meta)
    back, got = CK.restore(p, return_meta=True)
    tree_eq(tree, back)
    assert back["q"].dtype == jnp.int8
    assert got == meta
    # default restore ignores the metadata entirely
    tree_eq(tree, CK.restore(p))
    # checkpoints written without meta report None
    p2 = CK.save(str(tmp_path / "nometa.rsk"), tree)
    _, none_meta = CK.restore(p2, return_meta=True)
    assert none_meta is None


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - 1.0) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, lr=0.05, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) == pytest.approx(20.0)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup=10,
                               total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert lrs[99] < lrs[50] < lrs[12]

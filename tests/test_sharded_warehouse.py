"""Shard-aware warehouse: stream-hash routed ingestion, the partial/
merge query engine (1-shard bit-exact, multi-shard tolerance-bounded),
zero-recompile guarantees, per-shard tiering, and the compressed merge.

On a 1-device host every test runs the SAME kernels through the stacked
single-device fallback (``store.mesh is None``); ``scripts/tier1.sh``
re-runs this module under ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` where the stores get a real ``('shard',)`` mesh and
queries/ingests execute as ONE shard_map dispatch with collective
merges — the assertions are identical in both modes."""

import jax
import numpy as np

from benchmarks.fused_ingest_bench import _synthetic_fitted
from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.data.stream import generate
from repro.warehouse import (Filter, GroupBy, MultiGroupBy, Project,
                             SegmentStore, ShardedStore,
                             ShardedTieredStore, TopK, WindowAgg,
                             execute_ref, to_host, windows_for)
from repro.warehouse import query as Q
from test_warehouse import _random_rows

N_CORES = 8  # matches the profile baked into _synthetic_fitted


def _stores(n, D, n_shards, seed=0, chunk=256, streams=16):
    rows = _random_rows(n, D, seed=seed)
    rows["stream_id"] = (np.arange(n, dtype=np.int32) * 7) % streams
    single = SegmentStore(out_dim=D, chunk_rows=max(chunk, 64))
    single.append_rows(rows)
    sharded = ShardedStore(out_dim=D, n_shards=n_shards, chunk_rows=chunk)
    sharded.append_rows(rows)
    return single, sharded, rows


# ---------------------------------------------------------------------------
# routing / ingestion
# ---------------------------------------------------------------------------

def test_append_routes_by_stream_hash():
    single, sharded, rows = _stores(3000, 3, n_shards=4)
    assert sharded.n_rows == 3000
    # device row counts agree with the host-metadata mirror
    np.testing.assert_array_equal(np.asarray(sharded.n_rows_dev),
                                  sharded.n_rows_by_shard)
    h = sharded.host_rows()
    # every row lands exactly once, on its owner shard, in time order
    assert sorted(h["t"].tolist()) == sorted(rows["t"].tolist())
    off = 0
    for s in range(4):
        blk = slice(off, off + sharded.n_rows_by_shard[s])
        assert (h["stream_id"][blk] % 4 == s).all()
        assert (np.diff(h["t"][blk]) > 0).all()       # append order kept
        off += sharded.n_rows_by_shard[s]
    # full content equality against the unsharded store (row-order free)
    hf = single.host_rows()
    of = np.lexsort((hf["t"], hf["stream_id"]))
    os_ = np.lexsort((h["t"], h["stream_id"]))
    for k in hf:
        np.testing.assert_array_equal(hf[k][of], h[k][os_], err_msg=k)


def test_fused_multi_sink_shards_without_host_gathers():
    """The SAME fused multi-stream run lands in a flat and a sharded
    sink; the sharded one holds identical rows, each stream's whole
    trace on shard (stream_base + v) % n_shards."""
    fitted = _synthetic_fitted()
    K = len(fitted.configs)
    tau = fitted.workload.segment_seconds
    V = 3
    streams = [generate(COVID, days=0.01, seed=s) for s in range(V)]
    T = min(s.n_segments for s in streams)
    flat = SegmentStore(out_dim=K, chunk_rows=512)
    sharded = ShardedStore(out_dim=K, n_shards=2, chunk_rows=256)
    kw = dict(n_cores_each=N_CORES, cloud_budget_core_s=900.0,
              plan_days=64 * tau / 86400, sink_stream_base=10)
    IG.run_skyscraper_multi([fitted] * V, streams, sink=flat, **kw)
    IG.run_skyscraper_multi([fitted] * V, streams, sink=sharded, **kw)
    assert sharded.n_rows == flat.n_rows == V * T
    hf, hs = flat.host_rows(), sharded.host_rows()
    of = np.lexsort((hf["t"], hf["stream_id"]))
    os_ = np.lexsort((hs["t"], hs["stream_id"]))
    for k in hf:
        np.testing.assert_array_equal(hf[k][of], hs[k][os_], err_msg=k)
    # streams 10, 12 -> shard 0; stream 11 -> shard 1
    np.testing.assert_array_equal(
        np.unique(hs["stream_id"][: sharded.n_rows_by_shard[0]]), [10, 12])
    assert all(isinstance(v, jax.Array)
               for v in sharded.columns.values())


def test_single_stream_fused_sink_owns_one_shard():
    fitted = _synthetic_fitted()
    tau = fitted.workload.segment_seconds
    stream = generate(COVID, days=0.01, seed=7)
    store = ShardedStore(out_dim=len(fitted.configs), n_shards=4,
                        chunk_rows=128)
    IG.run_skyscraper_fused(fitted, stream, n_cores=N_CORES,
                            plan_days=64.5 * tau / 86400,
                            forecast_mode="uniform", sink=store,
                            sink_stream_id=6)
    T = stream.n_segments
    assert store.n_rows == T and store.n_rows_by_shard[6 % 4] == T
    h = store.host_rows()
    np.testing.assert_array_equal(h["t"], np.arange(T, dtype=np.int32))


def test_pool_tick_sink_sharded():
    from repro.core.api import Skyscraper, SkyscraperPool
    sky = Skyscraper(segment_seconds=2.0, n_categories=3)
    sky.set_resources(num_cores=4)
    sky.register_knob("det", [1, 5, 10])
    segs = list(np.linspace(0, 1, 40))

    def proc(seg, kv):
        return seg, float(np.clip(1 - seg * (1 - 1.0 / kv["det"]), 0, 1))

    sky.fit(segs, proc, plan_segments=16)
    V, S = 4, 3
    store = ShardedStore(out_dim=len(sky.configs), n_shards=S,
                        chunk_rows=32)
    pool = SkyscraperPool(sky, n_streams=V, sink=store)
    for _ in range(5):
        pool.process([0.2, 0.5, 0.7, 0.9])
    assert store.n_rows == 5 * V
    h = store.host_rows()
    off = 0
    for s in range(S):
        blk = slice(off, off + store.n_rows_by_shard[s])
        assert (h["stream_id"][blk] % S == s).all()
        off += store.n_rows_by_shard[s]


def test_sharded_growth_is_chunk_aligned():
    store = ShardedStore(out_dim=2, n_shards=2, chunk_rows=100)
    for i in range(4):
        rows = _random_rows(130, 2, seed=i, t0=130 * i)
        store.append_rows(rows)
    assert store.n_rows == 4 * 130
    assert store.capacity % 100 == 0
    assert store.capacity >= store.n_rows_by_shard.max()


# ---------------------------------------------------------------------------
# partial/merge engine vs the single-device engine
# ---------------------------------------------------------------------------

def test_one_shard_is_bit_exact_with_single_device():
    """The tentpole's degenerate case: n_shards=1 partial+merge IS the
    single-device engine — bit-exact fp32, not just close."""
    single, sharded, _ = _stores(4000, 4, n_shards=1, seed=2)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    nw = windows_for(single, 250)
    plans = [
        (Filter("quality", "ge", 0.4), Filter("stream_id", "ne", 3),
         WindowAgg(window=250, value="on_core_s", agg="mean",
                   num_windows=nw), TopK(7, by="on_core_s")),
        (Filter("buffer_s", "lt", 30.0),
         GroupBy("category", "cloud_core_s", agg="sum", num_groups=4)),
        (Project(("t", "quality", "k")), Filter("quality", "le", 0.9),
         TopK(11, by="quality", largest=False)),
    ]
    for plan in plans:
        table, mask = sharded.query(plan)
        ref, rmask = execute_ref(cols, single.n_rows, plan)
        for k in ref:
            if k == "index":
                continue       # sharded index is a global (shard*cap+i) id
            np.testing.assert_array_equal(np.asarray(table[k]), ref[k],
                                          err_msg=str((k, plan)))
        np.testing.assert_array_equal(np.asarray(mask), rmask)


def test_multi_shard_matches_single_device():
    """Aggregations over shards: counts / integer-valued sums exact,
    float sums within regrouping tolerance, groups and masks identical."""
    single, sharded, _ = _stores(6000, 4, n_shards=4, seed=3)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    for agg in ("sum", "mean", "count", "max", "min"):
        plan = (Filter("quality", "ge", 0.2),
                GroupBy("category", "on_core_s", agg=agg, num_groups=4))
        table, mask = sharded.query(plan)
        ref, rmask = execute_ref(cols, single.n_rows, plan)
        np.testing.assert_array_equal(np.asarray(table["count"]),
                                      ref["count"], err_msg=agg)
        np.testing.assert_array_equal(np.asarray(mask), rmask)
        if agg in ("max", "min", "count"):
            # order-independent: exact across any shard split
            np.testing.assert_array_equal(np.asarray(table["on_core_s"]),
                                          ref["on_core_s"], err_msg=agg)
        else:
            np.testing.assert_allclose(np.asarray(table["on_core_s"]),
                                       ref["on_core_s"], rtol=1e-5,
                                       atol=1e-4, err_msg=agg)
    # integer-valued column sums are exact in f32 no matter the split
    plan = (GroupBy("category", "k", agg="sum", num_groups=4),)
    table, _ = sharded.query(plan)
    ref, _ = execute_ref(cols, single.n_rows, plan)
    np.testing.assert_array_equal(np.asarray(table["k"]), ref["k"])


def test_sharded_row_topk_same_survivors():
    single, sharded, _ = _stores(3000, 3, n_shards=3, seed=4)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    plan = (Filter("stream_id", "eq", 5), TopK(9, by="quality"))
    table, mask = sharded.query(plan)
    ref, rmask = execute_ref(cols, single.n_rows, plan)
    m, rm = np.asarray(mask), rmask
    assert m.sum() == rm.sum()
    np.testing.assert_allclose(np.sort(np.asarray(table["quality"])[m]),
                               np.sort(ref["quality"][rm]), rtol=1e-6)
    # surviving rows are the same multiset of (t, quality) pairs
    got = sorted(zip(np.asarray(table["t"])[m].tolist(),
                     np.asarray(table["quality"])[m].tolist()))
    want = sorted(zip(ref["t"][rm].tolist(), ref["quality"][rm].tolist()))
    assert got == want


def test_sharded_pure_row_plan_concat():
    single, sharded, _ = _stores(1000, 2, n_shards=4, seed=6)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    plan = (Filter("quality", "ge", 0.5), Project(("t", "quality")))
    table, mask = sharded.query(plan)
    ref, rmask = execute_ref(cols, single.n_rows, plan)
    got = to_host(table, mask)
    want = to_host(ref, rmask)
    assert sorted(got["t"].tolist()) == sorted(want["t"].tolist())
    np.testing.assert_allclose(np.sort(got["quality"]),
                               np.sort(want["quality"]), rtol=1e-6)


def test_sharded_multigroupby_window_x_category():
    single, sharded, _ = _stores(5000, 3, n_shards=4, seed=7)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    nw = windows_for(single, 500)
    plan = (Filter("quality", "ge", 0.3),
            MultiGroupBy(keys=("t", "category"), value="on_core_s",
                         agg="mean", nums=(nw, 4), windows=(500, 0)),
            TopK(5, by="on_core_s"))
    table, mask = sharded.query(plan)
    ref, rmask = execute_ref(cols, single.n_rows, plan)
    np.testing.assert_array_equal(np.asarray(mask), rmask)
    np.testing.assert_array_equal(np.asarray(table["count"]),
                                  ref["count"])
    np.testing.assert_array_equal(np.asarray(table["t"]), ref["t"])
    np.testing.assert_array_equal(np.asarray(table["category"]),
                                  ref["category"])
    np.testing.assert_allclose(np.asarray(table["on_core_s"]),
                               ref["on_core_s"], rtol=1e-5, atol=1e-4)


def test_empty_shards_and_empty_result():
    """Streams hashing onto two shards leave the rest empty; predicates
    that kill every row stay well-defined."""
    rows = _random_rows(500, 2, seed=8)
    rows["stream_id"] = (np.arange(500, dtype=np.int32) % 2) * 4  # 0 or 4
    store = ShardedStore(out_dim=2, n_shards=8, chunk_rows=64)
    store.append_rows(rows)
    assert (store.n_rows_by_shard[[0, 4]] > 0).all()
    assert store.n_rows_by_shard[[1, 2, 3, 5, 6, 7]].sum() == 0
    single = SegmentStore(out_dim=2, chunk_rows=64)
    single.append_rows(rows)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    plan = (GroupBy("category", "quality", agg="mean", num_groups=4),)
    table, mask = store.query(plan)
    ref, rmask = execute_ref(cols, 500, plan)
    np.testing.assert_array_equal(np.asarray(table["count"]), ref["count"])
    np.testing.assert_allclose(np.asarray(table["quality"]),
                               ref["quality"], rtol=1e-5, atol=1e-5)
    # nothing matches at all
    dead = (Filter("quality", "gt", 2.0),
            GroupBy("category", "quality", agg="sum", num_groups=4),
            TopK(3, by="quality"))
    _, m = store.query(dead)
    assert not np.asarray(m).any()


def test_sharded_zero_recompiles():
    """Repeated queries at a fixed shard count — new thresholds, new
    rows within capacity — reuse ONE executable per plan shape."""
    store = ShardedStore(out_dim=3, n_shards=4, chunk_rows=4096)
    store.append_rows(_random_rows(10_000, 3, seed=9))
    nw = windows_for(store, 500)
    plan = (Filter("quality", "ge", 0.25),
            WindowAgg(window=500, value="quality", agg="sum",
                      num_windows=nw),
            TopK(10, by="quality"))
    before = Q.sharded_compile_cache_size()
    store.query(plan)
    after_first = Q.sharded_compile_cache_size()
    assert after_first == before + 1
    for thr in (0.1, 0.5, 0.8):
        store.query((Filter("quality", "ge", thr),) + plan[1:])
    rows2 = _random_rows(2_000, 3, seed=10, t0=10_000)
    store.append_rows(rows2)          # fits the reserved capacity
    store.query(plan)
    assert Q.sharded_compile_cache_size() == after_first, "recompiled"


def test_compressed_merge_bounded_error():
    """Opt-in int8-compressed partial-sum merge (embedding columns):
    counts stay exact; sums land within the per-shard quantization
    scale bound (scale = max|partial|/127, one per shard)."""
    single, sharded, _ = _stores(4000, 4, n_shards=4, seed=11)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    plan = (GroupBy("category", "out", agg="sum", num_groups=4),)
    exact, _ = sharded.query(plan)
    comp, _ = sharded.query(plan, compressed=True)
    ref, _ = execute_ref(cols, single.n_rows, plan)
    np.testing.assert_array_equal(np.asarray(comp["count"]), ref["count"])
    np.testing.assert_allclose(np.asarray(exact["out"]), ref["out"],
                               rtol=1e-5, atol=1e-3)
    # per-shard error <= that shard's scale; 4 shards of |sum| <= ~250
    bound = 4 * (np.abs(ref["out"]).max() / 127 + 1e-3)
    err = np.abs(np.asarray(comp["out"]) - ref["out"]).max()
    assert err <= bound, (err, bound)


# ---------------------------------------------------------------------------
# per-shard tiering
# ---------------------------------------------------------------------------

def test_sharded_tier_spill_and_query():
    single, sharded, _ = _stores(4096, 3, n_shards=4, seed=12, chunk=128)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    ts = ShardedTieredStore(sharded, seed=1)
    spilled = ts.spill(keep_hot=300)
    assert spilled > 0 and spilled % (128 * 4) == 0
    assert ts.n_rows == 4096
    np.testing.assert_raises(AssertionError, ts.spill, -1)
    plan = (GroupBy("category", "quality", agg="mean", num_groups=4),)
    table, _ = ts.query(plan)
    ref, _ = execute_ref(cols, 4096, plan)
    np.testing.assert_array_equal(np.asarray(table["count"]), ref["count"])
    tol = ts.max_cold_scale() + 1e-4
    np.testing.assert_allclose(np.asarray(table["quality"]),
                               ref["quality"], atol=tol)
    # memoized combined view across repeat queries; refreshed by appends
    c1, _ = ts.shard_source()
    c2, _ = ts.shard_source()
    assert c1 is c2
    ts.hot.append_rows(_random_rows(8, 3, seed=13, t0=5000))
    c3, _ = ts.shard_source()
    assert c3 is not c1 and ts.n_rows == 4096 + 8


def test_sharded_tier_ragged_spill_with_empty_shards():
    """Shards that own no streams (n_streams < n_shards, or hash gaps)
    must never block the populated shards from spilling: depths are
    ragged per shard. Regression test for the min-across-shards no-op."""
    n = 2000
    rows = _random_rows(n, 2, seed=31)
    rows["stream_id"] = ((np.arange(n, dtype=np.int32) % 2) * 4)  # 0 / 4
    store = ShardedStore(out_dim=2, n_shards=8, chunk_rows=256)
    store.append_rows(rows)
    single = SegmentStore(out_dim=2, chunk_rows=256)
    single.append_rows(rows)
    cols = {k: np.asarray(v) for k, v in single.columns.items()}
    ts = ShardedTieredStore(store, seed=2)
    spilled = ts.spill(keep_hot=0)
    assert spilled == 2 * (1000 // 256) * 256      # both live shards
    assert ts.n_cold_by_shard[[0, 4]].sum() == spilled
    assert ts.n_cold_by_shard[[1, 2, 3, 5, 6, 7]].sum() == 0
    assert ts.n_rows == n
    # a second, imbalanced spill: only shard 0 receives new rows
    more = _random_rows(600, 2, seed=32, t0=n)
    more["stream_id"] = np.zeros(600, np.int32)
    ts.hot.append_rows(more)
    # shard 0 now holds 232 + 600 = 832 hot rows -> spills 3 chunks;
    # shard 4 holds 232 (< one chunk) -> spills nothing
    spilled2 = ts.spill(keep_hot=0)
    assert spilled2 == (832 // 256) * 256
    assert ts.n_cold_by_shard[0] == 768 + 768
    assert ts.n_cold_by_shard[4] == 768
    # the deep shard's write window must be fully reserved: a shallow
    # shard's junk block at a clamped offset would otherwise overwrite
    # the deep shard's valid cold rows (dynamic_update_slice clamps
    # out-of-range starts backward instead of erroring)
    assert ts.cold_capacity >= ts.n_cold_by_shard.max()
    plan = (GroupBy("category", "quality", agg="mean", num_groups=4),)
    table, _ = ts.query(plan)
    # counts must stay exact across BOTH tiers despite ragged depths
    got_cnt = np.asarray(table["count"]).copy()
    ref2, _ = execute_ref({k: np.concatenate([cols[k][:n],
                                              np.asarray(more[k])])
                           for k in cols}, n + 600, plan)
    np.testing.assert_array_equal(got_cnt, ref2["count"])
    np.testing.assert_allclose(np.asarray(table["quality"]),
                               ref2["quality"],
                               atol=ts.max_cold_scale() + 1e-4)


def test_sharded_tier_shallow_spill_never_clamps_into_deep_shard():
    """Regression: when one shard's cold tier sits exactly at capacity
    and a LATER spill only moves rows on a shallower shard, the deep
    shard's junk write window must still be inside capacity —
    ``dynamic_update_slice`` clamps an out-of-range start backward, so
    an unreserved tail would silently overwrite valid cold rows."""
    chunk = 256
    store = ShardedStore(out_dim=2, n_shards=2, chunk_rows=chunk)
    ts = ShardedTieredStore(store, seed=3)
    all_rows = []

    def add(n, stream, t0, seed):
        rows = _random_rows(n, 2, seed=seed, t0=t0)
        rows["stream_id"] = np.full(n, stream, np.int32)
        store.append_rows(rows)
        all_rows.append(rows)

    # 8-chunk spills land exactly ON the bucketed capacity ladder
    # (chunk * 2^j), so the deep shard's cold tier sits EXACTLY at
    # capacity — the tight layout this regression needs
    add(8 * chunk, 0, 0, 41)            # shard 0 deep
    add(100, 1, 8 * chunk, 42)
    assert ts.spill(keep_hot=0) == 8 * chunk
    add(8 * chunk, 0, 8 * chunk + 100, 43)   # shard 0 deeper: at capacity
    assert ts.spill(keep_hot=0) == 8 * chunk
    assert ts.n_cold_by_shard[0] == ts.cold_capacity == 16 * chunk
    add(chunk, 1, 17 * chunk, 44)       # now ONLY shard 1 can spill
    assert ts.spill(keep_hot=0) == chunk
    assert ts.cold_capacity >= ts.n_cold_by_shard[0] + chunk
    # shard 0's cold rows survived: two-tier counts match the reference
    n_all = sum(len(r["t"]) for r in all_rows)
    cols = {k: np.concatenate([np.asarray(r[k]) for r in all_rows])
            for k in all_rows[0]}
    plan = (GroupBy("category", "quality", agg="count", num_groups=4),)
    table, _ = ts.query(plan)
    ref, _ = execute_ref(cols, n_all, plan)
    np.testing.assert_array_equal(np.asarray(table["count"]), ref["count"])
    np.testing.assert_allclose(np.asarray(table["quality"]),
                               ref["quality"],
                               atol=ts.max_cold_scale() + 1e-4)


def test_mesh_mode_active_when_devices_exist():
    """On the forced-8-device CI leg the stores must actually be on a
    mesh (ONE shard_map dispatch, collective merge) — on a 1-device
    host they must fall back to the stacked layout."""
    store = ShardedStore(out_dim=2, n_shards=2, chunk_rows=64)
    if jax.device_count() >= 2:
        assert store.mesh is not None
        assert set(store.mesh.axis_names) == {"shard"}
        store.append_rows(_random_rows(100, 2, seed=14))
        devs = {d for v in store.columns.values()
                for d in v.sharding.device_set}
        assert len(devs) == 2, "columns not spread across shard devices"
    else:
        assert store.mesh is None

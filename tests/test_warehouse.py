"""The Load subsystem: device-resident columnar store, compiled query
plans vs the numpy reference, zero-recompile guarantees, hot/cold
tiering, and checkpoint persistence."""

import jax
import numpy as np

from benchmarks.fused_ingest_bench import _synthetic_fitted
from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.data.stream import generate
from repro.warehouse import (Filter, GroupBy, MultiGroupBy, Project,
                             SegmentStore, TieredStore, TopK, WindowAgg,
                             execute, execute_ref, load_warehouse,
                             save_warehouse, to_host, windows_for)
from repro.warehouse import query as Q

N_CORES = 8  # matches the profile baked into _synthetic_fitted


def _random_rows(n, D, seed=0, t0=0):
    rng = np.random.default_rng(seed)
    return {
        "stream_id": rng.integers(0, 4, n).astype(np.int32),
        "t": (t0 + np.arange(n)).astype(np.int32),
        "category": rng.integers(0, 4, n).astype(np.int32),
        "k": rng.integers(0, D, n).astype(np.int32),
        "quality": rng.random(n).astype(np.float32),
        "on_core_s": (rng.random(n) * 20).astype(np.float32),
        "cloud_core_s": (rng.random(n) * 5).astype(np.float32),
        "buffer_s": (rng.random(n) * 40).astype(np.float32),
        "out": rng.random((n, D)).astype(np.float32),
    }


def _host_cols(store):
    return {k: np.asarray(v) for k, v in store.columns.items()}


# ---------------------------------------------------------------------------
# ingestion
# ---------------------------------------------------------------------------

def test_fused_sink_matches_run_traces():
    """A full fused run lands in the store with exactly the traces the
    RunResult reports, and the output column carries the (T, K) quality
    vectors. Everything in the store is a device array."""
    fitted = _synthetic_fitted()
    stream = generate(COVID, days=0.02, seed=3)            # T = 864
    T = stream.n_segments
    K = len(fitted.configs)
    tau = fitted.workload.segment_seconds
    store = SegmentStore(out_dim=K, chunk_rows=512)
    res = IG.run_skyscraper_fused(
        fitted, stream, n_cores=N_CORES, cloud_budget_core_s=5_000.0,
        plan_days=64.5 * tau / 86400, forecast_mode="model", sink=store)
    assert store.n_rows == T and store.t_max == T - 1
    assert all(isinstance(v, jax.Array) for v in store.columns.values())
    h = store.host_rows()
    np.testing.assert_array_equal(h["k"], res.k_trace)
    np.testing.assert_array_equal(h["category"], res.c_trace)
    np.testing.assert_allclose(h["buffer_s"], res.buffer_trace, rtol=1e-6)
    np.testing.assert_allclose(h["quality"].sum(), res.quality_sum,
                               rtol=1e-5)
    quals = np.asarray(stream.quality(fitted.power, seed=0), np.float32)
    np.testing.assert_array_equal(h["out"], quals[:T])
    np.testing.assert_array_equal(h["stream_id"], np.zeros(T, np.int32))
    np.testing.assert_array_equal(h["t"], np.arange(T, dtype=np.int32))


def test_sink_appends_across_runs_and_grows():
    """Two runs append (chunked growth), each under its own stream id."""
    fitted = _synthetic_fitted()
    K = len(fitted.configs)
    tau = fitted.workload.segment_seconds
    store = SegmentStore(out_dim=K, chunk_rows=500)
    kw = dict(n_cores=N_CORES, plan_days=64.5 * tau / 86400,
              forecast_mode="uniform")
    s0 = generate(COVID, days=0.02, seed=3)
    s1 = generate(COVID, days=0.01, seed=4)
    IG.run_skyscraper_fused(fitted, s0, sink=store, sink_stream_id=0, **kw)
    IG.run_skyscraper_fused(fitted, s1, sink=store, sink_stream_id=7, **kw)
    T0, T1 = s0.n_segments, s1.n_segments
    assert store.n_rows == T0 + T1
    assert store.capacity % 500 == 0 and store.capacity >= T0 + T1
    h = store.host_rows()
    np.testing.assert_array_equal(
        h["stream_id"], np.r_[np.zeros(T0, np.int32),
                              np.full(T1, 7, np.int32)])
    np.testing.assert_array_equal(h["t"][T0:], np.arange(T1))


def test_multi_sink_stream_major_rows():
    fitted = _synthetic_fitted()
    K = len(fitted.configs)
    tau = fitted.workload.segment_seconds
    V = 3
    streams = [generate(COVID, days=0.01, seed=s) for s in range(V)]
    T = min(s.n_segments for s in streams)
    store = SegmentStore(out_dim=K, chunk_rows=512)
    IG.run_skyscraper_multi([fitted] * V, streams, n_cores_each=N_CORES,
                            cloud_budget_core_s=900.0,
                            plan_days=64 * tau / 86400, sink=store,
                            sink_stream_base=10)
    assert store.n_rows == V * T
    h = store.host_rows()
    np.testing.assert_array_equal(
        h["stream_id"], np.repeat(np.arange(10, 10 + V, dtype=np.int32), T))
    np.testing.assert_array_equal(h["t"], np.tile(np.arange(T), V))
    # padding never lands: every row's quality is a real measured value
    assert h["quality"].min() >= 0.0 and store.t_max == T - 1


def test_pool_sink_one_row_per_stream_per_tick():
    from repro.core.api import Skyscraper, SkyscraperPool
    sky = Skyscraper(segment_seconds=2.0, n_categories=3)
    sky.set_resources(num_cores=4)
    sky.register_knob("det", [1, 5, 10])
    segs = list(np.linspace(0, 1, 40))

    def proc(seg, kv):
        return seg, float(np.clip(1 - seg * (1 - 1.0 / kv["det"]), 0, 1))

    sky.fit(segs, proc, plan_segments=16)
    V = 4
    store = SegmentStore(out_dim=len(sky.configs), chunk_rows=64)
    pool = SkyscraperPool(sky, n_streams=V, sink=store)
    n_ticks = 6
    for _ in range(n_ticks):
        pool.process([0.2, 0.5, 0.7, 0.9])
    assert store.n_rows == V * n_ticks
    h = store.host_rows()
    np.testing.assert_array_equal(h["t"], np.repeat(np.arange(n_ticks), V))
    np.testing.assert_array_equal(h["stream_id"], np.tile(np.arange(V),
                                                          n_ticks))
    # the quality column is the TRANSFORM-measured quality, and the out
    # column carries it one-hot at the chosen config
    k = h["k"]
    np.testing.assert_allclose(h["out"][np.arange(len(k)), k], h["quality"],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# query engine vs the numpy reference
# ---------------------------------------------------------------------------

def test_query_filter_window_topk_exact():
    store = SegmentStore(out_dim=4, chunk_rows=2048)
    store.append_rows(_random_rows(6000, 4, seed=1))
    nw = windows_for(store, 250)
    plan = (Filter("quality", "ge", 0.4), Filter("stream_id", "ne", 3),
            WindowAgg(window=250, value="on_core_s", agg="mean",
                      num_windows=nw),
            TopK(7, by="on_core_s"))
    table, mask = execute(store, plan)
    ref, rmask = execute_ref(_host_cols(store), store.n_rows, plan)
    # same fp32 row-order summation on both sides -> bit-exact
    np.testing.assert_array_equal(np.asarray(table["on_core_s"]),
                                  ref["on_core_s"])
    np.testing.assert_array_equal(np.asarray(table["window"]),
                                  ref["window"])
    np.testing.assert_array_equal(np.asarray(mask), rmask)


def test_query_groupby_aggs_exact():
    store = SegmentStore(out_dim=4, chunk_rows=2048)
    store.append_rows(_random_rows(5000, 4, seed=2))
    cols = _host_cols(store)
    for agg in ("sum", "mean", "count", "max", "min"):
        plan = (Filter("buffer_s", "lt", 30.0),
                GroupBy("category", "cloud_core_s", agg=agg, num_groups=4))
        table, mask = execute(store, plan)
        ref, rmask = execute_ref(cols, store.n_rows, plan)
        np.testing.assert_array_equal(np.asarray(table["cloud_core_s"]),
                                      ref["cloud_core_s"], err_msg=agg)
        np.testing.assert_array_equal(np.asarray(table["count"]),
                                      ref["count"])
        np.testing.assert_array_equal(np.asarray(mask), rmask)


def test_query_project_and_row_topk():
    store = SegmentStore(out_dim=4, chunk_rows=2048)
    store.append_rows(_random_rows(3000, 4, seed=5))
    plan = (Project(("t", "quality", "k")),
            Filter("quality", "le", 0.9),
            TopK(11, by="quality", largest=False))
    table, mask = execute(store, plan)
    ref, rmask = execute_ref(_host_cols(store), store.n_rows, plan)
    assert set(table) == {"t", "quality", "k", "index"}
    np.testing.assert_array_equal(np.asarray(table["index"]), ref["index"])
    np.testing.assert_array_equal(np.asarray(table["quality"]),
                                  ref["quality"])
    # to_host compacts to the valid rows only
    host = to_host(table, mask)
    assert len(host["quality"]) == int(np.asarray(mask).sum())


def test_query_multigroupby_window_x_category_exact():
    """Multi-key GroupBy (time window x content category) fuses the key
    tuple into ONE segment_sum pass and matches the numpy reference
    bit-exact; decoded key columns enumerate the full cross product."""
    store = SegmentStore(out_dim=3, chunk_rows=2048)
    store.append_rows(_random_rows(5000, 3, seed=21))
    cols = _host_cols(store)
    nw = windows_for(store, 400)
    for agg in ("sum", "mean", "count", "max", "min"):
        plan = (Filter("quality", "ge", 0.3),
                MultiGroupBy(keys=("t", "category"), value="on_core_s",
                             agg=agg, nums=(nw, 4), windows=(400, 0)))
        table, mask = execute(store, plan)
        ref, rmask = execute_ref(cols, store.n_rows, plan)
        np.testing.assert_array_equal(np.asarray(table["on_core_s"]),
                                      ref["on_core_s"], err_msg=agg)
        np.testing.assert_array_equal(np.asarray(table["count"]),
                                      ref["count"])
        np.testing.assert_array_equal(np.asarray(table["t"]), ref["t"])
        np.testing.assert_array_equal(np.asarray(table["category"]),
                                      ref["category"])
        np.testing.assert_array_equal(np.asarray(mask), rmask)
    # three keys, no windowing, composed with a TopK over the result
    plan = (MultiGroupBy(keys=("stream_id", "category", "k"),
                         value="quality", agg="sum", nums=(4, 4, 3)),
            TopK(6, by="quality"))
    table, mask = execute(store, plan)
    ref, rmask = execute_ref(cols, store.n_rows, plan)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(table[k]), ref[k],
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(mask), rmask)
    # the fused encoding equals an equivalent single-key GroupBy over a
    # hand-fused id column: window*4 + category
    h = _host_cols(store)
    fused_ids = (np.asarray(h["t"]) // 400) * 4 + np.asarray(h["category"])
    plan_m = (MultiGroupBy(keys=("t", "category"), value="quality",
                           agg="sum", nums=(nw, 4), windows=(400, 0)),)
    tm, _ = execute(store, plan_m)
    hand = {**h, "fused": fused_ids.astype(np.int32)}
    rg, _ = execute_ref(hand, store.n_rows,
                        (GroupBy("fused", "quality", agg="sum",
                                 num_groups=nw * 4),))
    np.testing.assert_array_equal(np.asarray(tm["quality"]), rg["quality"])


def test_query_groupby_wide_out_column():
    """GroupBy over the (row, D) embedding column aggregates per lane
    and matches the reference bit-exact (sum/mean) on one shard."""
    store = SegmentStore(out_dim=4, chunk_rows=1024)
    store.append_rows(_random_rows(3000, 4, seed=22))
    cols = _host_cols(store)
    for agg in ("sum", "mean"):
        plan = (Filter("quality", "ge", 0.5),
                GroupBy("category", "out", agg=agg, num_groups=4))
        table, mask = execute(store, plan)
        ref, rmask = execute_ref(cols, store.n_rows, plan)
        assert np.asarray(table["out"]).shape == (4, 4)
        np.testing.assert_array_equal(np.asarray(table["out"]),
                                      ref["out"], err_msg=agg)
        np.testing.assert_array_equal(np.asarray(mask), rmask)


def test_query_int_filter_exact_past_f32_precision():
    """Integer columns filter exactly even past 2^24 (where a float32
    cast would collapse neighboring values) — the append-only ``t``
    column crosses that after ~388 days of 2 s segments."""
    n = 64
    base = 2 ** 24
    rows = _random_rows(n, 2, seed=9)
    rows["t"] = (base + np.arange(n)).astype(np.int32)
    store = SegmentStore(out_dim=2, chunk_rows=64)
    store.append_rows(rows)
    for op, want in (("ge", n - 1), ("gt", n - 2), ("le", 2), ("lt", 1),
                     ("eq", 1), ("ne", n - 1)):
        plan = (Filter("t", op, float(base + 1)),)
        _, mask = execute(store, plan)
        assert int(np.asarray(mask).sum()) == want, (op, want)
        _, rmask = execute_ref(_host_cols(store), n, plan)
        assert int(rmask.sum()) == want, (op, want)
    # non-integral thresholds stay well-defined too
    _, m = execute(store, (Filter("t", "ge", base + 0.5),))
    _, rm = execute_ref(_host_cols(store), n,
                        (Filter("t", "ge", base + 0.5),))
    np.testing.assert_array_equal(np.asarray(m), rm)
    # extreme thresholds clamp without int32 wraparound
    for op, v in (("lt", float(-2 ** 31)), ("gt", float(2 ** 31))):
        _, m = execute(store, (Filter("t", op, v),))
        _, rm = execute_ref(_host_cols(store), n, (Filter("t", op, v),))
        assert not np.asarray(m).any()
        np.testing.assert_array_equal(np.asarray(m), rm)
    # infinite thresholds degenerate to all/none, like the reference
    for op, v, cnt in (("lt", float("inf"), n), ("ge", float("inf"), 0),
                       ("ge", float("-inf"), n), ("lt", float("-inf"), 0)):
        _, m = execute(store, (Filter("t", op, v),))
        _, rm = execute_ref(_host_cols(store), n, (Filter("t", op, v),))
        assert int(np.asarray(m).sum()) == cnt, (op, v)
        np.testing.assert_array_equal(np.asarray(m), rm)


def test_query_empty_result_and_sparse_groups():
    """Predicates that kill every row, and group ids beyond the static
    count, stay well-defined (clip + masked no-op semantics)."""
    store = SegmentStore(out_dim=2, chunk_rows=256)
    rows = _random_rows(400, 2, seed=6)
    rows["category"] = np.full(400, 9, np.int32)     # clips into last group
    store.append_rows(rows)
    plan = (Filter("quality", "gt", 2.0),            # nothing matches
            GroupBy("category", "quality", agg="mean", num_groups=4),
            TopK(3, by="quality"))
    table, mask = execute(store, plan)
    ref, rmask = execute_ref(_host_cols(store), store.n_rows, plan)
    assert not np.asarray(mask).any() and not rmask.any()
    np.testing.assert_array_equal(np.asarray(table["quality"]),
                                  ref["quality"])


def test_query_100k_single_dispatch_zero_recompiles():
    """The acceptance-criteria shape: Filter -> WindowAgg -> TopK over
    >=100k stored segments is ONE compiled dispatch, re-querying with
    new filter values / more rows reuses the executable, and the answer
    matches the numpy reference exactly."""
    store = SegmentStore(out_dim=4, chunk_rows=60_000)
    store.append_rows(_random_rows(100_000, 4, seed=7))
    nw = windows_for(store, 500)
    plan = (Filter("quality", "ge", 0.25),
            WindowAgg(window=500, value="quality", agg="sum",
                      num_windows=nw),
            TopK(10, by="quality"))
    before = Q.compile_cache_size()
    table, mask = execute(store, plan)
    after_first = Q.compile_cache_size()
    assert after_first == before + 1        # ONE new executable, total
    for thr in (0.1, 0.5, 0.8):
        plan_i = (Filter("quality", "ge", thr),) + plan[1:]
        table_i, mask_i = execute(store, plan_i)
        ref_i, rmask_i = execute_ref(_host_cols(store), store.n_rows,
                                     plan_i)
        np.testing.assert_array_equal(np.asarray(table_i["quality"]),
                                      ref_i["quality"])
        np.testing.assert_array_equal(np.asarray(mask_i), rmask_i)
    # appending within the reserved capacity keeps the same executable
    store.append_rows(_random_rows(10_000, 4, seed=8, t0=100_000))
    execute(store, plan)
    assert Q.compile_cache_size() == after_first, "query recompiled"


# ---------------------------------------------------------------------------
# tiering + persistence
# ---------------------------------------------------------------------------

def _tiered_fixture(n=4096, chunk=512, seed=11):
    store = SegmentStore(out_dim=3, chunk_rows=chunk)
    store.append_rows(_random_rows(n, 3, seed=seed))
    full_ref = _host_cols(store)      # fp32 snapshot before quantization
    ts = TieredStore(store, seed=1)
    spilled = ts.spill(keep_hot=n // 2)
    assert spilled > 0 and spilled % chunk == 0
    assert ts.n_rows == n and ts.hot.n_rows == n - spilled
    return ts, full_ref, n, spilled


def test_tiered_query_within_quantization_tolerance():
    ts, full_ref, n, spilled = _tiered_fixture()
    plan = (GroupBy("category", "quality", agg="mean", num_groups=4),)
    table, mask = ts.query(plan)
    ref, _ = execute_ref(full_ref, n, plan)
    # per-element cold error <= per-chunk scale (stochastic rounding),
    # and means only shrink it; counts are integer-column exact
    tol = ts.max_cold_scale() + 1e-6
    np.testing.assert_allclose(np.asarray(table["quality"]),
                               ref["quality"], atol=tol)
    np.testing.assert_array_equal(np.asarray(table["count"]), ref["count"])
    # hot rows stayed fp32: querying only recent times is exact
    t_lo = float(np.sort(full_ref["t"])[spilled])
    plan_hot = (Filter("t", "ge", t_lo),
                GroupBy("category", "quality", agg="sum", num_groups=4))
    table_h, _ = ts.query(plan_hot)
    ref_h, _ = execute_ref(full_ref, n, plan_hot)
    np.testing.assert_array_equal(np.asarray(table_h["quality"]),
                                  ref_h["quality"])


def test_tiered_spill_guards_and_memoized_view():
    ts, _, n, _ = _tiered_fixture(seed=17)
    np.testing.assert_raises(AssertionError, ts.spill, -1)
    # spilling everything never quantizes capacity padding: only whole
    # chunks of LIVE rows move, and no row is lost or invented
    ts.spill(0)
    assert ts.n_rows == n
    assert ts.n_cold % ts.hot.chunk_rows == 0 and ts.n_cold <= n
    # repeat queries reuse the memoized combined view...
    cols1, _ = ts.materialize()
    cols2, _ = ts.materialize()
    assert cols1 is cols2
    # ...and an append refreshes it
    ts.hot.append_rows(_random_rows(8, 3, seed=18, t0=n))
    cols3, n_tot = ts.materialize()
    assert cols3 is not cols1 and n_tot == n + 8


def test_warehouse_ckpt_roundtrip_bit_exact(tmp_path):
    ts, full_ref, n, _ = _tiered_fixture(seed=13)
    plan = (Filter("quality", "ge", 0.5),
            WindowAgg(window=256, value="quality", agg="mean",
                      num_windows=windows_for(ts, 256)),
            TopK(4, by="quality"))
    want_table, want_mask = ts.query(plan)
    path = str(tmp_path / "warehouse.rsk")
    save_warehouse(path, ts)
    back = load_warehouse(path)
    # hot tier restores bit-exact; cold tier's int8 codes + scales too
    for k, v in ts.hot.columns.items():
        np.testing.assert_array_equal(np.asarray(back.hot.columns[k]),
                                      np.asarray(v))
        assert back.hot.columns[k].dtype == v.dtype
    for k in ts.cold_q:
        np.testing.assert_array_equal(np.asarray(back.cold_q[k]),
                                      np.asarray(ts.cold_q[k]))
        np.testing.assert_array_equal(np.asarray(back.cold_scales[k]),
                                      np.asarray(ts.cold_scales[k]))
    assert (back.n_cold, back.hot.n_rows, back.hot.t_max,
            back.hot.chunk_rows) == (ts.n_cold, ts.hot.n_rows,
                                     ts.hot.t_max, ts.hot.chunk_rows)
    got_table, got_mask = back.query(plan)
    for k in want_table:
        np.testing.assert_array_equal(np.asarray(got_table[k]),
                                      np.asarray(want_table[k]))
    np.testing.assert_array_equal(np.asarray(got_mask),
                                  np.asarray(want_mask))


def test_store_is_a_pytree():
    store = SegmentStore(out_dim=2, chunk_rows=128)
    store.append_rows(_random_rows(100, 2, seed=3))
    leaves, treedef = jax.tree.flatten(store)
    assert all(isinstance(x, jax.Array) for x in leaves)
    back = jax.tree.unflatten(treedef, leaves)
    assert isinstance(back, SegmentStore)
    assert back.n_rows == store.n_rows and back.t_max == store.t_max
    # a store passes through jit like any other pytree
    total = jax.jit(lambda s: s.columns["quality"].sum())(store)
    np.testing.assert_allclose(
        float(total), float(store.columns["quality"].sum()), rtol=1e-6)

"""Top-level system behaviour: the V-ETL definition's two constraints
(Eq. 1 throughput, budget) hold simultaneously on every workload."""
import numpy as np
import pytest

from repro.configs.workloads import WORKLOADS
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.data.stream import generate


@pytest.mark.parametrize("wname", sorted(WORKLOADS))
def test_vetl_constraints_hold(wname):
    w = WORKLOADS[wname]
    f = fit(w, n_cores=16, days_unlabeled=3.0,
            n_categories=4 if wname in ("covid", "mot") else 5, seed=0)
    s = generate(w, days=0.5, seed=11)
    res = IG.run_skyscraper(f, s, n_cores=16, cloud_budget_core_s=5_000.0,
                            buffer_gb=1.0, plan_days=0.1)
    cap_s = 1.0 * 1e9 / 90e3
    assert res.buffer_peak_s <= cap_s + 1e-3          # Eq. 1
    assert res.cloud_core_s <= 5_000.0 + 1e-3         # budget
    assert not res.overflow
    assert res.quality_pct > 50.0

#!/usr/bin/env python
"""Doc-drift gate: every path and CLI flag the docs promise must exist.

Scans ``README.md`` and ``docs/*.md`` and fails when:

1. a referenced repo path (``src/...``, ``benchmarks/...``,
   ``examples/...``, ``scripts/...``, ``tests/...``, ``docs/...``, or a
   committed root file like ``ANALYSIS.json``) does not exist;
2. a fenced ``bash`` command documents a ``--flag`` for a script or
   ``python -m`` module whose source never mentions that flag
   (e.g. the classic ``--compare SOME_OLD_BASELINE.json`` drift).

Run from anywhere::

    python scripts/check_docs.py [-q]

Exit 0 = docs match the tree. Wired into ``scripts/tier1.sh`` and CI so
interface renames fail before a reader trips over them.
"""
from __future__ import annotations

import glob
import itertools
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DOC_FILES = ["README.md"] + sorted(glob.glob(os.path.join(ROOT, "docs",
                                                          "*.md")))

# path-like tokens rooted at a known top-level dir (brace groups expand)
_PATH_RE = re.compile(
    r"\b(?:src|docs|benchmarks|examples|scripts|tests)/"
    r"[\w./{},-]*[\w}/]")
# committed root-level artifacts; *_NEW/*_OLD/*_TRACE/PR-tagged names are
# documented placeholders, not promises
_ROOT_FILE_RE = re.compile(r"(?<![/\w])([A-Z][A-Z_0-9]*\.(?:md|json))\b")
_PLACEHOLDER = re.compile(r"NEW|OLD|TRACE|OUT|PR\d")

_FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.S)
_FLAG_RE = re.compile(r"(--[A-Za-z][\w-]*)")


def _expand_braces(tok: str):
    m = re.search(r"\{([^{}]*)\}", tok)
    if not m:
        return [tok]
    out = []
    for part in m.group(1).split(","):
        out.extend(_expand_braces(tok[:m.start()] + part + tok[m.end():]))
    return out


def _iter_paths(text):
    for m in _PATH_RE.finditer(text):
        for tok in _expand_braces(m.group(0)):
            yield tok.rstrip("/.")
    for m in _ROOT_FILE_RE.finditer(text):
        if not _PLACEHOLDER.search(m.group(1)):
            yield m.group(1)


def _module_sources(dotted: str):
    """Source files implementing ``python -m <dotted>`` (package dir
    py files, or the module file), [] if the module is missing."""
    base = os.path.join(ROOT, "src", *dotted.split("."))
    if os.path.isdir(base):
        return glob.glob(os.path.join(base, "*.py"))
    if os.path.isfile(base + ".py"):
        return [base + ".py"]
    return []


def _script_sources(path: str):
    full = os.path.join(ROOT, path)
    return [full] if os.path.isfile(full) else []


def _command_targets(line: str):
    """(target name, source files) pairs for each runnable a command
    line references — ``python -m mod``, ``python path.py``, ``*.sh``."""
    toks = line.split()
    for i, tok in enumerate(toks):
        if tok == "-m" and i + 1 < len(toks) \
                and toks[i + 1].startswith("repro"):
            # only first-party modules; pytest etc. live off-tree
            yield f"-m {toks[i + 1]}", _module_sources(toks[i + 1])
        elif tok.endswith(".py") and "/" in tok:
            yield tok, _script_sources(tok)
        elif tok.endswith(".sh"):
            yield tok, _script_sources(tok)


def check(verbose: bool = True):
    problems = []
    for doc in DOC_FILES:
        rel = os.path.relpath(doc, ROOT) if os.path.isabs(doc) else doc
        text = open(os.path.join(ROOT, rel)).read()

        here = os.path.dirname(os.path.join(ROOT, rel))
        for path in sorted(set(_iter_paths(text))):
            if not (os.path.exists(os.path.join(ROOT, path))
                    or os.path.exists(os.path.join(here, path))):
                problems.append(f"{rel}: missing path `{path}`")

        for lang, body in _FENCE_RE.findall(text):
            if lang not in ("bash", "sh", "shell", "console"):
                continue
            # join line continuations so flags stay with their command
            body = body.replace("\\\n", " ")
            for line in body.splitlines():
                line = line.split("#", 1)[0]
                targets = list(_command_targets(line))
                if not targets:
                    continue
                flags = _FLAG_RE.findall(line)
                srcs = list(itertools.chain.from_iterable(
                    s for _, s in targets))
                names = ", ".join(t for t, _ in targets)
                missing_target = [t for t, s in targets if not s]
                for t in missing_target:
                    problems.append(f"{rel}: command references missing "
                                    f"runnable `{t}`: {line.strip()}")
                if not srcs:
                    continue
                blob = "".join(open(s).read() for s in srcs)
                for flag in flags:
                    if flag not in blob:
                        problems.append(
                            f"{rel}: flag `{flag}` not found in source "
                            f"of {names}: {line.strip()}")
    if problems:
        for p in problems:
            print(f"DOC DRIFT: {p}", file=sys.stderr)
        return 1
    if verbose:
        n = len(DOC_FILES)
        print(f"check_docs: {n} docs clean (paths + fenced command flags)")
    return 0


if __name__ == "__main__":
    sys.exit(check(verbose="-q" not in sys.argv[1:]))

#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite on CPU.
#
#   scripts/tier1.sh [--bench-smoke] [extra pytest args...]
#
# --bench-smoke additionally runs the fused-ingest, warehouse, and
# multi-stream benchmarks in their --tiny configurations after the
# tests, so none of the benchmark entry points can silently rot.
#
# Honors an existing XLA_FLAGS; otherwise forces a single host device so
# smoke tests see a deterministic topology (the sharding tests fork their
# own 8-device subprocesses).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

BENCH_SMOKE=0
args=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  else
    args+=("$a")
  fi
done

python -m pytest -x -q "${args[@]+"${args[@]}"}"

if [[ "$BENCH_SMOKE" == "1" ]]; then
  for bench in fused_ingest_bench warehouse_bench multi_stream_bench; do
    echo "== bench smoke: ${bench} --tiny =="
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
      python "benchmarks/${bench}.py" --tiny
  done
fi

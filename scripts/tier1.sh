#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite on CPU.
#
#   scripts/tier1.sh [--bench-smoke] [extra pytest args...]
#
# --bench-smoke additionally runs the fused-ingest benchmark in its
# --tiny configuration after the tests, so the benchmark entry point
# cannot silently rot.
#
# Honors an existing XLA_FLAGS; otherwise forces a single host device so
# smoke tests see a deterministic topology (the sharding tests fork their
# own 8-device subprocesses).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

BENCH_SMOKE=0
args=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  else
    args+=("$a")
  fi
done

python -m pytest -x -q "${args[@]+"${args[@]}"}"

if [[ "$BENCH_SMOKE" == "1" ]]; then
  echo "== bench smoke: fused_ingest_bench --tiny =="
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fused_ingest_bench.py --tiny
fi

#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite on CPU.
#
#   scripts/tier1.sh [--bench-smoke] [extra pytest args...]
#
# Legs:
#   0. doc drift: scripts/check_docs.py (README + docs/ paths and flags);
#   1. the full suite on the default (single-device) topology;
#   2. static program audit + obs dispatch-trace smoke vs the committed
#      ANALYSIS.json / OBS.json baselines;
#   3. the sharded-warehouse suite re-run under a forced 8-device host
#      platform, where ShardedStore gets a real ('shard',) mesh and
#      queries/ingests execute as ONE shard_map dispatch with collective
#      merges (on one device the same tests cover the stacked fallback),
#      plus the audit and obs smoke on that topology.
#
# --bench-smoke additionally runs the fused-ingest, warehouse, sharded-
# warehouse, standing-query, and multi-stream benchmarks in their
# --tiny configurations after the tests, so none of the benchmark entry
# points can silently rot.
#
# Honors an existing XLA_FLAGS; otherwise forces a single host device so
# smoke tests see a deterministic topology (the sharding tests fork their
# own 8-device subprocesses).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

BENCH_SMOKE=0
args=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  else
    args+=("$a")
  fi
done

python -m pytest -x -q "${args[@]+"${args[@]}"}"

echo "== doc drift check (README + docs/ vs the tree) =="
python scripts/check_docs.py

echo "== static program audit (jaxpr/HLO/source) vs ANALYSIS.json =="
# every registered engine must audit clean, and no engine's dispatch
# count may grow vs the committed baseline (generated at 1 device; the
# compare skips dispatch deltas automatically on other topologies)
AUDIT_OUT="$(mktemp)"
python -m repro.analysis --json "$AUDIT_OUT" --compare ANALYSIS.json
rm -f "$AUDIT_OUT"

echo "== obs dispatch-trace smoke vs OBS.json =="
# trace every registry engine (1 warm rep), validate the Chrome trace,
# and gate vs the committed baseline: any new executable / recompile /
# host transfer fails; span-time floors only gate above the noise floor
OBS_OUT="$(mktemp)"
OBS_TRACE="$(mktemp)"
python -m repro.obs --smoke --json "$OBS_OUT" --trace "$OBS_TRACE" \
  --compare OBS.json
rm -f "$OBS_OUT" "$OBS_TRACE"

echo "== sharded warehouse suite on 8 forced host devices =="
# appended last: XLA flag parsing is last-wins, so this overrides any
# device-count already in XLA_FLAGS (e.g. CI's =1) for this leg only
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
  python -m pytest -x -q tests/test_sharded_warehouse.py \
    tests/test_sharded_properties.py tests/test_warehouse_agg_pallas.py \
    tests/test_standing.py tests/test_standing_properties.py \
    tests/test_analysis.py tests/test_pool_elastic.py

echo "== static program audit on 8 forced host devices (violations only) =="
# the shard_map engines compile with real collectives here; any
# violation (unbalanced collective, clip scatter, callback) still fails
AUDIT_OUT="$(mktemp)"
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
  python -m repro.analysis --json "$AUDIT_OUT"
rm -f "$AUDIT_OUT"

echo "== obs dispatch-trace smoke on 8 forced host devices =="
# --compare on a different topology skips per-engine gates but still
# proves the tracer runs (and the trace validates) with real collectives
OBS_OUT="$(mktemp)"
OBS_TRACE="$(mktemp)"
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
  python -m repro.obs --smoke --json "$OBS_OUT" --trace "$OBS_TRACE" \
    --compare OBS.json
rm -f "$OBS_OUT" "$OBS_TRACE"

if [[ "$BENCH_SMOKE" == "1" ]]; then
  for bench in fused_ingest_bench warehouse_bench sharded_warehouse_bench \
               standing_query_bench multi_stream_bench pool_scale_bench; do
    echo "== bench smoke: ${bench} --tiny =="
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
      python "benchmarks/${bench}.py" --tiny
  done
  echo "== bench smoke: examples/vetl_observe.py (tiny traced run) =="
  python examples/vetl_observe.py
  echo "== bench smoke: examples/vetl_pool_scale.py (elastic pool walkthrough) =="
  python examples/vetl_pool_scale.py
fi

#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite on CPU.
#
#   scripts/tier1.sh [extra pytest args...]
#
# Honors an existing XLA_FLAGS; otherwise forces a single host device so
# smoke tests see a deterministic topology (the sharding tests fork their
# own 8-device subprocesses).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

exec python -m pytest -x -q "$@"

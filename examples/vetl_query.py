"""End-to-end V-ETL: Extract/Transform (fused ingestion engine) ->
**Load** (device-resident columnar warehouse) -> compiled queries.

    PYTHONPATH=src python examples/vetl_query.py

The paper's founding premise is that video analytics is a data
warehousing problem: video must become "an application-specific format
that is easy to query". This example runs a day of synthetic traffic
video through the fused engine with a ``SegmentStore`` sink (ingestion
-> store is zero per-segment host transfers), then answers analyst
questions as single compiled dispatches::

    store = SegmentStore(out_dim=K)
    IG.run_skyscraper_fused(fitted, stream, sink=store, ...)
    table, mask = store.query((
        Filter("quality", "ge", 0.6),
        WindowAgg(window=150, value="quality", agg="mean",
                  num_windows=windows_for(store, 150)),
        TopK(5, by="quality"),
    ))

Re-running a plan with new thresholds reuses the same executable (the
plan's VALUES are dynamic operands), older chunks spill to an
int8-quantized cold tier, and the whole warehouse survives a process
restart through ``checkpoint/ckpt.py``.

The final section scales the Load layer HORIZONTALLY: a ``ShardedStore``
partitions rows by stream-id hash across a device mesh and answers the
same plans through the partial/merge engine as ONE shard_map dispatch.
It runs on any CPU — the line below forces 4 host-platform devices
before jax initializes, so even a laptop gets a real 4-device shard
mesh (drop the env var to see the stacked single-device fallback).
"""
import os
import sys
# must be set BEFORE jax initializes: gives a plain CPU host 4 devices
# for the sharded-warehouse section
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.data.stream import generate
from repro.warehouse import (Filter, GroupBy, MultiGroupBy, SegmentStore,
                             ShardedStore, TieredStore, TopK, WindowAgg,
                             load_warehouse, save_warehouse, to_host,
                             windows_for)
from repro.warehouse import query as Q


def main():
    print("== offline phase (fit on 2 days of historical stream) ==")
    fitted = fit(COVID, n_cores=8, days_unlabeled=2.0, n_categories=4)
    K = len(fitted.configs)
    print(f"K={K} Pareto configs")

    print("\n== Extract/Transform/LOAD: 24h through the fused engine ==")
    stream = generate(COVID, days=1.0, seed=99)
    store = SegmentStore(out_dim=K, chunk_rows=8192)
    res = IG.run_skyscraper_fused(fitted, stream, n_cores=8,
                                  cloud_budget_core_s=15_000.0,
                                  buffer_gb=4.0, plan_days=0.25,
                                  sink=store)
    print(f"run quality {res.quality_pct:.2f}%  ->  {store}")

    print("\n== query 1: worst five 5-min windows (mean quality), "
          "confident segments only ==")
    nw = windows_for(store, 150)
    plan = (Filter("quality", "ge", 0.05),
            WindowAgg(window=150, value="quality", agg="mean",
                      num_windows=nw),
            TopK(5, by="quality", largest=False))
    worst = to_host(*store.query(plan))
    for w, q in zip(worst["window"], worst["quality"]):
        print(f"   window {w:4d} ({w * 150 * 2 / 3600:5.2f}h): "
              f"mean quality {q:.3f}")

    print("\n== query 2: on-prem work per content category ==")
    spend = to_host(*store.query(
        (GroupBy("category", "on_core_s", agg="sum",
                 num_groups=fitted.centers.shape[0]),)))
    for c, s, n in zip(spend["category"], spend["on_core_s"],
                       spend["count"]):
        print(f"   category {c}: {s:9.1f} core-s over {int(n)} segments")

    print("\n== re-query with a new threshold: same compiled kernel ==")
    before = Q.compile_cache_size()
    store.query((Filter("quality", "ge", 0.5),) + plan[1:])
    store.query((Filter("quality", "ge", 0.9),) + plan[1:])
    assert Q.compile_cache_size() == before, "recompiled!"
    print(f"   0 recompiles ({before} cached plan shapes total)")

    print("\n== tiering: spill old chunks to the int8 cold tier ==")
    ts = TieredStore(store, seed=0)
    spilled = ts.spill(keep_hot=store.n_rows // 4)
    print(f"   {ts} (spilled {spilled} rows, "
          f"max cold scale {ts.max_cold_scale():.2e})")
    cold_ans = to_host(*ts.query(plan))
    print(f"   same query across both tiers: windows "
          f"{cold_ans['window'].tolist()}")

    print("\n== persistence: the warehouse survives restart ==")
    path = "/tmp/vetl_warehouse.rsk"
    save_warehouse(path, ts)
    back = load_warehouse(path)
    again = to_host(*back.query(plan))
    assert np.array_equal(again["window"], cold_ans["window"])
    assert np.array_equal(again["quality"], cold_ans["quality"])
    print(f"   restored {back} from {path}; answers identical")

    print("\n== sharded warehouse: 4 streams hashed across 4 devices ==")
    import jax
    print(f"   host devices: {jax.device_count()}")
    V = 4
    streams = [generate(COVID, days=0.05, seed=10 + v) for v in range(V)]
    shard_store = ShardedStore(out_dim=K, n_shards=4, chunk_rows=2048)
    print(f"   mesh: {shard_store.mesh}"
          if shard_store.mesh is not None
          else "   (1 device: stacked fallback, same semantics)")
    # the fused multi-stream engine routes every stream's trace to its
    # owning shard device-side — ONE shard_map ingest dispatch
    IG.run_skyscraper_multi([fitted] * V, streams, n_cores_each=8,
                            cloud_budget_core_s=4_000.0, plan_days=0.25,
                            sink=shard_store)
    print(f"   {shard_store}")
    # the same plan runs as ONE dispatch: per-shard partial kernel
    # (masked segment_sum) + collective merge (psum) + top-k
    nw4 = windows_for(shard_store, 150)
    splan = (Filter("quality", "ge", 0.05),
             WindowAgg(window=150, value="quality", agg="mean",
                       num_windows=nw4),
             TopK(5, by="quality", largest=False))
    worst4 = to_host(*shard_store.query(splan))
    for w, q in zip(worst4["window"], worst4["quality"]):
        print(f"   window {w:4d}: mean quality {q:.3f}")
    before = Q.sharded_compile_cache_size()
    shard_store.query((Filter("quality", "ge", 0.5),) + splan[1:])
    assert Q.sharded_compile_cache_size() == before, "recompiled!"
    print("   re-query with a new threshold: 0 recompiles")
    # multi-key GroupBy: per (window x category) mean quality, fused
    # into one segment_sum pass
    by_wc = to_host(*shard_store.query((
        MultiGroupBy(keys=("t", "category"), value="quality", agg="mean",
                     nums=(nw4, fitted.centers.shape[0]),
                     windows=(150, 0)),
        TopK(3, by="quality", largest=False))))
    for w, c, q in zip(by_wc["t"], by_wc["category"], by_wc["quality"]):
        print(f"   window {w:4d} x category {c}: mean quality {q:.3f}")

    print("\nOK: ingest -> store -> query -> spill -> restore -> shard "
          "all good.")


if __name__ == "__main__":
    main()

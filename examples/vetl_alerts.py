"""Standing queries & alerts walkthrough: register -> ingest -> alert
fires -> snapshot answers without a rescan.

    PYTHONPATH=src python examples/vetl_alerts.py

1. Attach a ``StandingQueries`` registry to a warehouse store, register
   a batch of same-shape standing queries (their thresholds stack into
   ONE vmapped fold) and subscribe a threshold alert.
2. Run fused V-ETL ingestion into the store: every ingest dispatch
   ALSO folds the new rows into each standing query's accumulators —
   no second dispatch, no rescan — and ``RunResult.alerts`` carries the
   fired-alert masks the sink's subscriptions produced.
3. Read O(result) snapshot answers and show they match a full rescan,
   then check the flight-recorder counters that account for all of it.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.data.stream import generate
from repro.warehouse import (Filter, GroupBy, SegmentStore,
                             StandingQueries, WindowAgg, execute_ref)


def main():
    print("== 1. register standing queries on an empty store ==")
    fitted = fit(COVID, n_cores=8, days_unlabeled=2.0, n_categories=4,
                 seed=0)
    store = SegmentStore(out_dim=len(fitted.configs), chunk_rows=1024)
    reg = StandingQueries(store)
    # same plan shape, different thresholds: one vmapped fold for all
    handles = {
        thr: reg.register(
            (Filter("quality", "ge", thr),
             GroupBy("category", "quality", agg="mean", num_groups=4)),
            name=f"mean-quality>={thr}")
        for thr in (0.0, 0.5, 0.9)
    }
    # alert: fire when any 64-segment window burns >40 core-seconds
    sid = reg.subscribe(
        (WindowAgg(window=64, value="on_core_s", agg="sum",
                   num_windows=16),),
        predicate=Filter("on_core_s", "gt", 40.0),
        name="hot-window")
    print(f"   {len(reg)} standing queries registered "
          f"(alert subscription {sid})")

    print("\n== 2. fused ingestion refreshes every query in-dispatch ==")
    stream = generate(COVID, days=0.02, seed=7)
    tau = fitted.workload.segment_seconds
    res = IG.run_skyscraper_fused(
        fitted, stream, n_cores=8, cloud_budget_core_s=5_000.0,
        plan_days=64.5 * tau / 86400, forecast_mode="model", sink=store)
    print(f"   ingested {store.n_rows} segments; quality "
          f"{res.quality_pct:.2f}%")
    for alert in res.alerts:             # polled right after the sink
        print(f"   alert {alert.name!r}: fired on {alert.n_fired} of "
              f"{alert.fired.shape[0]} windows")
        if alert.n_fired:
            hot = np.flatnonzero(alert.fired)
            print(f"     windows {hot.tolist()} burned "
                  f"{alert.table['on_core_s'][hot].round(1).tolist()} "
                  f"core-seconds")

    print("\n== 3. O(result) snapshots == full rescan, no rescan run ==")
    cols = store.host_rows()
    for thr, h in handles.items():
        table, mask = reg.answer(h)      # accumulator finalize only
        ref, rmask = execute_ref(
            cols, store.n_rows,
            (Filter("quality", "ge", thr),
             GroupBy("category", "quality", agg="mean", num_groups=4)))
        assert np.array_equal(np.asarray(mask), rmask)
        assert np.array_equal(np.asarray(table["quality"]),
                              ref["quality"])
        live = np.asarray(mask)
        means = np.asarray(table["quality"])[live].round(3)
        print(f"   quality>={thr}: per-category means {means.tolist()}")

    tel = store.telemetry()
    print(f"\n   store telemetry: {tel.summary()}")
    assert tel.standing_queries == len(reg)
    assert tel.standing_refreshes >= 1 and tel.alerts_checked >= 1
    print("\nOK: standing answers exact, alerts live, zero rescans.")


if __name__ == "__main__":
    main()

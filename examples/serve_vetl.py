"""V-ETL serving with an assigned-arch backbone (deliverable b):
batched segment requests flow through the Skyscraper switcher, which
picks {sampling, resolution, model-size} knobs per segment; the heavy
UDF is a JAX transformer forward whose mean top-1 certainty is the
quality signal (paper §5.2's certainty proxy). The resolution knob
exercises the Pallas frame-preprocessing kernel.

    PYTHONPATH=src python examples/serve_vetl.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.api import Skyscraper
from repro.core.vetl_serving import BackboneVETL


def make_segments(n, seed=0):
    rng = np.random.default_rng(seed)
    segs = []
    for t in range(n):
        segs.append({
            "frames": rng.normal(0, 1, (8, 32, 32, 3)).astype(np.float32),
            "tokens": rng.integers(0, 200, (8, 16)),
        })
    return segs


def main():
    job = BackboneVETL(arch="qwen1.5-0.5b")
    sky = Skyscraper(segment_seconds=1.0, n_categories=3)
    sky.set_resources(num_cores=2, buffer_gb=0.5)
    sky.register_knob("sample_every", [1, 2, 4])
    sky.register_knob("resolution", [1, 2])
    sky.register_knob("model_size", ["small", "medium", "large"])

    print("== offline: profiling knob configs on the backbone ==")
    sky.fit(make_segments(40, seed=1), job.proc_fn, plan_segments=25)
    print(f"{len(sky.configs)} Pareto configs kept "
          f"(costs {np.round(sky.cost, 4)} core-s/segment)")

    print("== online: serving 60 segments ==")
    sizes, quals = [], []
    for seg in make_segments(60, seed=2):
        info, out = sky.process(seg)
        sizes.append(info["config"]["model_size"])
        quals.append(info["quality"])
    hist = {v: sizes.count(v) for v in sorted(set(sizes))}
    print(f"model-size usage: {hist}; mean certainty {np.mean(quals):.3f}")
    print("OK: served with content-adaptive knobs over a JAX backbone.")


if __name__ == "__main__":
    main()

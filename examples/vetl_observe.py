"""Flight-recorder walkthrough: the observability layer end to end.

    PYTHONPATH=src python examples/vetl_observe.py

1. Fit a tiny Skyscraper on historical COVID stream, then run one day
   of fused ingestion with ``telemetry=True`` — the per-segment health
   counters (drops, buffer high-water mark, core-seconds, config
   switches) ride inside the SAME compiled scan, so the flight recorder
   costs zero extra dispatches.
2. Land the run in a SegmentStore sink and read the store-side
   counters: rows per shard, ingest-to-queryable lag, dispatch counts.
3. Trace the fused engines with the dispatch tracer (``repro.obs``):
   wall-time spans, executable/recompile deltas, a Chrome-trace JSON
   you can drop into chrome://tracing or Perfetto.

The full tracer run over EVERY engine plus the regression gate against
the committed baseline is one command::

    python -m repro.obs --json OBS_NEW.json --compare OBS.json
"""
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.data.stream import generate
from repro.obs import validate_chrome_trace
from repro.obs.trace import trace_all
from repro.warehouse import Filter, SegmentStore, TopK


def main():
    print("== 1. fused ingestion with the on-device flight recorder ==")
    fitted = fit(COVID, n_cores=8, days_unlabeled=2.0, n_categories=4,
                 seed=0)
    stream = generate(COVID, days=0.02, seed=7)
    store = SegmentStore(out_dim=len(fitted.configs), chunk_rows=512)
    tau = fitted.workload.segment_seconds
    res = IG.run_skyscraper_fused(
        fitted, stream, n_cores=8, cloud_budget_core_s=5_000.0,
        plan_days=64.5 * tau / 86400, forecast_mode="model",
        sink=store, telemetry=True)
    tel = res.telemetry
    print(f"   quality {res.quality_pct:6.2f}%  over "
          f"{stream.n_segments} segments")
    print(f"   telemetry: {tel.summary()}")
    # the counters are accumulated INSIDE the scan carry; the host
    # mirror in repro.obs.telemetry_ref reproduces them bit-exactly
    assert tel.segments == stream.n_segments
    # counter also sees a first-segment switch away from the boot
    # config, which diff(k_trace) cannot
    switches = int((np.diff(res.k_trace) != 0).sum())
    assert switches <= tel.config_switches <= switches + 1

    print("\n== 2. warehouse-side counters (same store, zero probes) ==")
    table, mask = store.query((Filter("quality", "ge", 0.0),
                               TopK(5, by="on_core_s")))
    stel = store.telemetry()
    print(f"   store: {stel.summary()}")
    assert stel.n_rows == stream.n_segments
    assert stel.query_dispatches == 1
    # fused batch ingest: row t waited T-1-t ticks before queryable
    assert stel.lag_max_ticks == stream.n_segments - 1

    print("\n== 3. dispatch tracer over the fused engines ==")
    records, trace = trace_all(only="fused", reps=2)
    for name, r in sorted(records.items()):
        if "skipped" in r:
            print(f"   {name:28s} SKIP ({r['skipped']})")
            continue
        print(f"   {name:28s} span={r['span_us']:9.1f}us "
              f"exec+{r['new_executables']} "
              f"recompile={r['recompiles']}")
        assert r["recompiles"] == 0
    problems = validate_chrome_trace(trace)
    assert not problems, problems
    out = os.path.join(tempfile.gettempdir(), "vetl_trace.json")
    with open(out, "w") as f:
        json.dump(trace, f)
    print(f"   wrote {len(trace['traceEvents'])} spans to {out}")
    print("   (open in chrome://tracing; gate a CI run with "
          "`python -m repro.obs --compare OBS.json`)")
    print("\nOK: flight recorder + dispatch tracer both healthy.")


if __name__ == "__main__":
    main()

"""Elastic serving pool walkthrough: admit -> overload shed -> alert ->
retire -> rebalance.

    PYTHONPATH=src python examples/vetl_pool_scale.py

1. Fit a tiny Skyscraper and stand up a ``SkyscraperPool`` over a
   sharded warehouse sink. Admit a fleet of live streams with
   priorities — capacity grows on a power-of-two slot ladder, so
   admits inside a bucket never recompile.
2. Squeeze ``capacity_core_s`` (a traced operand: mutable between
   ticks for free) and watch priority-ordered shedding show up in the
   flight recorder and fire a standing-alert subscription.
3. Lift the squeeze, retire the low-priority streams, and rebalance
   the warehouse onto a different shard count in ONE collective
   dispatch — standing queries replay handle-stably.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.api import Skyscraper, SkyscraperPool
from repro.runtime.elastic import rebalance
from repro.warehouse import (Filter, GroupBy, ShardedStore,
                             StandingQueries, to_host)


def _proc(seg, knobs):
    return ("out", min(0.5 + 0.1 * knobs["q"], 1.0))


def main():
    print("== 1. fit + admit a prioritized fleet ==")
    rng = np.random.default_rng(0)
    sky = Skyscraper(fps=2, segment_seconds=1.0, n_categories=2, seed=0)
    sky.set_resources(num_cores=4, buffer_gb=1.0, cloud_budget_core_s=0.0)
    sky.register_knob("q", [1, 2, 3])
    sky.fit([rng.random((3,)) for _ in range(12)], _proc)

    sink = ShardedStore(out_dim=len(sky.configs), n_shards=2,
                        chunk_rows=64)
    reg = StandingQueries(sink)
    reg.subscribe([GroupBy("stream_id", "quality", agg="min",
                           num_groups=16)],
                  Filter("quality", "le", 0.0), name="shed-watch")

    pool = SkyscraperPool(sky, n_streams=2, priorities=[4.0, 4.0],
                          sink=sink, telemetry=True)
    for sid, prio in [(2, 3.0), (3, 2.0), (4, 1.0), (5, 1.0)]:
        pool.admit(sid, priority=prio)
    print(f"   streams={pool.streams} slot capacity={pool.cap}")

    seg = np.zeros(3)
    pool.process([seg] * pool.V)           # unconstrained tick
    tel = pool.telemetry()
    demand = float(np.asarray(tel.counters["onprem_core_s"]).sum())
    print(f"   fleet demand {demand * 1e6:.2f}us core-s/tick "
          f"(the tiny demo proc), no shedding: "
          f"dropped={int(np.asarray(tel.counters['seg_dropped']).sum())}")

    print("== 2. overload: squeeze capacity, shed by priority ==")
    pool.capacity_core_s = demand * 0.5    # room for ~half the fleet
    for _ in range(3):
        statuses, _ = pool.process([seg] * pool.V)
    shed = {s["stream_id"]: s["shed"] for s in statuses}
    print(f"   shed by stream: {shed}")
    assert not shed[0] and not shed[1], "high priority must be kept"
    stats = pool.shed_stats()
    for sid in pool.streams:
        print(f"   stream {sid}: prio={stats[sid]['priority']:.1f} "
              f"shed {stats[sid]['dropped']}/{stats[sid]['segments']}")
    assert pool.alerts and pool.alerts[0].name == "shed-watch"
    print(f"   standing alert fired: {pool.alerts[0].name} on streams "
          f"{np.nonzero(np.asarray(pool.alerts[0].fired))[0].tolist()}")

    print("== 3. recover: lift the squeeze, retire, rebalance ==")
    pool.capacity_core_s = None
    for sid in (4, 5):
        pool.retire(sid)
    pool.process([seg] * pool.V)
    print(f"   fleet now {pool.streams}, rows in store: {sink.n_rows}")

    new_store = rebalance(sink, 4)         # 2 shards -> 4, one dispatch
    print(f"   rebalanced {sink.n_shards} -> {new_store.n_shards} shards, "
          f"rows/shard {new_store.n_rows_by_shard.tolist()}")
    assert new_store.n_rows == sink.n_rows
    # standing queries replayed handle-stably on the new store
    table, mask = new_store.standing.answer(pool.alerts[0].handle)
    groups = to_host(table, mask)
    print(f"   shed-watch still answering post-rebalance: "
          f"{len(groups['quality'])} streams tracked")
    print("ok")


if __name__ == "__main__":
    main()

"""End-to-end training driver (deliverable b): train a ~100M-param LM
(qwen1.5-0.5b family at reduced width) for a few hundred steps with
checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

import jax

from repro.configs.base import get
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    losses = train_main([
        "--arch", "qwen1.5-0.5b", "--reduced",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "128", "--lr", "3e-3",
        "--microbatches", "2",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "20",
    ])
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()

"""Figure-3 style end-to-end V-ETL run: 24 h of a synthetic traffic
stream on constrained hardware with buffering + cloud bursting.

    PYTHONPATH=src python examples/vetl_ingest.py

Whole-run fused engine: ``run_skyscraper_fused`` compiles the ENTIRE
online phase — forecast, LP planning, and reactive switching for every
planning window — into one ``lax.scan`` program, so a T-segment run is
a single dispatch instead of T/W host round-trips (>=5x faster at
T>=10k, see benchmarks/fused_ingest_bench.py) and reproduces the
windowed loop's results to float32 tolerance::

    from repro.core import ingest as IG
    from repro.core.offline import fit
    from repro.data.stream import generate

    fitted = fit(COVID, n_cores=8, days_unlabeled=6.0)
    stream = generate(COVID, days=1.0, seed=99)
    res = IG.run_skyscraper_fused(fitted, stream, n_cores=8,
                                  cloud_budget_core_s=15_000.0,
                                  forecast_mode="model")   # | oracle | uniform
    print(res.quality_pct, res.cloud_core_s)

Multi-stream ingestion (paper App. D) gets the same treatment: the
joint LP over all streams' categories runs ON DEVICE inside the outer
scan (``solve_lp_stacked`` on the sentinel-padded (V, C_max, K) category
stack), so ``run_skyscraper_multi`` performs zero host planning work::

    streams = [generate(COVID, days=1.0, seed=s) for s in range(8)]
    res = IG.run_skyscraper_multi([fitted] * 8, streams, n_cores_each=8,
                                  cloud_budget_core_s=8000.0)
    print(res["quality_pct"], res["per_stream_pct"])

For online serving (one decision per arriving segment across V live
cameras in a single dispatch) use ``repro.core.api.SkyscraperPool`` —
it runs on the same fused planning engine: per-stream label histories
live in a device-side rolling buffer and replanning is one compiled
vmapped forecast + LP call.
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.data.stream import generate


def sparkline(xs, width=64):
    xs = np.asarray(xs, float)
    xs = xs[:: max(1, len(xs) // width)]
    lo, hi = xs.min(), xs.max()
    ticks = " .:-=+*#%@"
    if hi - lo < 1e-9:
        return ticks[0] * len(xs)
    return "".join(ticks[int((x - lo) / (hi - lo) * (len(ticks) - 1))]
                   for x in xs)


def main():
    print("== offline phase (fit on 6 days of historical stream) ==")
    fitted = fit(COVID, n_cores=8, days_unlabeled=6.0, n_categories=4)
    print(f"K={len(fitted.configs)} Pareto configs, costs="
          f"{np.round(fitted.cost, 2)} core-s/seg")
    print(f"forecaster val MAE: {fitted.forecast_metrics['val_mae']:.4f}")

    print("\n== online: 24h ingestion, 8 cores + 4GB buffer + cloud ==")
    print("   (fused engine: the whole day is ONE compiled scan)")
    stream = generate(COVID, days=1.0, seed=99)
    res = IG.run_skyscraper_fused(fitted, stream, n_cores=8,
                                  cloud_budget_core_s=15_000.0,
                                  buffer_gb=4.0, plan_days=0.25)
    k = IG.best_static_config(fitted, 8)
    static = IG.run_static(fitted, stream, k, n_cores=8)
    opt = IG.run_optimum(fitted, stream, n_cores=8,
                         cloud_budget_core_s=15_000.0)

    print(f"skyscraper quality: {res.quality_pct:6.2f}%  "
          f"(work {res.work_core_s / 1e3:.0f}k core-s, "
          f"cloud {res.cloud_core_s:.0f} core-s)")
    print(f"static-best quality: {static.quality_pct:6.2f}%")
    print(f"optimum (oracle):    {opt.quality_pct:6.2f}%")
    print(f"knob switches: "
          f"{int((np.diff(res.k_trace) != 0).sum())} over "
          f"{len(res.k_trace)} segments")
    print("\nbuffer fill over the day (paper Fig. 3, third panel):")
    print("  " + sparkline(res.buffer_trace))
    print("difficulty (content) over the day:")
    print("  " + sparkline(stream.difficulty))
    print("chosen config cost over the day (second panel):")
    print("  " + sparkline(fitted.cost[res.k_trace]))
    assert res.quality_pct > static.quality_pct
    print("\nOK: content-adaptive ingestion beat the static baseline.")


if __name__ == "__main__":
    main()

"""Quickstart — the paper's EV-counting example (App. F) on synthetic
frames with toy UDFs.

    PYTHONPATH=src python examples/quickstart.py

A Skyscraper instance is provisioned, one knob is registered
(detector interval), fit() profiles the configs offline, and process()
ingests segments with content-adaptive knob switching.
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.api import Skyscraper


def make_segments(n=120, seed=0):
    """Synthetic 'traffic' segments: difficulty follows a day cycle."""
    rng = np.random.default_rng(seed)
    segs = []
    for t in range(n):
        difficulty = 0.5 + 0.45 * np.sin(2 * np.pi * t / n)
        segs.append({
            "frames": rng.normal(0, 1, (4, 32, 32, 3)).astype(np.float32),
            "difficulty": float(np.clip(difficulty, 0, 1)),
        })
    return segs


def main():
    # --- the user's UDF DAG: detector (knob-controlled) + tracker -------
    def proc_frame(segment, knobs):
        interval = knobs["det_interval"]
        frames = segment["frames"][::interval]
        # toy "yolo": mean-pool detector + toy "kcf" tracker
        dets = np.tanh(frames.mean(axis=(1, 2, 3)))
        ev_count = float((dets > 0).sum())
        # quality: running the detector more often handles difficult
        # (occluded) content better — reported by the UDF itself
        power = 1.0 / interval
        qual = 1.0 - segment["difficulty"] * (1.0 - 0.85 * power)
        return {"ev_count": ev_count}, qual

    sky = Skyscraper(fps=30, segment_seconds=2.0, n_categories=3)
    sky.set_resources(num_cores=4, buffer_gb=1.0)
    sky.register_knob("det_interval", [1, 2, 4, 8])

    train = make_segments(100, seed=1)
    sky.fit(train, proc_frame, plan_segments=40)
    print(f"offline done: {len(sky.configs)} Pareto configs, "
          f"centers=\n{np.round(sky.centers, 3)}")

    total_ev, quals, used = 0.0, [], []
    for seg in make_segments(120, seed=2):
        info, out = sky.process(seg)
        total_ev += out["ev_count"]
        quals.append(info["quality"])
        used.append(info["config"]["det_interval"])
    print(f"ingested 120 segments: EV count={total_ev:.0f}, "
          f"mean quality={np.mean(quals):.3f}")
    print(f"knob usage histogram (det_interval -> segments): "
          f"{ {v: used.count(v) for v in sorted(set(used))} }")
    assert len(set(used)) > 1, "expected content-adaptive switching"
    print("OK: Skyscraper adapted the knob to the content.")


if __name__ == "__main__":
    main()

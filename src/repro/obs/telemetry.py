"""On-device flight-recorder telemetry for the fused engines.

The stack's contract is a *throughput guarantee* (paper Eq. 1), but
every hot path is ONE fused dispatch — buffer occupancy, drops, cloud
spend and config churn are invisible between ingest and final state.
This module threads a fixed-shape ``tel`` counter pytree through the
carries of the existing scans so a run can report what happened
WITHOUT breaking the single-dispatch property:

- counters are float32 scalars (single-stream) or (V,) leaves (multi)
  accumulated SEQUENTIALLY in segment-time order inside the inner
  window scan — the same add order a host ``np.float32`` loop performs,
  so every counter is bit-exact against ``telemetry_ref``;
- padding steps are exact no-ops (``jnp.where(valid, ...)``), matching
  the masked-switch no-op contract;
- the outer scan snapshots the cumulative counters at every window
  boundary as extra ys, so per-window deltas are derived host-side for
  free (no extra dispatches, no host transfers inside the program).

``telemetry=True`` is a static flag on the fused engines: the
no-telemetry program traces to the EXACT pre-telemetry jaxpr, and the
telemetry variant is one additional jit cache entry (still one
dispatch per run) — the overhead contract the auditor pins.

Counter semantics (per stream; all float32):

    seg_total          valid segments executed
    seg_dropped        segments shed by overload (no feasible placement)
    buffer_hwm_s       high-water mark of post-segment buffer fill (s)
    buffer_occ_sum_s   sum of post-segment buffer fill (s) — divide by
                       seg_total for mean occupancy
    onprem_core_s      on-prem work accumulated (core-seconds)
    cloud_core_s       cloud work accumulated (core-seconds)
    config_switches    valid steps whose chosen config differs from the
                       previous step's (dropped segments still switch)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

TEL_KEYS = ("seg_total", "seg_dropped", "buffer_hwm_s",
            "buffer_occ_sum_s", "onprem_core_s", "cloud_core_s",
            "config_switches")


# ---------------------------------------------------------------------------
# device side: counter pytree + telemetry-extended window scans
# ---------------------------------------------------------------------------

def tel_init(state) -> Dict[str, jnp.ndarray]:
    """Zeroed counter pytree shaped like the switcher state's
    ``buffer_s`` leaf (scalar single-stream, (V,) multi)."""
    z = jnp.zeros_like(state["buffer_s"])
    return {k: z for k in TEL_KEYS}


def tel_step(tel, k_prev, out, valid):
    """One segment's counter update. ``k_prev`` is the PRE-step
    ``k_cur``; ``out`` is the switch-step outs dict; ``valid=False``
    leaves every counter untouched (exact no-op). All adds are single
    float32 ops in carry order — the host mirror replays them exactly."""
    keep = jnp.asarray(valid, bool)

    def add(cur, x):
        return jnp.where(keep, cur + x, cur)

    one = jnp.float32(1.0)
    return {
        "seg_total": add(tel["seg_total"], one),
        "seg_dropped": add(tel["seg_dropped"],
                           out["dropped"].astype(jnp.float32)),
        "buffer_hwm_s": jnp.where(
            keep, jnp.maximum(tel["buffer_hwm_s"], out["buffer_s"]),
            tel["buffer_hwm_s"]),
        "buffer_occ_sum_s": add(tel["buffer_occ_sum_s"], out["buffer_s"]),
        "onprem_core_s": add(tel["onprem_core_s"], out["on_s"]),
        "cloud_core_s": add(tel["cloud_core_s"], out["cl_s"]),
        "config_switches": add(
            tel["config_switches"],
            (out["k"] != k_prev).astype(jnp.float32)),
    }


def masked_switch_tel(carry, qual_row, arrival, valid, alpha, tables):
    """``_masked_switch`` with the telemetry carry alongside the state."""
    # deferred: core.ingest imports this module, so importing the
    # switcher at module scope would close an import cycle
    from repro.core.switcher import _masked_switch
    state, tel = carry
    k_prev = state["k_cur"]
    new_state, out = _masked_switch(state, qual_row, arrival, valid,
                                    alpha, tables)
    return (new_state, tel_step(tel, k_prev, out, valid)), out


def window_scan_tel(state, tel, quals, arrivals, valid, alpha, tables):
    """``switcher.window_scan`` + telemetry carry (pure; inlined by the
    fused engine's outer scan when ``telemetry=True``)."""
    def body(carry, inp):
        q_row, arr, v = inp
        return masked_switch_tel(carry, q_row, arr, v, alpha, tables)

    return jax.lax.scan(body, (state, tel), (quals, arrivals, valid))


def window_scan_multi_tel(state, tel, quals, arrivals, valid, alpha,
                          tables):
    """``switcher.window_scan_multi`` + per-stream telemetry carry:
    the decision AND its counter update vmap over the leading stream
    axis of every pytree, then one scan over time."""
    def step(st, tl, q_row, arr, v, al, tb):
        (st, tl), out = masked_switch_tel((st, tl), q_row, arr, v, al, tb)
        return st, tl, out

    vstep = jax.vmap(step)

    def body(carry, inp):
        st, tl = carry
        q_row, arr, v = inp                         # (V,K), (V,), (V,)
        st, tl, out = vstep(st, tl, q_row, arr, v, alpha, tables)
        return (st, tl), out

    xs = (jnp.swapaxes(quals, 0, 1), jnp.swapaxes(arrivals, 0, 1),
          jnp.swapaxes(valid, 0, 1))
    (state, tel), outs = jax.lax.scan(body, (state, tel), xs)
    outs = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), outs)
    return (state, tel), outs


# ---------------------------------------------------------------------------
# host side: run telemetry container + numpy mirror
# ---------------------------------------------------------------------------

@dataclass
class Telemetry:
    """Flight-recorder counters of one run (host-side container).

    ``counters`` holds the FINAL cumulative float32 values (scalars
    single-stream, (V,) arrays multi); ``per_window`` the cumulative
    window-boundary snapshots ((n_w,) / (n_w, V) arrays) the outer scan
    emitted; ``extras`` carries engine-specific host-side counts (pool
    ticks, replans). The raw counters are the bit-exactness contract —
    derived views (means, deltas) are computed here, on host, for
    display only."""
    counters: Dict[str, np.ndarray]
    per_window: Dict[str, np.ndarray] = field(default_factory=dict)
    extras: Dict[str, float] = field(default_factory=dict)

    @classmethod
    def from_device(cls, tel_windows) -> "Telemetry":
        """From the fused engine's stacked per-window snapshots
        ((n_w, ...) leaves): final row = end-of-run cumulative values."""
        per_window = {k: np.asarray(v) for k, v in tel_windows.items()}
        counters = {k: v[-1] for k, v in per_window.items()}
        return cls(counters=counters, per_window=per_window)

    # -- derived views (display only; not part of the exactness contract)
    @property
    def segments(self) -> float:
        return float(np.sum(self.counters["seg_total"]))

    @property
    def dropped(self) -> float:
        return float(np.sum(self.counters["seg_dropped"]))

    @property
    def buffer_hwm_s(self) -> float:
        return float(np.max(self.counters["buffer_hwm_s"]))

    @property
    def buffer_occ_mean_s(self) -> float:
        n = np.sum(self.counters["seg_total"])
        return float(np.sum(self.counters["buffer_occ_sum_s"])
                     / max(n, 1.0))

    @property
    def onprem_core_s(self) -> float:
        return float(np.sum(self.counters["onprem_core_s"]))

    @property
    def cloud_core_s(self) -> float:
        return float(np.sum(self.counters["cloud_core_s"]))

    @property
    def config_switches(self) -> float:
        return float(np.sum(self.counters["config_switches"]))

    def window_deltas(self) -> Dict[str, np.ndarray]:
        """Per-window deltas of the monotone counters (the gauges —
        ``buffer_hwm_s`` — stay cumulative)."""
        out = {}
        for k, v in self.per_window.items():
            if k == "buffer_hwm_s":
                out[k] = v.copy()
            else:
                out[k] = np.diff(v, axis=0, prepend=np.zeros_like(v[:1]))
        return out

    def summary(self) -> str:
        return (f"segments={self.segments:.0f} "
                f"dropped={self.dropped:.0f} "
                f"buffer_hwm={self.buffer_hwm_s:.1f}s "
                f"occ_mean={self.buffer_occ_mean_s:.2f}s "
                f"onprem={self.onprem_core_s:.0f}core-s "
                f"cloud={self.cloud_core_s:.0f}core-s "
                f"switches={self.config_switches:.0f}")


def _accumulate(counters: Dict[str, np.ndarray], k_prev: np.ndarray,
                k, dropped, buffer_s, on_s, cl_s, valid) -> np.ndarray:
    """One segment-time step of the float32 mirror, vectorized over the
    stream axis. Mutates ``counters`` in place; returns the new
    ``k_prev``. Each update is ONE float32 add/max per stream in the
    same order as the device carry — bit-exact by construction."""
    v = np.asarray(valid, bool)
    f32 = np.float32
    counters["seg_total"] = np.where(
        v, (counters["seg_total"] + f32(1.0)).astype(f32),
        counters["seg_total"])
    counters["seg_dropped"] = np.where(
        v, (counters["seg_dropped"]
            + np.asarray(dropped, f32)).astype(f32),
        counters["seg_dropped"])
    counters["buffer_hwm_s"] = np.where(
        v, np.maximum(counters["buffer_hwm_s"],
                      np.asarray(buffer_s, f32)),
        counters["buffer_hwm_s"])
    counters["buffer_occ_sum_s"] = np.where(
        v, (counters["buffer_occ_sum_s"]
            + np.asarray(buffer_s, f32)).astype(f32),
        counters["buffer_occ_sum_s"])
    counters["onprem_core_s"] = np.where(
        v, (counters["onprem_core_s"] + np.asarray(on_s, f32)).astype(f32),
        counters["onprem_core_s"])
    counters["cloud_core_s"] = np.where(
        v, (counters["cloud_core_s"] + np.asarray(cl_s, f32)).astype(f32),
        counters["cloud_core_s"])
    counters["config_switches"] = np.where(
        v, (counters["config_switches"]
            + (np.asarray(k) != k_prev).astype(f32)).astype(f32),
        counters["config_switches"])
    return np.where(v, np.asarray(k, np.int64), k_prev)


def telemetry_ref(traces: Dict[str, np.ndarray], k0,
                  valid: Optional[np.ndarray] = None
                  ) -> Dict[str, np.ndarray]:
    """Numpy float32 mirror of the device counters: replay the run's
    per-segment traces in time order with sequential float32
    accumulation. ``traces`` needs keys ``k``, ``dropped``,
    ``buffer_s``, ``on_s``, ``cl_s`` with (T,) (single-stream) or
    (V, T) (multi) leaves; ``k0`` is the initial ``k_cur`` (the
    switcher starts on the most qualitative config —
    ``argmin(rank_pos)``). Returns the counter dict the device
    telemetry must match BIT-EXACTLY."""
    k = np.asarray(traces["k"])
    single = k.ndim == 1
    def twod(x):
        a = np.asarray(x)
        return a[None] if single else a
    k = twod(traces["k"])
    dropped = twod(traces["dropped"])
    buf = twod(traces["buffer_s"]).astype(np.float32)
    on = twod(traces["on_s"]).astype(np.float32)
    cl = twod(traces["cl_s"]).astype(np.float32)
    V, T = k.shape
    if valid is None:
        vmask = np.ones((V, T), bool)
    else:
        vmask = twod(valid).astype(bool)
    counters = {key: np.zeros((V,), np.float32) for key in TEL_KEYS}
    k_prev = np.broadcast_to(np.asarray(k0, np.int64), (V,)).copy()
    for t in range(T):
        k_prev = _accumulate(counters, k_prev, k[:, t], dropped[:, t],
                             buf[:, t], on[:, t], cl[:, t], vmask[:, t])
    if single:
        counters = {key: v[0] for key, v in counters.items()}
    return counters


class HostTelemetry:
    """Sequential float32 accumulator over per-tick switch outs — the
    serving-pool flight recorder. Updates happen host-side from arrays
    the pool already materializes each tick, so telemetry adds ZERO
    device dispatches (the extra ``np.asarray`` reads are transfers of
    already-computed outputs, not new programs)."""

    def __init__(self, n_streams: int, k0: int):
        self.V = int(n_streams)
        self.k0 = int(k0)
        self.counters = {k: np.zeros((self.V,), np.float32)
                         for k in TEL_KEYS}
        self._k_prev = np.full((self.V,), int(k0), np.int64)
        self.ticks = 0
        self.replans = 0

    def update(self, outs, valid=None) -> None:
        """One pool tick: ``outs`` is the ``switch_step_multi`` outs
        dict ((V,) leaves, device or host). ``valid`` (V,) bool masks
        slots that took no step this tick (the elastic pool's
        retired/empty slots) — their counters are untouched, matching
        the fused engines' masked-step no-op contract."""
        self._k_prev = _accumulate(
            self.counters, self._k_prev, np.asarray(outs["k"]),
            np.asarray(outs["dropped"]), np.asarray(outs["buffer_s"]),
            np.asarray(outs["on_s"]), np.asarray(outs["cl_s"]),
            np.ones((self.V,), bool) if valid is None
            else np.asarray(valid, bool))
        self.ticks += 1

    def grow(self, n_streams: int) -> None:
        """Widen the stream axis to ``n_streams`` slots (elastic-pool
        bucket growth); existing counters are preserved, new slots
        start zeroed with ``k_prev = k0``."""
        n = int(n_streams)
        if n <= self.V:
            return
        pad = n - self.V
        self.counters = {k: np.concatenate(
            [v, np.zeros((pad,), np.float32)])
            for k, v in self.counters.items()}
        self._k_prev = np.concatenate(
            [self._k_prev, np.full((pad,), self.k0, np.int64)])
        self.V = n

    def reset_slot(self, v: int) -> None:
        """Zero one slot's counters (a retired slot being re-admitted
        for a different stream starts a fresh accumulation)."""
        for arr in self.counters.values():
            arr[v] = np.float32(0.0)
        self._k_prev[v] = self.k0

    def snapshot(self, select=None) -> Telemetry:
        """Counter snapshot; ``select`` (slot indices) restricts the
        stream axis (the elastic pool passes its active slots)."""
        if select is None:
            counters = {k: v.copy() for k, v in self.counters.items()}
        else:
            idx = np.asarray(select, np.int64)
            counters = {k: v[idx].copy()
                        for k, v in self.counters.items()}
        return Telemetry(
            counters=counters,
            extras={"ticks": float(self.ticks),
                    "replans": float(self.replans)})


# ---------------------------------------------------------------------------
# warehouse: ingest-to-queryable lag + shard balance (host metadata only)
# ---------------------------------------------------------------------------

@dataclass
class StoreTelemetry:
    """Warehouse-side observability, computed ENTIRELY from host
    metadata the store already tracks (per-shard row counts, batch
    shapes) — zero extra dispatches, zero device reads.

    Ingest-to-queryable lag is measured in ticks (segment slots): a row
    ingested as part of a T-segment fused batch became queryable when
    the batch landed, so a row with in-batch timeline offset ``t`` waited
    ``T - 1 - t`` ticks; per-tick ingest is lag 0. This is the Fluid-ETL
    freshness metric: fused whole-run loads trade T/2 mean lag for
    throughput, the serving pool's tick ingest is lag-free."""
    rows_by_shard: np.ndarray
    ingest_dispatches: int = 0
    query_dispatches: int = 0
    lag_rows: int = 0
    lag_sum_ticks: int = 0
    lag_max_ticks: int = 0
    spill_events: int = 0
    spilled_rows: int = 0
    dequantize_events: int = 0
    # standing-query registry (warehouse.standing): registered plans,
    # how many ingest dispatches also refreshed them (lag-0 freshness —
    # a refresh IS the ingest), and the alert subscriptions' activity
    standing_queries: int = 0
    standing_refreshes: int = 0
    alerts_checked: int = 0
    alerts_fired: int = 0

    @property
    def n_rows(self) -> int:
        return int(np.sum(self.rows_by_shard))

    @property
    def imbalance(self) -> float:
        """max-shard rows / mean-shard rows (1.0 = perfectly balanced;
        n_shards = everything on one shard; 0 rows reports 1.0)."""
        total = int(np.sum(self.rows_by_shard))
        if total == 0:
            return 1.0
        mean = total / len(self.rows_by_shard)
        return float(np.max(self.rows_by_shard) / mean)

    @property
    def lag_mean_ticks(self) -> float:
        return self.lag_sum_ticks / max(self.lag_rows, 1)

    def summary(self) -> str:
        return (f"rows={self.n_rows} shards={len(self.rows_by_shard)} "
                f"imbalance={self.imbalance:.2f} "
                f"lag_mean={self.lag_mean_ticks:.1f}t "
                f"lag_max={self.lag_max_ticks}t "
                f"ingests={self.ingest_dispatches} "
                f"queries={self.query_dispatches} "
                f"spills={self.spill_events} "
                f"dequantizes={self.dequantize_events} "
                f"standing={self.standing_queries} "
                f"refreshes={self.standing_refreshes} "
                f"alerts={self.alerts_fired}/{self.alerts_checked}")


def store_obs_init() -> Dict[str, int]:
    """Fresh host-side counter dict for a store instance."""
    return {"ingest_dispatches": 0, "query_dispatches": 0,
            "lag_rows": 0, "lag_sum_ticks": 0, "lag_max_ticks": 0,
            "standing_queries": 0, "standing_refreshes": 0,
            "alerts_checked": 0, "alerts_fired": 0}


def store_obs_batch(obs: Dict[str, int], n_streams: int, T: int) -> None:
    """Record one fused-batch ingest: ``n_streams`` streams of ``T``
    sequential segments became queryable together, so per stream the
    lag over its rows is 0..T-1 (sum T*(T-1)/2, max T-1)."""
    obs["ingest_dispatches"] += 1
    obs["lag_rows"] += n_streams * T
    obs["lag_sum_ticks"] += n_streams * (T * (T - 1) // 2)
    obs["lag_max_ticks"] = max(obs["lag_max_ticks"], T - 1)


def store_obs_tick(obs: Dict[str, int], n_rows: int) -> None:
    """Record one per-tick ingest: rows are queryable the tick they
    land — lag 0."""
    obs["ingest_dispatches"] += 1
    obs["lag_rows"] += n_rows

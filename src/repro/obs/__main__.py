import sys

from repro.obs.run import main

sys.exit(main())

"""Observability: on-device run telemetry + host-side dispatch tracing.

Two layers (see ISSUE 8 / README "Observability"):

1. **Telemetry** — a fixed-shape counter pytree threaded through the
   fused engines' scan carries (``telemetry=True``), bit-exact against
   the ``telemetry_ref`` numpy mirror, adding zero dispatches and zero
   recompiles to the warm path (an auditor-pinned invariant).
2. **Tracing** — ``python -m repro.obs`` wraps every analysis-registry
   engine in wall-clock spans with jit-cache-probe recompile
   accounting, emits Chrome-trace JSON, and gates ``OBS.json``
   regressions exactly like ``ANALYSIS.json``/``BENCH_*.json``.
"""
from repro.obs.telemetry import (HostTelemetry, StoreTelemetry, Telemetry,
                                 TEL_KEYS, telemetry_ref)
from repro.obs.trace import traceable_engine_names, validate_chrome_trace

__all__ = ["HostTelemetry", "StoreTelemetry", "Telemetry", "TEL_KEYS",
           "telemetry_ref", "traceable_engine_names",
           "validate_chrome_trace"]

"""Host-side dispatch tracing over the analysis registry.

Every engine the PR-5 auditor verifies is also *traceable*: the tracer
builds the engine's tiny example, runs it with wall-clock spans around
the cold (compile) and warm calls, brackets each call with the engine's
jit-cache probe (so a recompile shows up as a counted event, not a
mystery latency), sizes the argument/output pytrees, and counts
host-transfer ops in the compiled HLO. Spans are emitted in Chrome
trace-event format (load ``OBS_TRACE.json`` in ``chrome://tracing`` /
Perfetto) and aggregated into the ``OBS.json`` report that
``python -m repro.obs --compare`` gates regressions against.

Scanner ships per-stage profiling as a first-class feature of its
pipeline runtime; this is the equivalent for a stack whose "stages"
are compiled programs — the unit of observation is the dispatch.
"""
from __future__ import annotations

import json
import statistics
import time
from typing import Dict, List, Optional, Tuple

import jax

from repro.analysis import registry
from repro.analysis.hlo_audit import audit_hlo


def traceable_engine_names() -> set:
    """Engines the tracer covers: every registry entry with a jit-cache
    probe (without one, recompiles inside a span are unobservable, so
    the engine does not count as traced — the coverage lint in
    ``repro.analysis`` flags it)."""
    registry.import_engine_modules()
    return {name for name, e in registry.engines().items()
            if e.probe is not None}


def _tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        size = getattr(leaf, "size", None)
        dtype = getattr(leaf, "dtype", None)
        if size is not None and dtype is not None:
            total += int(size) * int(dtype.itemsize)
    return total


def _host_transfer_count(ex: registry.EngineExample) -> int:
    """Host-transfer ops surviving in the compiled module (infeed /
    outfeed / is_host_transfer sends / host callbacks) — counted via the
    same detector the HLO audit uses."""
    hlo = ex.fn.lower(*ex.args, **ex.kwargs).compile().as_text()
    violations, _info = audit_hlo(hlo, {"no_host_transfers": True})
    return sum(1 for v in violations if v["check"] == "host_transfer")


class SpanRecorder:
    """Collects Chrome trace events against one wall-clock origin."""

    def __init__(self):
        self.origin = time.perf_counter()
        self.events: List[Dict] = []

    def span(self, name: str, cat: str, t_start: float, t_end: float,
             tid: int, args: Optional[Dict] = None) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": (t_start - self.origin) * 1e6,
            "dur": max((t_end - t_start) * 1e6, 0.01),
            "pid": 0, "tid": tid, "args": args or {}})

    def chrome_trace(self) -> Dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}


def trace_engine(name: str, engine: registry.Engine, rec: SpanRecorder,
                 tid: int, reps: int = 3, with_hlo: bool = True) -> Dict:
    """Trace one engine: cold span (compile + first run), ``reps`` warm
    spans, probe deltas, byte sizes, host-transfer count. Returns the
    engine's OBS.json record."""
    try:
        ex = engine.build()
    except registry.SkipEngine as e:
        return {"skipped": str(e)}

    probe = engine.probe or (lambda: 0)
    p0 = probe()
    t0 = time.perf_counter()
    out = jax.block_until_ready(ex.fn(*ex.args, **ex.kwargs))
    t1 = time.perf_counter()
    p1 = probe()
    rec.span(f"{name}:cold", "compile+run", t0, t1, tid,
             {"new_executables": p1 - p0})

    spans_us = []
    recompiles = 0
    for i in range(max(reps, 1)):
        q0 = probe()
        s0 = time.perf_counter()
        out = jax.block_until_ready(ex.fn(*ex.args, **ex.kwargs))
        s1 = time.perf_counter()
        q1 = probe()
        recompiles += q1 - q0
        spans_us.append((s1 - s0) * 1e6)
        rec.span(name, "dispatch", s0, s1, tid,
                 {"call": i, "recompiles": q1 - q0})

    record = {
        "cold_us": (t1 - t0) * 1e6,
        "span_us": statistics.median(spans_us),
        "span_min_us": min(spans_us),
        "new_executables": int(p1 - p0),
        "recompiles": int(recompiles),
        "arg_bytes": _tree_bytes((ex.args, ex.kwargs)),
        "out_bytes": _tree_bytes(out),
    }
    if with_hlo:
        record["host_transfers"] = _host_transfer_count(ex)
    return record


def trace_all(only: Optional[str] = None, reps: int = 3,
              with_hlo: bool = True) -> Tuple[Dict[str, Dict], Dict]:
    """Trace every registered engine (optionally substring-filtered).
    Returns ``(records, chrome_trace)``."""
    registry.import_engine_modules()
    engines = registry.engines()
    if only:
        engines = {k: v for k, v in engines.items() if only in k}
    rec = SpanRecorder()
    records: Dict[str, Dict] = {}
    for tid, (name, engine) in enumerate(engines.items()):
        records[name] = trace_engine(name, engine, rec, tid, reps=reps,
                                     with_hlo=with_hlo)
    return records, rec.chrome_trace()


def validate_chrome_trace(trace: Dict) -> List[str]:
    """Structural problems of a Chrome trace dict (empty list = valid:
    serializable, required keys present, durations non-negative)."""
    problems = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        if ev.get("ph") == "X" and ev.get("dur", 0) < 0:
            problems.append(f"event {i}: negative dur")
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as e:
        problems.append(f"not JSON-serializable: {e}")
    return problems

"""``python -m repro.obs`` — the observability report driver.

Traces every registry engine (see ``repro.obs.trace``), writes

- ``OBS.json``      aggregated per-engine metrics (committed baseline),
- ``OBS_TRACE.json`` the Chrome-trace span timeline (open in
  ``chrome://tracing`` or Perfetto; regenerated, not committed),

and with ``--compare OLD.json`` exits non-zero on regressions —
mirroring the ``ANALYSIS.json`` / ``BENCH_*.json`` gating pattern:

- **ceilings** (structural, host-independent, zero headroom): a warm
  recompile, a host-transfer op, or extra executables vs baseline;
- **span-time floors** (timings, host-class-gated like the bench
  floors): a span that slowed >20% vs baseline fails — but only when
  both snapshots come from the same host class AND the baseline span
  is above ``SPAN_FLOOR_US`` (micro-spans are pure noise);
- a baseline engine that disappears (or degrades to skipped) fails —
  a gate that goes green when its engine vanishes is no gate.

Topology changes (e.g. the forced-8-device tier1 leg) skip per-engine
numeric gates, exactly like the analysis compare.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

import jax

from repro.obs.trace import trace_all

SCHEMA = 1
SPAN_FLOOR_US = 5000.0       # gate span growth only above this baseline
SPAN_GROWTH = 0.20           # >20% slower than baseline fails
_CEILINGS = ("new_executables", "recompiles", "host_transfers")


def run_obs(only=None, reps: int = 3, with_hlo: bool = True) -> Dict:
    """Trace the registry; return ``(report, chrome_trace)``."""
    records, trace = trace_all(only=only, reps=reps, with_hlo=with_hlo)
    report = {
        "schema": SCHEMA,
        "topology": {"n_devices": jax.device_count()},
        "host": {"host_cores": float(os.cpu_count() or 1)},
        "engines": records,
        "n_engines": len(records),
        "n_skipped": sum(1 for r in records.values() if "skipped" in r),
    }
    return report, trace


def compare(new: Dict, old: Dict) -> List[str]:
    """Regressions of ``new`` vs a committed ``OBS.json`` baseline."""
    regressions: List[str] = []
    if new.get("topology") != old.get("topology"):
        print(f"[obs] topology changed {old.get('topology')} -> "
              f"{new.get('topology')}; skipping per-engine gates",
              file=sys.stderr)
        return regressions
    old_cores = old.get("host", {}).get("host_cores")
    new_cores = new.get("host", {}).get("host_cores")
    same_host = (old_cores is None or new_cores is None
                 or old_cores == new_cores)
    if not same_host:
        print(f"[obs] host class changed ({old_cores:.0f} -> "
              f"{new_cores:.0f} cores): span floors advisory, "
              f"ceilings still gated", file=sys.stderr)
    for name, old_rec in sorted(old.get("engines", {}).items()):
        if "skipped" in old_rec:
            continue
        new_rec = new.get("engines", {}).get(name)
        if new_rec is None:
            regressions.append(f"engine {name!r} disappeared from trace")
            continue
        if "skipped" in new_rec:
            regressions.append(
                f"engine {name!r} now skipped: {new_rec['skipped']}")
            continue
        for key in _CEILINGS:
            ov, nv = old_rec.get(key), new_rec.get(key)
            if isinstance(ov, (int, float)) \
                    and isinstance(nv, (int, float)) and nv > ov:
                regressions.append(
                    f"{name}: {key} grew {ov} -> {nv} [ceiling]")
        ov, nv = old_rec.get("span_us"), new_rec.get("span_us")
        if same_host and isinstance(ov, (int, float)) \
                and isinstance(nv, (int, float)) \
                and ov >= SPAN_FLOOR_US \
                and nv > ov * (1.0 + SPAN_GROWTH):
            regressions.append(
                f"{name}: span_us slowed {ov:.0f} -> {nv:.0f} "
                f"(>{SPAN_GROWTH:.0%}) [floor]")
    return regressions


def _summary(report: Dict) -> str:
    lines = [f"obs: {report['n_engines']} engines traced "
             f"({report['n_skipped']} skipped, "
             f"{report['topology']['n_devices']} devices)"]
    for name, rec in report["engines"].items():
        if "skipped" in rec:
            lines.append(f"  {name:30s} SKIP ({rec['skipped']})")
            continue
        lines.append(
            f"  {name:30s} span={rec['span_us']:9.1f}us "
            f"cold={rec['cold_us']:10.1f}us "
            f"exec+{rec['new_executables']} "
            f"recompile={rec['recompiles']} "
            f"hosttx={rec.get('host_transfers', '?')} "
            f"out={rec['out_bytes']}B")
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI for the dispatch tracer (``python -m repro.obs``): runs every
    registered engine under the tracer, writes OBS.json + a Chrome
    trace, and regression-gates against ``--compare``."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="dispatch tracer over every registered engine: "
                    "Chrome-trace spans + regression-gated OBS.json")
    ap.add_argument("--json", default="OBS.json",
                    help="report path (default ./OBS.json)")
    ap.add_argument("--trace", default="OBS_TRACE.json",
                    help="Chrome-trace output path "
                         "(default ./OBS_TRACE.json)")
    ap.add_argument("--compare", metavar="OLD",
                    help="fail on regressions vs a baseline OBS.json")
    ap.add_argument("--only", help="substring filter on engine names "
                                   "(debug; compare gates still apply "
                                   "to the traced subset)")
    ap.add_argument("--smoke", action="store_true",
                    help="single warm rep per engine (CI smoke; "
                         "structural gates only in practice)")
    ap.add_argument("--reps", type=int, default=None,
                    help="warm calls per engine (default 3; smoke 1)")
    args = ap.parse_args(argv)

    old = None
    if args.compare:
        with open(args.compare) as fh:
            old = json.load(fh)

    reps = args.reps if args.reps is not None else (1 if args.smoke else 3)
    report, trace = run_obs(only=args.only, reps=reps)
    print(_summary(report))

    with open(args.json, "w") as fh:
        json.dump(report, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"[obs] wrote {args.json}")
    with open(args.trace, "w") as fh:
        json.dump(trace, fh)
        fh.write("\n")
    print(f"[obs] wrote {len(trace['traceEvents'])} spans to {args.trace}")

    rc = 0
    if old is not None:
        regs = compare(report, old)
        for r in regs:
            print(f"[obs] REGRESSION: {r}")
        if regs:
            rc = 1
        else:
            print(f"[obs] compare vs {args.compare}: OK")
    return rc


if __name__ == "__main__":
    sys.exit(main())

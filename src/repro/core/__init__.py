"""Skyscraper — the paper's contribution: content-adaptive knob tuning
with throughput guarantees for V-ETL (see DESIGN.md §1)."""
from repro.core.api import Skyscraper
from repro.core.categories import classify_1d, classify_full, kmeans
from repro.core.forecaster import forecast, init_forecaster, train_forecaster
from repro.core.ingest import (RunResult, best_static_config,
                               run_chameleon_star, run_optimum,
                               run_skyscraper, run_static,
                               run_videostorm_like)
from repro.core.offline import Fitted, fit
from repro.core.planner import (plan_value, solve_lp_lagrangian,
                                solve_lp_scipy)
from repro.core.switcher import SwitchTables, init_state, switch_step

__all__ = [
    "Skyscraper", "classify_1d", "classify_full", "kmeans", "forecast",
    "init_forecaster", "train_forecaster", "RunResult", "best_static_config",
    "run_chameleon_star", "run_optimum", "run_skyscraper", "run_static",
    "run_videostorm_like", "Fitted", "fit", "plan_value",
    "solve_lp_lagrangian", "solve_lp_scipy", "SwitchTables", "init_state",
    "switch_step",
]

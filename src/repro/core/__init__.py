"""Skyscraper — the paper's contribution: content-adaptive knob tuning
with throughput guarantees for V-ETL (see DESIGN.md §1)."""
from repro.core.api import Skyscraper, SkyscraperPool
from repro.core.categories import classify_1d, classify_full, kmeans
from repro.core.forecaster import (forecast, forecast_from_labels,
                                   init_forecaster, train_forecaster)
from repro.core.ingest import (RunResult, best_static_config,
                               run_chameleon_star, run_optimum,
                               run_skyscraper, run_skyscraper_fused,
                               run_skyscraper_multi,
                               run_skyscraper_multi_windowed,
                               run_static, run_videostorm_like)
from repro.core.offline import Fitted, fit
from repro.core.planner import (plan_value, solve_lp_lagrangian,
                                solve_lp_rationed, solve_lp_scipy,
                                solve_lp_stacked, solve_multi_stream)
from repro.core.switcher import (SwitchTables, init_state, init_state_multi,
                                 pad_window, run_window, run_window_multi,
                                 stack_tables, switch_step, switch_step_multi)
# the Load side (paper §2): every engine above accepts a SegmentStore
# ``sink=`` so ingested runs land in the queryable warehouse. Submodule
# imports (not the repro.warehouse package) keep the import graph
# acyclic: warehouse.query pulls repro.core.switcher back in.
from repro.warehouse.store import SegmentStore
from repro.warehouse.tiers import TieredStore

__all__ = [
    "SegmentStore", "TieredStore",
    "Skyscraper", "SkyscraperPool", "classify_1d", "classify_full", "kmeans",
    "forecast", "forecast_from_labels", "init_forecaster",
    "train_forecaster", "RunResult", "best_static_config",
    "run_chameleon_star", "run_optimum", "run_skyscraper",
    "run_skyscraper_fused", "run_skyscraper_multi",
    "run_skyscraper_multi_windowed", "run_static",
    "run_videostorm_like", "Fitted", "fit", "plan_value",
    "solve_lp_lagrangian", "solve_lp_rationed", "solve_lp_scipy",
    "solve_lp_stacked", "solve_multi_stream",
    "SwitchTables", "init_state", "init_state_multi", "pad_window",
    "run_window", "run_window_multi", "stack_tables", "switch_step",
    "switch_step_multi",
]

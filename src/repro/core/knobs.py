"""Knob semantics for the paper's workloads (§5.2, App. J): every knob
configuration maps to (a) per-task duration multipliers for the placement
simulator and (b) a scalar *power* in (0,1] — the config's intrinsic
ability to handle difficult content. Ground-truth segment quality is
qual = 1 - difficulty * (1 - power): cheap configs are only penalized on
difficult content, matching the paper's premise.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Tuple

from repro.configs.workloads import WorkloadCfg

SIZE_MULT = {"small": 0.35, "medium": 0.65, "large": 1.0}
SIZE_POW = {"small": 0.75, "medium": 0.9, "large": 1.0}


def enumerate_configs(w: WorkloadCfg) -> List[Dict]:
    """Cartesian product of the workload's knob values, one dict per
    config (the K axis of every fitted table)."""
    names = list(w.knobs)
    out = []
    for vals in itertools.product(*(w.knobs[n] for n in names)):
        out.append(dict(zip(names, vals)))
    return out


def task_multipliers(w: WorkloadCfg, kv: Dict) -> Dict[str, float]:
    """Per-task compute multipliers a knob setting induces on the
    workload's DAG (frame rate, tiling, detection interval, ...)."""
    m: Dict[str, float] = {}
    if w.name == "covid":
        fr = kv["frame_rate"] / 30.0
        m = {"decode": 1.0, "yolo": fr * kv["tiling"] / kv["det_interval"],
             "kcf": fr, "homography": fr, "mask_cls": fr / kv["det_interval"]}
    elif w.name == "mot":
        fr = kv["frame_rate"] / 30.0
        sz = SIZE_MULT[kv["model_size"]]
        hist = 0.7 + 0.3 * kv["history"]
        m = {"decode": 1.0, "detect": fr * kv["tiling"],
             "embed": fr * sz, "graph_tf": fr * sz * hist}
    elif w.name.startswith("mosei"):
        act = 1.0 / (1 + kv["sent_skip"])
        frac = kv["frac_frames"] / 6.0
        sz = SIZE_MULT[kv["model_size"]]
        m = {"asr": 1.0, "glove": act, "face": act * frac,
             "acoustic": act * frac, "fuse_cls": act * sz}
    return m


def config_power(w: WorkloadCfg, kv: Dict) -> float:
    """Scalar 'power' of a knob setting: the 1-D accuracy proxy the
    quality model discounts by content difficulty (Eq. 5)."""
    if w.name == "covid":
        return ((kv["frame_rate"] / 30.0) ** 0.25
                * (1.0 / kv["det_interval"]) ** 0.3
                * (1.0 if kv["tiling"] == 4 else 0.82))
    if w.name == "mot":
        return ((kv["frame_rate"] / 30.0) ** 0.25
                * (1.0 if kv["tiling"] == 4 else 0.85)
                * (0.8 + 0.05 * kv["history"])
                * SIZE_POW[kv["model_size"]])
    # mosei
    return ((1.0 / (1 + kv["sent_skip"])) ** 0.3
            * (kv["frac_frames"] / 6.0) ** 0.3
            * SIZE_POW[kv["model_size"]])


def config_work(w: WorkloadCfg, kv: Dict, fps: float = 30.0) -> float:
    """On-prem core-seconds per segment when everything runs locally.

    DAG task times are per frame at the source rate; the knob multipliers
    already fold in frame-rate / interval / size scaling, so per-segment
    work = sum(on_ms * mult) * fps * segment_seconds / 1e3.
    """
    m = task_multipliers(w, kv)
    total_ms = sum(on_ms * m.get(name, 1.0)
                   for name, _, on_ms, _, _, _ in w.dag)
    return total_ms / 1e3 * fps * w.segment_seconds


# Even the most powerful config degrades somewhat on difficult content
# (e.g. YOLO certainty drops under heavy occlusion at any resolution) —
# this keeps every config's quality discriminative across categories,
# which is the premise of the paper's 1-D content classifier (Eq. 5).
QUALITY_DISCOUNT = 0.85


def quality(power, difficulty):
    """Eq. 5 quality model: clip(1 - difficulty*(1 - 0.85*power), 0, 1)."""
    import numpy as np
    return np.clip(1.0 - difficulty * (1.0 - QUALITY_DISCOUNT * power),
                   0.0, 1.0)

"""Knob switcher (paper §4.2) — reactive, jit-compiled, O(µs)/decision.

Per segment:
 1. classify current content from the running config's reported quality
    (Eq. 5 — one KMeans dimension);
 2. pick the config with the largest planned-minus-actual usage deficit
    (Eq. 6);
 3. pick the cheapest placement that cannot overflow the buffer,
    recursively degrading to less-qualitative configs if necessary
    (vectorized here as a masked argmin instead of a loop).

The throughput guarantee: the cheapest config's all-on-prem placement is
validated real-time at fit(); it is always feasible, so the buffer can
never overflow.

Batched multi-stream engine (paper App. D): ``SwitchTables`` is a JAX
pytree, so V streams' tables stack leaf-wise into one table with a
leading (V,) axis (``stack_tables``) and the whole structure passes
straight through ``jax.jit`` / ``jax.vmap`` without field-unpacking.
``run_window_multi`` vmaps the per-segment decision over the stream axis
and drives all V streams through a SINGLE fused ``lax.scan`` — one
dispatch per window instead of V. ``run_window`` accepts an optional
validity mask so tail windows can be padded to a fixed length (masked
steps are exact no-ops), which keeps every window the same shape and
eliminates per-window recompiles.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, fields
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

BIG = jnp.int32(10 ** 6)


@dataclass
class SwitchTables:
    """Device-resident lookup tables the online switcher steps against
    (quality centers, cost/placement tables, rank order, thresholds) —
    a pytree so multi-stream code can ``stack_tables`` a batch."""
    centers: jnp.ndarray      # (C, K) mean quality of config k on category c
    power: jnp.ndarray        # (K,)
    cost: jnp.ndarray         # (K,) all-on-prem core-s / segment
    place_rt: jnp.ndarray     # (K, P) wall seconds / segment
    place_on: jnp.ndarray     # (K, P) on-prem core-s
    place_cl: jnp.ndarray     # (K, P) cloud core-s
    place_valid: jnp.ndarray  # (K, P) bool
    rank_pos: jnp.ndarray     # (K,) 0 = most qualitative
    tau: float                # segment seconds
    buffer_cap_s: float       # buffer size in seconds of video
    cloud_budget: float       # total cloud core-s for the run

    @property
    def n_categories(self):
        return self.centers.shape[0]

    @property
    def n_configs(self):
        return self.centers.shape[1]


_TABLE_FIELDS = tuple(f.name for f in fields(SwitchTables))


def _tables_flatten(t: SwitchTables):
    return tuple(getattr(t, n) for n in _TABLE_FIELDS), None


def _tables_unflatten(_, children):
    return SwitchTables(*children)


# Every field is a leaf (tau/buffer_cap_s/cloud_budget included), so
# tables stack per-stream — heterogeneous budgets become (V,) leaves —
# and the whole dataclass is a valid jit/vmap/scan argument.
jax.tree_util.register_pytree_node(SwitchTables, _tables_flatten,
                                   _tables_unflatten)


def stack_tables(tables: List[SwitchTables]) -> SwitchTables:
    """Stack V streams' tables leaf-wise onto a leading (V,) axis.
    Python-float scalar fields (tau etc.) stack to STRONGLY-typed f32
    leaves so carried table stacks round-trip through jitted admission
    edits with stable avals (no weak->strong recompiles)."""
    def stk(*xs):
        out = jnp.stack([jnp.asarray(x) for x in xs])
        return out.astype(out.dtype) if out.weak_type else out
    return jax.tree.map(stk, *tables)


def init_state(tables: SwitchTables) -> Dict:
    """Fresh per-stream switcher state (usage stats, buffer, cloud
    spend, current config = most qualitative)."""
    C, K = tables.centers.shape
    return {
        "used": jnp.zeros((C, K), jnp.float32),
        "count": jnp.zeros((C,), jnp.float32),
        "buffer_s": jnp.float32(0.0),
        "cloud_spent": jnp.float32(0.0),
        "k_cur": jnp.int32(int(jnp.argmin(tables.rank_pos))),
        "qual_prev": jnp.float32(1.0),
    }


def init_state_multi(tables: List[SwitchTables]) -> Dict:
    """Batched state for V streams: each leaf gains a leading (V,) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[init_state(t) for t in tables])


def _switch(state, qual_row, arrival, alpha, tables: SwitchTables):
    """One knob-switching decision (pure function of pytrees; vmappable
    over a leading stream axis on every argument)."""
    tau = jnp.asarray(tables.tau, jnp.float32)
    cap = jnp.asarray(tables.buffer_cap_s, jnp.float32)
    cloud_budget = jnp.asarray(tables.cloud_budget, jnp.float32)
    # 1. classify from previous segment's reported quality (Eq. 5)
    col = jnp.take(tables.centers, state["k_cur"], axis=1)
    c = jnp.argmin(jnp.abs(col - state["qual_prev"]))
    # 2. usage-deficit pick (Eq. 6)
    frac = state["used"][c] / jnp.maximum(state["count"][c], 1.0)
    k_next = jnp.argmax(alpha[c] - frac)
    # 3. placement feasibility
    rt_eff = tables.place_rt * arrival
    headroom = tau + (cap - state["buffer_s"])
    feas = (tables.place_valid
            & (rt_eff <= headroom)
            & (state["cloud_spent"] + tables.place_cl * arrival
               <= cloud_budget))
    feas_k = feas.any(axis=1)
    cl_masked = jnp.where(feas, tables.place_cl, jnp.inf)
    p_best = jnp.argmin(cl_masked, axis=1)                       # (K,)
    eligible = tables.rank_pos >= tables.rank_pos[k_next]
    cand = feas_k & eligible
    pos1 = jnp.where(cand, tables.rank_pos, BIG)
    pos2 = jnp.where(feas_k, tables.rank_pos, BIG)
    k_sel = jnp.where(cand.any(), jnp.argmin(pos1), jnp.argmin(pos2))
    p_sel = p_best[k_sel]
    # overload shedding: if NO config/placement fits (arrival spike above
    # peak provisioning), drop the segment — Eq. 1 must hold universally
    # (the streaming-ETL load-shedding fallback; quality 0 for the drop)
    any_feas = feas_k.any()
    rt = jnp.where(any_feas, rt_eff[k_sel, p_sel], 0.0)
    on_s = jnp.where(any_feas, tables.place_on[k_sel, p_sel] * arrival, 0.0)
    cl_s = jnp.where(any_feas, tables.place_cl[k_sel, p_sel] * arrival, 0.0)
    qual = jnp.where(any_feas, qual_row[k_sel], 0.0)
    new_state = {
        "used": state["used"].at[c, k_sel].add(1.0),
        "count": state["count"].at[c].add(1.0),
        "buffer_s": jnp.maximum(state["buffer_s"] + rt - tau, 0.0),
        "cloud_spent": state["cloud_spent"] + cl_s,
        "k_cur": k_sel.astype(jnp.int32),
        "qual_prev": qual,
    }
    out = {"k": k_sel, "p": p_sel, "c": c, "qual": qual, "on_s": on_s,
           "cl_s": cl_s, "buffer_s": new_state["buffer_s"], "rt": rt,
           "dropped": ~any_feas}
    return new_state, out


def _masked_switch(state, qual_row, arrival, valid, alpha,
                   tables: SwitchTables):
    """_switch, but a ``valid=False`` step is an exact no-op: state is
    untouched and every output is zeroed (padding segments contribute
    nothing to quality, work, or buffer)."""
    new_state, out = _switch(state, qual_row, arrival, alpha, tables)
    keep = jnp.asarray(valid, bool)
    new_state = jax.tree.map(
        lambda new, old: jnp.where(keep, new, old), new_state, state)
    zero = {"k": jnp.int32(0), "p": jnp.int32(0), "c": jnp.int32(0),
            "qual": jnp.float32(0.0), "on_s": jnp.float32(0.0),
            "cl_s": jnp.float32(0.0), "buffer_s": state["buffer_s"],
            "rt": jnp.float32(0.0), "dropped": jnp.asarray(False)}
    out = jax.tree.map(lambda o, z: jnp.where(keep, o, z), out, zero)
    return new_state, out


_switch_jit = jax.jit(_switch)
_switch_multi_jit = jax.jit(jax.vmap(_switch))


def switch_step(state, qual_row, arrival, alpha, tables: SwitchTables):
    """One knob-switching decision. qual_row (K,) = measured qualities of
    this segment (only qual_row[k_sel] is observed by the system). The
    tables pytree is passed straight to jit — no field unpacking."""
    return _switch_jit(state, qual_row, arrival, alpha, tables)


def switch_step_multi(state, qual_rows, arrivals, alpha,
                      tables: SwitchTables):
    """One batched decision for V live streams in a single dispatch:
    state from ``init_state_multi``, qual_rows (V,K), arrivals (V,),
    alpha (V,C,K), tables stacked via ``stack_tables``."""
    return _switch_multi_jit(state, qual_rows, arrivals, alpha, tables)


def window_scan(state, quals, arrivals, valid, alpha, tables):
    """Pure (un-jitted) window body: the masked-switch ``lax.scan`` over
    one planning window. Reusable INSIDE an outer scan — the fused
    whole-run engine (``ingest.run_skyscraper_fused``) inlines this as
    its per-window step, so forecast→plan→switch lowers to one program.
    """
    def body(st, inp):
        q_row, arr, v = inp
        return _masked_switch(st, q_row, arr, v, alpha, tables)

    return jax.lax.scan(body, state, (quals, arrivals, valid))


_run_window = jax.jit(window_scan)


def run_window(state, quals, arrivals, alpha, tables: SwitchTables,
               valid: Optional[jnp.ndarray] = None):
    """lax.scan over a planning window. quals (T,K); arrivals (T,);
    valid (T,) bool — False marks padding segments (exact no-ops).

    Top-level jitted: repeated windows of the same length compile once.
    """
    if valid is None:
        valid = jnp.ones(quals.shape[:1], bool)
    return _run_window(state, quals, arrivals, valid, alpha, tables)


def pad_window(quals, arrivals, W: int):
    """Pad a (T,K)/(T,) window to length W, returning (quals, arrivals,
    valid). With a fixed W every window — including the short tail —
    lowers to the same jaxpr, so the scan compiles exactly once."""
    T = quals.shape[0]
    if T == W:
        return quals, arrivals, jnp.ones((W,), bool)
    pad = W - T
    quals = jnp.pad(quals, ((0, pad), (0, 0)))
    arrivals = jnp.pad(arrivals, (0, pad), constant_values=1.0)
    valid = jnp.arange(W) < T
    return quals, arrivals, valid


def pad_window_multi(quals, arrivals, W: int):
    """Batched pad_window: quals (V,T,K), arrivals (V,T) -> padded to W
    along the time axis with a (V,W) validity mask."""
    V, T = arrivals.shape
    valid = jnp.broadcast_to(jnp.arange(W) < T, (V, W))
    if T == W:
        return quals, arrivals, valid
    pad = W - T
    quals = jnp.pad(quals, ((0, 0), (0, pad), (0, 0)))
    arrivals = jnp.pad(arrivals, ((0, 0), (0, pad)), constant_values=1.0)
    return quals, arrivals, valid


def window_scan_multi(state, quals, arrivals, valid, alpha, tables):
    """Pure (un-jitted) batched window body — reusable inside an outer
    scan (the fused multi-stream engine). vmaps the decision over the
    leading stream axis of EVERY pytree — batched state {used:(V,C,K),
    buffer_s:(V,), ...}, (V,C,K) alpha stack, and stacked tables — then
    scans once over time."""
    vstep = jax.vmap(_masked_switch)

    def body(st, inp):
        q_row, arr, v = inp                         # (V,K), (V,), (V,)
        return vstep(st, q_row, arr, v, alpha, tables)

    # scan iterates the leading axis: feed time-major (T,V,...) slices
    xs = (jnp.swapaxes(quals, 0, 1), jnp.swapaxes(arrivals, 0, 1),
          jnp.swapaxes(valid, 0, 1))
    state, outs = jax.lax.scan(body, state, xs)
    outs = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), outs)  # (V,T,...)
    return state, outs


_run_window_multi = jax.jit(window_scan_multi)


def run_window_multi(state, quals, arrivals, alpha,
                     tables: SwitchTables,
                     valid: Optional[jnp.ndarray] = None):
    """Batched multi-stream window: ONE fused lax.scan executes all V
    streams' switch decisions per time step.

    state: batched pytree from ``init_state_multi`` (leading (V,) axis);
    quals (V,T,K); arrivals (V,T); alpha (V,C,K); tables stacked via
    ``stack_tables``; valid (V,T) bool marks padding (exact no-ops).
    Returns (batched state, outs with (V,T) leaves).
    """
    if valid is None:
        valid = jnp.ones(arrivals.shape, bool)
    return _run_window_multi(state, quals, arrivals, valid, alpha, tables)


def compile_cache_size() -> Tuple[int, int]:
    """(single-window, multi-window) jit cache entries — lets tests and
    benchmarks assert zero recompiles after warmup."""
    return _run_window._cache_size(), _run_window_multi._cache_size()


# Engine modules (fused ingest, serving pool) register their jitted
# entry points here so one probe covers every compiled program that
# could silently retrace.
_CACHE_PROBES = {
    "run_window": lambda: _run_window._cache_size(),
    "run_window_multi": lambda: _run_window_multi._cache_size(),
    "switch_step": lambda: _switch_jit._cache_size(),
    "switch_step_multi": lambda: _switch_multi_jit._cache_size(),
}


def register_cache_probe(name: str, probe) -> None:
    """Register a zero-arg callable reporting an engine's jit cache
    entry count under ``name`` in ``compile_cache_sizes()``."""
    _CACHE_PROBES[name] = probe


def compile_cache_sizes() -> Dict[str, int]:
    """Per-engine jit cache entry counts (a superset of
    ``compile_cache_size``): stable values across ticks/windows prove
    zero recompiles after warmup."""
    return {name: int(probe()) for name, probe in _CACHE_PROBES.items()}


# ---- static-analysis registry (see repro.analysis) -------------------------
from repro.analysis.registry import example_builder, register_engine  # noqa: E402

register_engine("switch_step", example_builder("switch_step"),
                probe=_CACHE_PROBES["switch_step"],
                covers=("repro.core.switcher:_switch_jit",),
                probe_name="switch_step")
register_engine("switch_step_multi", example_builder("switch_step_multi"),
                probe=_CACHE_PROBES["switch_step_multi"],
                covers=("repro.core.switcher:_switch_multi_jit",),
                probe_name="switch_step_multi")
register_engine("run_window", example_builder("run_window"),
                probe=_CACHE_PROBES["run_window"],
                covers=("repro.core.switcher:_run_window",),
                probe_name="run_window")
register_engine("run_window_multi", example_builder("run_window_multi"),
                probe=_CACHE_PROBES["run_window_multi"],
                covers=("repro.core.switcher:_run_window_multi",),
                probe_name="run_window_multi")

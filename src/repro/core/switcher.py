"""Knob switcher (paper §4.2) — reactive, jit-compiled, O(µs)/decision.

Per segment:
 1. classify current content from the running config's reported quality
    (Eq. 5 — one KMeans dimension);
 2. pick the config with the largest planned-minus-actual usage deficit
    (Eq. 6);
 3. pick the cheapest placement that cannot overflow the buffer,
    recursively degrading to less-qualitative configs if necessary
    (vectorized here as a masked argmin instead of a loop).

The throughput guarantee: the cheapest config's all-on-prem placement is
validated real-time at fit(); it is always feasible, so the buffer can
never overflow.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

BIG = jnp.int32(10 ** 6)


@dataclass
class SwitchTables:
    centers: jnp.ndarray      # (C, K) mean quality of config k on category c
    power: jnp.ndarray        # (K,)
    cost: jnp.ndarray         # (K,) all-on-prem core-s / segment
    place_rt: jnp.ndarray     # (K, P) wall seconds / segment
    place_on: jnp.ndarray     # (K, P) on-prem core-s
    place_cl: jnp.ndarray     # (K, P) cloud core-s
    place_valid: jnp.ndarray  # (K, P) bool
    rank_pos: jnp.ndarray     # (K,) 0 = most qualitative
    tau: float                # segment seconds
    buffer_cap_s: float       # buffer size in seconds of video
    cloud_budget: float       # total cloud core-s for the run

    @property
    def n_categories(self):
        return self.centers.shape[0]

    @property
    def n_configs(self):
        return self.centers.shape[1]


def init_state(tables: SwitchTables) -> Dict:
    C, K = tables.centers.shape
    return {
        "used": jnp.zeros((C, K), jnp.float32),
        "count": jnp.zeros((C,), jnp.float32),
        "buffer_s": jnp.float32(0.0),
        "cloud_spent": jnp.float32(0.0),
        "k_cur": jnp.int32(int(jnp.argmin(tables.rank_pos))),
        "qual_prev": jnp.float32(1.0),
    }


@functools.partial(jax.jit, static_argnames=("tab_static",))
def _switch(state, qual_row, arrival, alpha, centers, place_rt, place_on,
            place_cl, place_valid, rank_pos, tab_static):
    tau, cap, cloud_budget = tab_static
    # 1. classify from previous segment's reported quality (Eq. 5)
    col = jnp.take(centers, state["k_cur"], axis=1)
    c = jnp.argmin(jnp.abs(col - state["qual_prev"]))
    # 2. usage-deficit pick (Eq. 6)
    frac = state["used"][c] / jnp.maximum(state["count"][c], 1.0)
    k_next = jnp.argmax(alpha[c] - frac)
    # 3. placement feasibility
    rt_eff = place_rt * arrival
    headroom = tau + (cap - state["buffer_s"])
    feas = (place_valid
            & (rt_eff <= headroom)
            & (state["cloud_spent"] + place_cl * arrival <= cloud_budget))
    feas_k = feas.any(axis=1)
    cl_masked = jnp.where(feas, place_cl, jnp.inf)
    p_best = jnp.argmin(cl_masked, axis=1)                       # (K,)
    eligible = rank_pos >= rank_pos[k_next]
    cand = feas_k & eligible
    pos1 = jnp.where(cand, rank_pos, BIG)
    pos2 = jnp.where(feas_k, rank_pos, BIG)
    k_sel = jnp.where(cand.any(), jnp.argmin(pos1), jnp.argmin(pos2))
    p_sel = p_best[k_sel]
    # overload shedding: if NO config/placement fits (arrival spike above
    # peak provisioning), drop the segment — Eq. 1 must hold universally
    # (the streaming-ETL load-shedding fallback; quality 0 for the drop)
    any_feas = feas_k.any()
    rt = jnp.where(any_feas, rt_eff[k_sel, p_sel], 0.0)
    on_s = jnp.where(any_feas, place_on[k_sel, p_sel] * arrival, 0.0)
    cl_s = jnp.where(any_feas, place_cl[k_sel, p_sel] * arrival, 0.0)
    qual = jnp.where(any_feas, qual_row[k_sel], 0.0)
    new_state = {
        "used": state["used"].at[c, k_sel].add(1.0),
        "count": state["count"].at[c].add(1.0),
        "buffer_s": jnp.maximum(state["buffer_s"] + rt - tau, 0.0),
        "cloud_spent": state["cloud_spent"] + cl_s,
        "k_cur": k_sel.astype(jnp.int32),
        "qual_prev": qual,
    }
    out = {"k": k_sel, "p": p_sel, "c": c, "qual": qual, "on_s": on_s,
           "cl_s": cl_s, "buffer_s": new_state["buffer_s"], "rt": rt,
           "dropped": ~any_feas}
    return new_state, out


def switch_step(state, qual_row, arrival, alpha, tables: SwitchTables):
    """One knob-switching decision. qual_row (K,) = measured qualities of
    this segment (only qual_row[k_sel] is observed by the system)."""
    return _switch(state, qual_row, arrival, alpha, tables.centers,
                   tables.place_rt, tables.place_on, tables.place_cl,
                   tables.place_valid, tables.rank_pos,
                   (float(tables.tau), float(tables.buffer_cap_s),
                    float(tables.cloud_budget)))


def run_window(state, quals, arrivals, alpha, tables: SwitchTables):
    """lax.scan over a planning window. quals (T,K); arrivals (T,)."""
    tab_static = (float(tables.tau), float(tables.buffer_cap_s),
                  float(tables.cloud_budget))

    def body(st, inp):
        q_row, arr = inp
        return _switch(st, q_row, arr, alpha, tables.centers,
                       tables.place_rt, tables.place_on, tables.place_cl,
                       tables.place_valid, tables.rank_pos, tab_static)

    return jax.lax.scan(body, state, (quals, arrivals))

"""Content categories (paper §3.2): KMeans over |K|-dim quality vectors.

Categories are built so every knob configuration achieves similar quality
on content of the same category; online, the switcher classifies with a
SINGLE dimension (the running config's reported quality — Eq. 5), which
works because categories separate along every config's quality axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def kmeans_pp_init(Q: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """KMeans++ seeding (offline, numpy)."""
    rng = np.random.default_rng(seed)
    n = Q.shape[0]
    centers = [Q[rng.integers(n)]]
    for _ in range(k - 1):
        d2 = np.min(
            [np.sum((Q - c) ** 2, axis=1) for c in centers], axis=0)
        s = d2.sum()
        if not np.isfinite(s) or s <= 1e-12:
            centers.append(Q[rng.integers(n)])   # degenerate: uniform pick
            continue
        centers.append(Q[rng.choice(n, p=d2 / s)])
    return np.stack(centers)


@jax.jit
def _lloyd_step(centers, Q):
    d = jnp.sum((Q[:, None, :] - centers[None]) ** 2, axis=-1)
    assign = jnp.argmin(d, axis=1)
    oh = jax.nn.one_hot(assign, centers.shape[0], dtype=Q.dtype)
    counts = oh.sum(axis=0)
    sums = oh.T @ Q
    new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1)[:, None],
                    centers)
    return new, assign


def kmeans(Q, k: int, iters: int = 50, seed: int = 0):
    """Q (n, d) -> (centers (k, d), assignment (n,))."""
    Qn = np.asarray(Q, np.float32)
    centers = jnp.asarray(kmeans_pp_init(Qn, k, seed))
    Qj = jnp.asarray(Qn)
    for _ in range(iters):
        centers, assign = _lloyd_step(centers, Qj)
    # order centers by mean quality (ascending difficulty) for determinism
    order = jnp.argsort(centers.mean(axis=1))
    centers = centers[order]
    _, assign = _lloyd_step(centers, Qj)
    return centers, assign


@jax.jit
def classify_full(vec, centers):
    """Full-vector nearest center (offline labeling)."""
    return jnp.argmin(jnp.sum((centers - vec[None]) ** 2, axis=-1))


@jax.jit
def classify_1d(qual, k_idx, centers):
    """Paper Eq. 5: argmin_c |centers[c, k_cur] - qual|."""
    col = jnp.take(centers, k_idx, axis=1)
    return jnp.argmin(jnp.abs(col - qual))


from repro.analysis.registry import example_builder, register_engine  # noqa: E402
from repro.core.switcher import register_cache_probe  # noqa: E402

register_cache_probe("categories", lambda: (_lloyd_step._cache_size()
                                            + classify_full._cache_size()
                                            + classify_1d._cache_size()))
register_engine("kmeans_lloyd", example_builder("lloyd_step"),
                probe=lambda: _lloyd_step._cache_size(),
                covers=("repro.core.categories:_lloyd_step",),
                probe_name="categories")
register_engine("classify_full", example_builder("classify_full"),
                probe=lambda: classify_full._cache_size(),
                covers=("repro.core.categories:classify_full",),
                probe_name="categories")
register_engine("classify_1d", example_builder("classify_1d"),
                probe=lambda: classify_1d._cache_size(),
                covers=("repro.core.categories:classify_1d",),
                probe_name="categories")

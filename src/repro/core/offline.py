"""Offline preparation phase (paper §3, App. A/E/H).

fit() = profile + Pareto-filter knob configs (greedy hill climbing over
max-min-sampled segments, App. A.1), enumerate + Pareto-filter task
placements (App. A.2/M), build content categories (KMeans on quality
vectors, §3.2), train the forecasting model (§3.3), and validate the
throughput guarantee (cheapest config must run real-time on-prem).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.workloads import WorkloadCfg
from repro.core import knobs as KB
from repro.core.categories import kmeans
from repro.core.forecaster import (forecast, init_forecaster, make_dataset,
                                   train_forecaster)
from repro.core.placement import tasks_from_dag
from repro.core.switcher import SwitchTables
from repro.data.stream import Stream, generate

P_MAX = 8          # placement slots per config
UPLINK_MBS = 12.5  # 100 Mbit/s
RTT_S = 0.25


@dataclass
class Fitted:
    """Everything the offline phase produces: per-config power/cost and
    placement tables, the quality centers, forecaster params, and the
    selected config subset — the immutable input to every engine."""
    workload: WorkloadCfg
    configs: List[Dict]
    power: np.ndarray
    cost: np.ndarray
    place_rt: np.ndarray
    place_on: np.ndarray
    place_cl: np.ndarray
    place_valid: np.ndarray
    centers: np.ndarray
    forecaster: Dict
    n_split: int
    interval_segments: int
    horizon_segments: int
    n_cores: int
    timings: Dict[str, float] = field(default_factory=dict)
    forecast_metrics: Dict[str, float] = field(default_factory=dict)

    def tables(self, *, buffer_gb: float = 4.0, bitrate_Bps: float = 90e3,
               cloud_budget: float = 0.0) -> SwitchTables:
        tau = self.workload.segment_seconds
        rank = np.argsort(np.argsort(-self.power))       # 0 = most powerful
        return SwitchTables(
            centers=jnp.asarray(self.centers),
            power=jnp.asarray(self.power),
            cost=jnp.asarray(self.cost),
            place_rt=jnp.asarray(self.place_rt),
            place_on=jnp.asarray(self.place_on),
            place_cl=jnp.asarray(self.place_cl),
            place_valid=jnp.asarray(self.place_valid),
            rank_pos=jnp.asarray(rank, jnp.int32),
            tau=tau,
            buffer_cap_s=buffer_gb * 1e9 / bitrate_Bps,
            cloud_budget=cloud_budget,
        )


def _segment_placements(w: WorkloadCfg, kv: Dict, n_cores: int):
    """Throughput-mode placement costs per segment: for each subset of
    tasks offloaded, runtime = max(on_core_s/cores, uplink serialization)
    + RTT if any cloud task. Pareto on (runtime, cloud core-s)."""
    import itertools
    tasks = tasks_from_dag(w.dag)
    mult = KB.task_multipliers(w, kv)
    fps = 30.0
    frames = fps * w.segment_seconds
    per = []
    for t in tasks:
        m = mult.get(t.name, 1.0)
        per.append((t.onprem_ms * m * frames / 1e3,
                    t.cloud_ms * m * frames / 1e3,
                    t.mb_in * m * frames))
    n = len(tasks)
    cands = []
    for mask in itertools.product([0, 1], repeat=n):
        on_s = sum(p[0] for p, b in zip(per, mask) if not b)
        cl_s = sum(p[1] for p, b in zip(per, mask) if b)
        up_mb = sum(p[2] for p, b in zip(per, mask) if b)
        r = max(on_s / n_cores, up_mb / UPLINK_MBS) \
            + (RTT_S if any(mask) else 0.0)
        cands.append((r, cl_s, on_s))
    # pareto: sort by runtime, keep strictly-decreasing cloud cost
    cands.sort()
    pareto = []
    best_cl = float("inf")
    for r, c, o in cands:
        if c < best_cl - 1e-9:
            pareto.append((r, c, o))
            best_cl = c
    if len(pareto) > P_MAX:
        # even subsample but ALWAYS keep both endpoints — the last point
        # is the zero-cloud placement the throughput guarantee relies on
        idx = np.unique(np.linspace(0, len(pareto) - 1, P_MAX).astype(int))
        pareto = [pareto[i] for i in idx]
    rt = np.full(P_MAX, np.inf)
    on = np.zeros(P_MAX)
    cl = np.zeros(P_MAX)
    valid = np.zeros(P_MAX, bool)
    for i, (r, c, o) in enumerate(pareto):
        rt[i], cl[i], on[i], valid[i] = r, c, o, True
    return rt, on, cl, valid


def _hill_climb_pareto(w: WorkloadCfg, all_configs: List[Dict],
                       difficulties: np.ndarray, max_k: int = 12):
    """Greedy hill climbing (VideoStorm-style, App. A.1) per sampled
    segment; union of visited configs approximates the Pareto set."""
    powers = np.array([KB.config_power(w, kv) for kv in all_configs])
    costs = np.array([KB.config_work(w, kv) for kv in all_configs])
    names = list(w.knobs)
    idx_of = {tuple(kv[n] for n in names): i
              for i, kv in enumerate(all_configs)}

    def neighbors(kv):
        out = []
        for n in names:
            dom = list(w.knobs[n])
            i = dom.index(kv[n])
            for j in (i - 1, i + 1):
                if 0 <= j < len(dom):
                    kv2 = dict(kv)
                    kv2[n] = dom[j]
                    out.append(idx_of[tuple(kv2[x] for x in names)])
        return out

    selected = set()
    for d in difficulties:
        qual = 1.0 - d * (1.0 - powers)
        cur = int(np.argmin(costs))
        selected.add(cur)
        for _ in range(64):
            best, best_gain = None, 0.0
            for nb in neighbors(all_configs[cur]):
                dq = qual[nb] - qual[cur]
                dc = costs[nb] - costs[cur]
                if dq > 1e-9:
                    gain = dq / max(dc, 1e-6)
                    if gain > best_gain:
                        best, best_gain = nb, gain
            if best is None:
                break
            cur = best
            selected.add(cur)
    # thin to max_k keeping the cost-quality Pareto spread
    sel = sorted(selected, key=lambda i: costs[i])
    if len(sel) > max_k:
        keep = np.linspace(0, len(sel) - 1, max_k).astype(int)
        sel = [sel[i] for i in keep]
    return sel


def fit(w: WorkloadCfg, *, n_cores: int, days_unlabeled: float = 14.0,
        n_categories: int = 4, seed: int = 0, sample_frac: float = 0.05,
        n_search: int = 5, plan_days: float = 2.0, input_days: float = 2.0,
        n_split: int = 8, max_k: int = 12) -> Fitted:
    """Offline ETL fit (Sec. 4.1): profile configs on sampled segments,
    solve placements, cluster content categories, train the forecaster,
    and prune to ``max_k`` configs; returns the ``Fitted`` bundle."""
    t_all = {}
    rng = np.random.default_rng(seed)
    tau = w.segment_seconds

    # --- filter knob configurations (App. A.1) ---------------------------
    t0 = time.time()
    all_configs = KB.enumerate_configs(w)
    pre = generate(w, days=1.0, seed=seed + 7)
    n_pre = min(200, pre.n_segments)
    pre_d = pre.difficulty[rng.choice(pre.n_segments, n_pre, replace=False)]
    # greedy max-min sampling in (k-, k+) quality space == difficulty space
    chosen = [float(pre_d[np.argmin(np.abs(pre_d - pre_d.mean()))])]
    for _ in range(n_search - 1):
        dmin = np.min(np.abs(pre_d[:, None] - np.array(chosen)[None]), axis=1)
        chosen.append(float(pre_d[np.argmax(dmin)]))
    sel = _hill_climb_pareto(w, all_configs, np.array(chosen), max_k)
    configs = [all_configs[i] for i in sel]
    power = np.array([KB.config_power(w, kv) for kv in configs], np.float32)
    cost = np.array([KB.config_work(w, kv) for kv in configs], np.float32)
    t_all["filter_configs"] = time.time() - t0

    # --- filter task placements (App. A.2 / M) ---------------------------
    t0 = time.time()
    K = len(configs)
    rt = np.zeros((K, P_MAX))
    on = np.zeros((K, P_MAX))
    cl = np.zeros((K, P_MAX))
    valid = np.zeros((K, P_MAX), bool)
    for i, kv in enumerate(configs):
        rt[i], on[i], cl[i], valid[i] = _segment_placements(w, kv, n_cores)
    t_all["filter_placements"] = time.time() - t0

    # --- throughput guarantee: cheapest config real-time on-prem ---------
    k_cheap = int(np.argmin(cost))
    rt_cheap = cost[k_cheap] / n_cores
    if rt_cheap > tau * 1.001:
        raise ValueError(
            f"provisioning too small: cheapest config needs "
            f"{rt_cheap:.2f}s > segment {tau}s on {n_cores} cores")

    # --- content categories (§3.2) ---------------------------------------
    t0 = time.time()
    unl = generate(w, days=days_unlabeled, seed=seed + 1)
    qual_all = unl.quality(power, seed=seed + 2)          # (T, K)
    n_samp = max(n_categories * 20, int(unl.n_segments * sample_frac))
    samp = rng.choice(unl.n_segments, min(n_samp, unl.n_segments),
                      replace=False)
    centers, _ = kmeans(qual_all[samp], n_categories, seed=seed)
    centers = np.asarray(centers)
    t_all["categories"] = time.time() - t0

    # --- forecaster (§3.3, App. H) ----------------------------------------
    t0 = time.time()
    # label the unlabeled stream with the cheapest config only (App. H)
    col = centers[:, k_cheap]
    labels = np.argmin(np.abs(qual_all[:, k_cheap][:, None] - col[None]),
                       axis=1)
    interval = max(1, int(input_days * 86400 / n_split / tau))
    horizon = max(1, int(plan_days * 86400 / tau))
    # clamp to the available unlabeled data (short fits in tests)
    T_unl = len(labels)
    horizon = min(horizon, max(1, T_unl // 4))
    interval = min(interval, max(1, (T_unl - horizon) // (2 * n_split)))
    X, Y = make_dataset(labels, n_categories, interval=interval,
                        n_split=n_split, horizon=horizon)
    t_all["forecast_data"] = time.time() - t0
    t0 = time.time()
    params = init_forecaster(jax.random.PRNGKey(seed), n_split, n_categories)
    params, fmetrics = train_forecaster(params, X, Y)
    t_all["forecast_train"] = time.time() - t0

    return Fitted(workload=w, configs=configs, power=power, cost=cost,
                  place_rt=rt, place_on=on, place_cl=cl, place_valid=valid,
                  centers=centers, forecaster=params, n_split=n_split,
                  interval_segments=interval, horizon_segments=horizon,
                  n_cores=n_cores, timings=t_all, forecast_metrics=fmetrics)

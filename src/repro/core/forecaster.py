"""Forecasting model (paper §3.3, App. H/K): a small MLP mapping the
recent history of per-interval content-category histograms to the
category histogram of the next planned interval.

Architecture (App. K): input -> 16 (ReLU) -> 8 (ReLU) -> |C| (softmax).
Trained 40 epochs, 20% validation split, best-val weights kept.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def init_forecaster(key, n_split: int, n_categories: int) -> Dict:
    """Init the tiny MLP (Sec. 4.2) that maps a day split's category
    histogram to next-window category shares; returns the param tree."""
    k1, k2, k3 = jax.random.split(key, 3)
    d_in = n_split * n_categories

    def lin(k, i, o):
        return {"w": jax.random.normal(k, (i, o)) / jnp.sqrt(i),
                "b": jnp.zeros((o,))}

    return {"l1": lin(k1, d_in, 16), "l2": lin(k2, 16, 8),
            "l3": lin(k3, 8, n_categories)}


def forecast(params, hist):
    """hist (..., n_split, |C|) -> predicted histogram (..., |C|)."""
    x = hist.reshape(hist.shape[:-2] + (-1,))
    x = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    x = jax.nn.relu(x @ params["l2"]["w"] + params["l2"]["b"])
    return jax.nn.softmax(x @ params["l3"]["w"] + params["l3"]["b"], axis=-1)


def history_histogram(label_buf, n_categories: int, *, n_split: int,
                      interval: int):
    """Fixed-shape histogram features from a rolling label buffer.

    label_buf: (n_split * interval,) int32 — the most recent labels,
    oldest first (zero-initialized buffers behave like the host loop's
    left-zero padding). Returns (n_split, |C|) per-sub-interval category
    histograms — pure jnp, so it is jit/scan-friendly and can sit inside
    the fused whole-run engine's carry.
    """
    oh = jax.nn.one_hot(label_buf, n_categories, dtype=jnp.float32)
    return oh.reshape(n_split, interval, n_categories).mean(axis=1)


def forecast_from_labels(params, label_buf, n_categories: int, *,
                         n_split: int, interval: int):
    """forecast() on a fixed-shape rolling label buffer (scan-friendly:
    every shape is static, so the fused engine carries ``label_buf``
    through an outer ``lax.scan`` and replans entirely on device)."""
    hist = history_histogram(label_buf, n_categories, n_split=n_split,
                             interval=interval)
    return forecast(params, hist)


def _loss(params, X, Y):
    pred = forecast(params, X)
    return jnp.mean(jnp.sum((pred - Y) ** 2, axis=-1))


@jax.jit
def _adam_step(params, opt, X, Y, lr):
    g = jax.grad(_loss)(params, X, Y)
    m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, opt["m"], g)
    v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_, opt["v"], g)
    t = opt["t"] + 1
    mhat = jax.tree.map(lambda x: x / (1 - 0.9 ** t), m)
    vhat = jax.tree.map(lambda x: x / (1 - 0.999 ** t), v)
    params = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
                          params, mhat, vhat)
    return params, {"m": m, "v": v, "t": t}


from repro.analysis.registry import example_builder, register_engine  # noqa: E402
from repro.core.switcher import register_cache_probe  # noqa: E402

register_cache_probe("forecaster_adam", lambda: _adam_step._cache_size())
register_engine("forecaster_adam", example_builder("adam_step"),
                probe=lambda: _adam_step._cache_size(),
                covers=("repro.core.forecaster:_adam_step",),
                probe_name="forecaster_adam")


def train_forecaster(params, X, Y, *, epochs: int = 40, lr: float = 3e-3,
                     val_frac: float = 0.2, batch: int = 64, seed: int = 0):
    """X (n, n_split, |C|), Y (n, |C|). Returns (best params, metrics)."""
    n = X.shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_val = max(1, int(n * val_frac))
    vi, ti = perm[:n_val], perm[n_val:]
    Xt, Yt = jnp.asarray(X[ti]), jnp.asarray(Y[ti])
    Xv, Yv = jnp.asarray(X[vi]), jnp.asarray(Y[vi])
    # t must be a strong int32: a python 0 traces weak, so the second
    # step (strong t from step 1's output) would silently recompile
    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.int32(0)}
    best, best_val = params, float("inf")
    nt = Xt.shape[0]
    for ep in range(epochs):
        order = rng.permutation(nt)
        for i in range(0, nt, batch):
            idx = order[i:i + batch]
            params, opt = _adam_step(params, opt, Xt[idx], Yt[idx],
                                     jnp.float32(lr))
        val = float(_loss(params, Xv, Yv))
        if val < best_val:
            best, best_val = params, val
    mae = float(jnp.mean(jnp.abs(forecast(best, Xv) - Yv)))
    return best, {"val_mse": best_val, "val_mae": mae}


def make_dataset(labels: np.ndarray, n_categories: int, *,
                 interval: int, n_split: int, horizon: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """labels (T,) per-segment category ids -> (X, Y) histogram pairs.

    interval: segments per input sub-interval; n_split sub-intervals of
    history predict the histogram of the next ``horizon`` segments.
    """
    T = len(labels)
    oh = np.eye(n_categories, dtype=np.float32)[labels]
    X, Y = [], []
    span = interval * n_split
    step = max(1, interval // 2)
    for t in range(span, T - horizon, step):
        hist = oh[t - span:t].reshape(n_split, interval, n_categories).mean(1)
        X.append(hist)
        Y.append(oh[t:t + horizon].mean(0))
    return np.stack(X), np.stack(Y)

"""Task-placement machinery (paper App. A.2 + M.1).

- ``simulate``: the App. M list-scheduling simulator — on-prem tasks on
  the earliest-free core, cloud tasks serialized through uplink/downlink
  bandwidth with RTT folded into the cloud runtime.
- ``enumerate_placements``: exhaustive 2^T enumeration for small DAGs
  (all the paper's DAGs have <= 12 tasks), Pareto-filtered on
  (runtime, cloud cost). This replaces PlaceTo's GNN+RL search — noted
  as a deviation in DESIGN.md §8: the paper only needs the Pareto set,
  and exhaustive enumeration is exact at this scale.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Task:
    """One DAG node for placement: per-segment on-prem/cloud runtimes
    and transfer sizes, with deps as indices into the task list."""
    name: str
    deps: Tuple[int, ...]
    onprem_ms: float
    cloud_ms: float
    mb_in: float
    mb_out: float


def tasks_from_dag(dag) -> List[Task]:
    """Build ``Task`` records from the workload DAG tuples, resolving
    dependency names to indices."""
    names = [t[0] for t in dag]
    out = []
    for name, deps, on_ms, cl_ms, mi, mo in dag:
        out.append(Task(name, tuple(names.index(d) for d in deps),
                        on_ms, cl_ms, mi, mo))
    return out


def simulate(tasks: Sequence[Task], placement: Sequence[bool], n_cores: int,
             uplink_mbs: float = 12.5, downlink_mbs: float = 25.0,
             mult: Dict[str, float] = None) -> Tuple[float, float, float]:
    """placement[i]=True -> cloud. Returns (runtime_s, onprem_core_s,
    cloud_core_s). ``mult`` scales per-task durations (knob effects)."""
    mult = mult or {}
    n = len(tasks)
    finish = np.zeros(n)
    cores = np.zeros(n_cores)          # free-at times
    up_free = 0.0
    down_free = 0.0
    onprem_s = 0.0
    cloud_s = 0.0
    for i, t in enumerate(tasks):
        m = mult.get(t.name, 1.0)
        ready = max((finish[d] for d in t.deps), default=0.0)
        if placement[i]:
            dur = t.cloud_ms * m / 1e3
            up = t.mb_in * m / uplink_mbs
            start_up = max(ready, up_free)
            up_free = start_up + up
            done_cloud = up_free + dur
            down = t.mb_out * m / downlink_mbs
            start_down = max(done_cloud, down_free)
            down_free = start_down + down
            finish[i] = down_free
            cloud_s += dur
        else:
            dur = t.onprem_ms * m / 1e3
            ci = int(np.argmin(cores))
            start = max(ready, cores[ci])
            cores[ci] = start + dur
            finish[i] = cores[ci]
            onprem_s += dur
    return float(finish.max(initial=0.0)), onprem_s, cloud_s


def pareto_filter(points: List[Tuple[float, float, int]]) -> List[int]:
    """points (runtime, cloud_cost, idx) -> indices on the Pareto frontier."""
    pts = sorted(points)
    best = []
    min_cost = float("inf")
    for rt, cc, idx in pts:
        if cc < min_cost - 1e-12:
            best.append(idx)
            min_cost = cc
    return best


def enumerate_placements(tasks: Sequence[Task], n_cores: int,
                         mult: Dict[str, float] = None,
                         max_exhaustive: int = 14):
    """Returns list of (placement_mask, runtime_s, onprem_s, cloud_s) on
    the (runtime, cloud) Pareto frontier, sorted by cloud cost asc."""
    n = len(tasks)
    results = []
    if n <= max_exhaustive:
        masks = list(itertools.product([False, True], repeat=n))
    else:                               # greedy fallback for big DAGs
        masks = [tuple(False for _ in range(n))]
        cur = list(masks[0])
        for i in range(n):              # greedily move best task to cloud
            cur2 = list(cur)
            cur2[i] = True
            masks.append(tuple(cur2))
    sims = []
    for mi, mask in enumerate(masks):
        rt, on_s, cl_s = simulate(tasks, mask, n_cores, mult=mult)
        sims.append((mask, rt, on_s, cl_s))
    keep = pareto_filter([(rt, cl, i) for i, (_, rt, _, cl) in enumerate(sims)])
    out = [sims[i] for i in keep]
    out.sort(key=lambda x: x[3])        # by cloud cost
    return out

"""Knob planner (paper §4.1): assign knob-config mixing histograms to
content categories, maximizing expected quality under a compute budget.

    max   sum_{k,c} a[k,c] r[c] qual[k,c]
    s.t.  sum_{k,c} a[k,c] r[c] cost[k] <= budget
          sum_k a[k,c] = 1,  a >= 0                       (per category)

Two solvers:
- ``solve_lp_scipy``: the paper's approach (off-the-shelf LP, <1 s).
- ``solve_lp_lagrangian``: beyond-paper. The LP is a product of simplices
  coupled by ONE budget constraint, so the dual is a 1-D piecewise-linear
  function of the budget multiplier λ: at a given λ each category simply
  picks argmax_k (qual - λ·cost). Bisect λ, then blend the prefer-cheap /
  prefer-expensive tie-breaks to exhaust the budget exactly. Exact (same
  optimum as the LP), jit-compiled, ~µs instead of ~ms.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def solve_lp_scipy(qual, cost, r, budget):
    """qual (C,K); cost (K,); r (C,). Returns alpha (C,K)."""
    from scipy.optimize import linprog
    C, K = qual.shape
    qual = np.asarray(qual, np.float64)
    cost = np.asarray(cost, np.float64)
    r = np.asarray(r, np.float64)
    c_obj = -(r[:, None] * qual).reshape(-1)             # maximize
    A_ub = (r[:, None] * cost[None, :]).reshape(1, -1)
    A_eq = np.zeros((C, C * K))
    for ci in range(C):
        A_eq[ci, ci * K:(ci + 1) * K] = 1.0
    res = linprog(c_obj, A_ub=A_ub, b_ub=[budget], A_eq=A_eq,
                  b_eq=np.ones(C), bounds=(0, 1), method="highs")
    if not res.success:
        # infeasible budget: everyone gets the cheapest config
        alpha = np.zeros((C, K))
        alpha[:, int(np.argmin(cost))] = 1.0
        return alpha
    return res.x.reshape(C, K)


@functools.partial(jax.jit, static_argnames=("iters",))
def solve_lp_lagrangian(qual, cost, r, budget, iters: int = 64):
    """Exact jit-able solver. qual (C,K); cost (K,); r (C,).

    The affordable / unaffordable endpoint solutions are CARRIED through
    the bisection loop (not recomputed afterwards) so the result is
    robust to XLA fusion-dependent rounding at argmax boundaries.
    """
    qual = qual.astype(jnp.float32)
    cost = cost.astype(jnp.float32)
    r = r.astype(jnp.float32)
    C, K = qual.shape
    if K == 1:                       # single config: nothing to plan
        return jnp.ones((C, 1), jnp.float32)

    def pick(lam):
        score = qual - lam * cost[None, :]
        idx = jnp.argmax(score, axis=1)
        a = jax.nn.one_hot(idx, K)
        spend = jnp.sum(r * (a * cost[None, :]).sum(axis=1))
        return a, spend

    a0, s0 = pick(jnp.float32(0.0))                       # unconstrained opt
    # λ large enough that argmax is (near-)min-cost: must beat the largest
    # quality gap across the SMALLEST positive cost gap.
    cs = jnp.sort(cost)
    gaps = jnp.diff(cs)
    gap_min = jnp.min(jnp.where(gaps > 1e-9, gaps, jnp.inf))
    gap_min = jnp.where(jnp.isfinite(gap_min), gap_min, 1.0)
    q_range = jnp.max(qual) - jnp.min(qual)
    lam_hi0 = jnp.minimum((q_range + 1.0) / jnp.maximum(gap_min, 1e-6), 1e7)
    a_min, s_min = pick(lam_hi0)                          # min-spend plan

    def body(_, carry):
        lo, hi, a_aff, s_aff, a_un, s_un = carry
        mid = 0.5 * (lo + hi)
        a, s = pick(mid)
        take = s <= budget

        def sel(x, y):
            return jnp.where(take, x, y)
        return (sel(lo, mid), sel(mid, hi),
                sel(a, a_aff), sel(s, s_aff),
                sel(a_un, a), sel(s_un, s))

    carry = (jnp.float32(0.0), lam_hi0, a_min, s_min, a0, s0)
    _, _, a_aff, s_aff, a_un, s_un = jax.lax.fori_loop(0, iters, body, carry)
    # blend to exhaust the budget: θ·s_un + (1-θ)·s_aff = budget
    theta = jnp.where(s_un > s_aff,
                      jnp.clip((budget - s_aff)
                               / jnp.maximum(s_un - s_aff, 1e-9), 0.0, 1.0),
                      0.0)
    a_mix = theta * a_un + (1 - theta) * a_aff
    return jnp.where(s0 <= budget, a0, a_mix)


from repro.analysis.registry import example_builder, register_engine  # noqa: E402
from repro.core.switcher import register_cache_probe  # noqa: E402

register_cache_probe("planner_lp", lambda: solve_lp_lagrangian._cache_size())
register_engine("lp_lagrangian", example_builder("lp_lagrangian"),
                probe=lambda: solve_lp_lagrangian._cache_size(),
                covers=("repro.core.planner:solve_lp_lagrangian",),
                probe_name="planner_lp")


def solve_lp_rationed(qual, cost, r, *, core_s_per_segment, cloud_left,
                      frac, window_len, cloud_premium):
    """Window-rationed LP entry point (paper §4 online loop): the
    per-window budget is the on-prem capacity plus the REMAINING cloud
    budget rationed proportionally to the window's share of the rest of
    the run, discounted by the cloud premium. Pure jnp on scalars, so it
    inlines into the fused whole-run scan (``cloud_left`` comes from the
    switcher state carry). Returns the (C, K) plan."""
    w_t = jnp.asarray(window_len, jnp.float32)
    budget = (jnp.asarray(core_s_per_segment, jnp.float32) * w_t
              + jnp.maximum(jnp.asarray(cloud_left, jnp.float32), 0.0)
              * jnp.asarray(frac, jnp.float32) / cloud_premium)
    return solve_lp_lagrangian(qual, cost, r, budget / w_t)


def solve_lp_stacked(qual, cost, r, budget, weights=None):
    """Batched multi-stream LP on STATIC shapes: qual (V, C_max, K)
    sentinel-padded category tables, r (V, C_max) forecasts with zero
    rate on padding rows, one shared ``budget``. The joint LP is the
    same product-of-simplices + single-budget structure, so flattening
    the stream axis into the category axis and calling the Lagrangian
    solver once is exact; zero-rate rows contribute nothing to spend or
    value, so the padding cannot perturb the optimum. jit/scan-friendly
    device-side replacement for ``solve_multi_stream``'s host loop.

    ``weights`` (V,), when given, scales each stream's quality term in
    the joint objective: under a shared budget the Lagrangian tradeoff
    ``w_v * qual - lambda * cost`` then buys quality for high-priority
    streams first — the serving pool's priority-weighted admission
    plan (scaling is a no-op for independent per-stream budgets, which
    are scale-invariant; it only matters for this joint form).
    Returns alpha (V, C_max, K)."""
    V, C, K = qual.shape
    if weights is not None:
        qual = qual * jnp.asarray(weights, jnp.float32)[:, None, None]
    alpha = solve_lp_lagrangian(qual.reshape(V * C, K), cost,
                                r.reshape(V * C), budget)
    return alpha.reshape(V, C, K)


def plan_value(alpha, qual, cost, r):
    """Returns (expected quality, expected spend) of a plan."""
    q = float(jnp.sum(r[:, None] * alpha * qual))
    s = float(jnp.sum(r[:, None] * alpha * cost[None, :]))
    return q, s


def solve_multi_stream(quals, cost, rs, budget):
    """Joint multi-stream knob plan (paper App. D, Eqs. 7-9).

    quals: list of per-stream (C_v, K) tables; rs: list of per-stream
    forecasts (each a distribution); cost (K,); budget = total core-s
    per segment across ALL streams. The joint LP has the same
    product-of-simplices + single-budget structure, so the Lagrangian
    solver applies to the stacked system unchanged.
    Returns list of per-stream alpha (C_v, K)."""
    import numpy as np
    sizes = [q.shape[0] for q in quals]
    qual = jnp.concatenate([jnp.asarray(q, jnp.float32) for q in quals], 0)
    r = jnp.concatenate([jnp.asarray(x, jnp.float32) for x in rs], 0)
    alpha = solve_lp_lagrangian(qual, jnp.asarray(cost, jnp.float32), r,
                                jnp.float32(budget))
    out = []
    off = 0
    for s in sizes:
        out.append(alpha[off:off + s])
        off += s
    return out

"""User-facing Skyscraper API (paper App. F).

    sky = Skyscraper(fps=30, segment_seconds=2.0)
    sky.set_resources(num_cores=8, buffer_gb=4.0, cloud_budget_core_s=0)
    sky.register_knob("det_interval", [1, 5, 10])
    sky.fit(unlabeled_segments, proc_fn)
    status, out = sky.process(segment)        # online, content-adaptive

``proc_fn(segment, knobs) -> (output, quality)`` is the user's transform
(the V-ETL *T*). fit() profiles every knob configuration's wall-clock
runtime (the paper's offline profiling), Pareto-filters configurations,
builds content categories from measured quality vectors, and trains the
forecaster. process() is the online loop: classify -> look up plan ->
switch -> execute.
"""
from __future__ import annotations

import functools
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import example_builder, register_engine
from repro.core.categories import kmeans
from repro.core.forecaster import (forecast_from_labels, init_forecaster,
                                   make_dataset, train_forecaster)
from repro.core.planner import solve_lp_lagrangian, solve_lp_stacked
from repro.core.switcher import (SwitchTables, _masked_switch, init_state,
                                 init_state_multi, register_cache_probe,
                                 stack_tables, switch_step,
                                 switch_step_multi)


class Skyscraper:
    """User-facing ETL handle: declare a workload (fps, knobs, cores,
    buffer, cloud budget), ``fit()`` offline tables, then ``process()``
    segments online through the fused switch/plan kernels."""

    def __init__(self, fps: int = 30, segment_seconds: float = 2.0,
                 n_categories: int = 4, seed: int = 0):
        self.fps = fps
        self.tau = segment_seconds
        self.n_categories = n_categories
        self.seed = seed
        self.knobs: Dict[str, Sequence] = {}
        self.num_cores = 1
        self.buffer_gb = 4.0
        self.cloud_budget = 0.0
        self._fitted = False

    def set_resources(self, *, num_cores: int, buffer_gb: float = 4.0,
                      cloud_budget_core_s: float = 0.0):
        self.num_cores = num_cores
        self.buffer_gb = buffer_gb
        self.cloud_budget = cloud_budget_core_s
        self.budget_override = None

    def set_budget(self, core_s_per_segment: float):
        """Override the per-segment compute budget used by the planner
        (defaults to num_cores * segment_seconds)."""
        self.budget_override = core_s_per_segment
        if getattr(self, "_fitted", False):
            self._replan()

    def register_knob(self, name: str, domain: Sequence):
        self.knobs[name] = tuple(domain)

    # ------------------------------------------------------------------
    def fit(self, unlabeled: Sequence, proc_fn: Callable, *,
            profile_repeats: int = 1, plan_segments: int = 512,
            n_split: int = 4, max_k: int = 10):
        """unlabeled: list of segments (opaque to Skyscraper)."""
        configs = [dict(zip(self.knobs, v))
                   for v in itertools.product(*self.knobs.values())]
        # --- profile runtimes + quality vectors on the unlabeled data ---
        sample = unlabeled[:: max(1, len(unlabeled) // 40)]
        runtimes = np.zeros(len(configs))
        quals = np.zeros((len(unlabeled), len(configs)), np.float32)
        for ki, kv in enumerate(configs):
            t0 = time.perf_counter()
            for _ in range(profile_repeats):
                for seg in sample:
                    proc_fn(seg, kv)
            runtimes[ki] = ((time.perf_counter() - t0)
                            / (profile_repeats * len(sample)))
            for si, seg in enumerate(unlabeled):
                _, q = proc_fn(seg, kv)
                quals[si, ki] = q
        # --- Pareto-filter configurations -------------------------------
        mq = quals.mean(axis=0)
        order = np.argsort(runtimes)
        keep = []
        best_q = -1.0
        for i in order:
            if mq[i] > best_q + 1e-6:
                keep.append(i)
                best_q = mq[i]
        keep = keep[:max_k]
        self.configs = [configs[i] for i in keep]
        self.cost = runtimes[keep] * self.num_cores  # core-s per segment
        quals = quals[:, keep]
        # --- categories + forecaster ------------------------------------
        import jax
        centers, labels = kmeans(quals, min(self.n_categories, len(unlabeled)),
                                 seed=self.seed)
        self.centers = np.asarray(centers)
        C = self.centers.shape[0]
        interval = max(1, len(labels) // (4 * n_split))
        horizon = max(1, min(plan_segments, len(labels) // 4))
        X, Y = make_dataset(np.asarray(labels), C, interval=interval,
                            n_split=n_split, horizon=horizon)
        params = init_forecaster(jax.random.PRNGKey(self.seed), n_split, C)
        self.forecaster, self.forecast_metrics = train_forecaster(params, X, Y)
        self.n_split, self.interval = n_split, interval
        # --- switcher tables (single all-on-prem placement per config) --
        K = len(self.configs)
        rt = (self.cost / self.num_cores)[:, None]
        self.tables = SwitchTables(
            centers=jnp.asarray(self.centers),
            power=jnp.asarray(mq[keep]),
            cost=jnp.asarray(self.cost, jnp.float32),
            place_rt=jnp.asarray(rt, jnp.float32),
            place_on=jnp.asarray(self.cost[:, None], jnp.float32),
            place_cl=jnp.zeros((K, 1), jnp.float32),
            place_valid=jnp.ones((K, 1), bool),
            rank_pos=jnp.asarray(np.argsort(np.argsort(-mq[keep])), jnp.int32),
            tau=self.tau,
            buffer_cap_s=self.buffer_gb * 1e9 / 90e3,
            cloud_budget=self.cloud_budget,
        )
        self.state = init_state(self.tables)
        self.proc_fn = proc_fn
        self._labels_hist: List[int] = []
        self._plan_every = plan_segments
        self._seen = 0
        self._replan()
        self._fitted = True
        return self

    def _replan(self):
        C = self.centers.shape[0]
        need = self.n_split * self.interval
        if len(self._labels_hist) >= need:
            lab = jnp.asarray(self._labels_hist[-need:], jnp.int32)
            r = np.asarray(forecast_from_labels(
                self.forecaster, lab, C, n_split=self.n_split,
                interval=self.interval))
        else:
            r = np.full(C, 1.0 / C)
        budget = (self.budget_override if getattr(self, "budget_override",
                                                  None)
                  else self.num_cores * self.tau)
        self.alpha = solve_lp_lagrangian(
            jnp.asarray(self.centers), self.tables.cost,
            jnp.asarray(r, jnp.float32), jnp.float32(budget))

    # ------------------------------------------------------------------
    def process(self, segment, arrival_mult: float = 1.0):
        """Run the V-ETL Transform on one segment with adaptive knobs."""
        assert self._fitted, "call fit() first"
        K = len(self.configs)
        dummy_quals = jnp.zeros((K,), jnp.float32)  # filled post-exec
        self.state, out = switch_step(self.state, dummy_quals,
                                      jnp.float32(arrival_mult),
                                      self.alpha, self.tables)
        k = int(out["k"])
        result, q = self.proc_fn(segment, self.configs[k])
        # report the measured quality back (drives the next classification)
        self.state["qual_prev"] = jnp.float32(q)
        self._labels_hist.append(int(out["c"]))
        self._seen += 1
        if self._seen % self._plan_every == 0:
            self._replan()
        return {"config": self.configs[k], "k": k, "category": int(out["c"]),
                "quality": float(q),
                "buffer_s": float(out["buffer_s"])}, result


@functools.partial(jax.jit, static_argnames=("n_split", "interval"))
def _pool_replan(params, bufs, centers, cost, budget, use_model, *,
                 n_split: int, interval: int):
    """Device-side batched replanning for V streams: each stream's
    rolling label buffer -> histogram features -> forecaster MLP -> LP,
    all vmapped into one dispatch. ``use_model`` (traced bool) falls
    back to the uniform prior until the buffers have filled once —
    flipping it never recompiles."""
    C = centers.shape[0]
    r_model = jax.vmap(lambda b: forecast_from_labels(
        params, b, C, n_split=n_split, interval=interval))(bufs)
    r = jnp.where(use_model, r_model,
                  jnp.full_like(r_model, 1.0 / C))
    return jax.vmap(lambda rv: solve_lp_lagrangian(centers, cost, rv,
                                                   budget))(r)


_pool_shift = jax.jit(lambda bufs, c: jnp.concatenate(
    [bufs[:, 1:], c[:, None].astype(jnp.int32)], axis=1))


@functools.partial(jax.jit, static_argnames=("n_split", "interval"))
def _pool_replan_stacked(params, bufs, centers, cost, budget, use_model,
                         active, priority, *, n_split: int, interval: int):
    """Joint priority-weighted replanning for the elastic pool: every
    ACTIVE stream's forecast feeds ONE stacked LP under a single shared
    pool budget, with each stream's quality term scaled by its
    priority (``solve_lp_stacked``'s ``weights``). Under overload the
    shared Lagrangian multiplier rises and the plan buys quality for
    high-priority streams first — low-priority streams degrade toward
    cheap configs before anyone sheds. Inactive slots get zero rate,
    so they contribute nothing to the joint spend; flipping ``active``
    / ``priority`` / ``budget`` values never recompiles."""
    C = centers.shape[0]
    r_model = jax.vmap(lambda b: forecast_from_labels(
        params, b, C, n_split=n_split, interval=interval))(bufs)
    r = jnp.where(use_model, r_model,
                  jnp.full_like(r_model, 1.0 / C))
    r = r * jnp.asarray(active, jnp.float32)[:, None]
    V = bufs.shape[0]
    qual = jnp.broadcast_to(centers, (V,) + centers.shape)
    return solve_lp_stacked(qual, cost, r, budget, weights=priority)


def _pool_tick_fn(state, q_meas, q_valid, quals, arr, active, priority,
                  alpha, tables, capacity_core_s, watermark_frac):
    """One elastic-pool tick, fully fused: fold last tick's measured
    qualities into the carried classification state, run the masked
    batched switch (retired/empty slots are exact no-ops), then apply
    priority shedding — all ONE executable per capacity bucket.

    Shedding (the paper's last degradation rung, §3 throughput
    guarantee): two overload triggers, both computed on device —
    (1) the tick's total planned on-prem demand exceeds
    ``capacity_core_s`` (the joint plan's feasible set collapsed for
    the slice of streams that no longer fits), and (2) a stream's
    pre-tick buffer crossed ``watermark_frac`` of its buffer capacity
    (it is falling behind faster than degradation can absorb). Under
    trigger (1) streams are kept in priority order (stable argsort, so
    equal priorities shed by slot index) until the kept demand fits;
    a shed stream's segment reverts to the switch's own drop
    semantics: zero work, zero quality, buffer drains by tau. Both
    thresholds are traced operands — defaults of +inf make the whole
    stage the identity, so the fixed pool pays nothing."""
    state = dict(state, qual_prev=jnp.where(jnp.asarray(q_valid, bool),
                                            q_meas, state["qual_prev"]))
    pre_buf = state["buffer_s"]
    new_state, outs = jax.vmap(_masked_switch)(
        state, quals, arr, active, alpha, tables)
    demand = outs["on_s"]
    order = jnp.argsort(jnp.where(active, -priority, jnp.inf))
    keep = jnp.zeros_like(active).at[order].set(
        jnp.cumsum(demand[order]) <= capacity_core_s)
    hwm_s = watermark_frac * jnp.asarray(tables.buffer_cap_s, jnp.float32)
    shed = active & ~outs["dropped"] & (~keep | (pre_buf >= hwm_s))
    tau = jnp.asarray(tables.tau, jnp.float32)
    shed_buf = jnp.maximum(pre_buf - tau, 0.0)
    new_state = dict(
        new_state,
        buffer_s=jnp.where(shed, shed_buf, new_state["buffer_s"]),
        cloud_spent=jnp.where(shed,
                              new_state["cloud_spent"] - outs["cl_s"],
                              new_state["cloud_spent"]),
        qual_prev=jnp.where(shed, 0.0, new_state["qual_prev"]))
    zero = jnp.float32(0.0)
    outs = dict(outs,
                qual=jnp.where(shed, zero, outs["qual"]),
                on_s=jnp.where(shed, zero, outs["on_s"]),
                cl_s=jnp.where(shed, zero, outs["cl_s"]),
                rt=jnp.where(shed, zero, outs["rt"]),
                buffer_s=jnp.where(shed, shed_buf, outs["buffer_s"]),
                dropped=outs["dropped"] | shed,
                shed=shed)
    return new_state, outs


_pool_tick = jax.jit(_pool_tick_fn)


def _pool_admit_fn(tables, state, bufs, alpha, active, priority, slot,
                   prio, row_tables, alpha_row):
    """Fill one slot with a freshly admitted stream: write its (possibly
    per-stream) table row, a fresh switcher state, an empty label
    buffer, the current single-stream plan, and flip the slot active.
    Every argument is a traced VALUE — admissions within a capacity
    bucket reuse ONE executable (the zero-warm-recompile contract)."""
    tables = jax.tree.map(
        lambda t, r: t.at[slot].set(jnp.asarray(r, t.dtype)),
        tables, row_tables)
    k0 = jnp.argmin(row_tables.rank_pos).astype(jnp.int32)
    state = {
        "used": state["used"].at[slot].set(0.0),
        "count": state["count"].at[slot].set(0.0),
        "buffer_s": state["buffer_s"].at[slot].set(0.0),
        "cloud_spent": state["cloud_spent"].at[slot].set(0.0),
        "k_cur": state["k_cur"].at[slot].set(k0),
        "qual_prev": state["qual_prev"].at[slot].set(1.0),
    }
    bufs = bufs.at[slot].set(0)
    alpha = alpha.at[slot].set(alpha_row)
    active = active.at[slot].set(True)
    priority = priority.at[slot].set(prio)
    return tables, state, bufs, alpha, active, priority


_pool_admit = jax.jit(_pool_admit_fn)

_pool_retire = jax.jit(lambda active, slot: active.at[slot].set(False))

register_cache_probe("pool_replan", lambda: _pool_replan._cache_size())
register_cache_probe("pool_shift", lambda: _pool_shift._cache_size())
register_cache_probe("pool_replan_stacked",
                     lambda: _pool_replan_stacked._cache_size())
register_cache_probe("pool_tick", lambda: _pool_tick._cache_size())
register_cache_probe("pool_admit", lambda: _pool_admit._cache_size())
register_cache_probe("pool_retire", lambda: _pool_retire._cache_size())
register_engine("pool_replan", example_builder("pool_replan"),
                probe=lambda: _pool_replan._cache_size(),
                covers=("repro.core.api:_pool_replan",),
                probe_name="pool_replan")
register_engine("pool_shift", example_builder("pool_shift"),
                probe=lambda: _pool_shift._cache_size(),
                covers=("repro.core.api:_pool_shift",),
                probe_name="pool_shift")
register_engine("pool_replan_stacked",
                example_builder("pool_replan_stacked"),
                probe=lambda: _pool_replan_stacked._cache_size(),
                covers=("repro.core.api:_pool_replan_stacked",),
                probe_name="pool_replan_stacked")
register_engine("pool_tick", example_builder("pool_tick"),
                probe=lambda: _pool_tick._cache_size(),
                covers=("repro.core.api:_pool_tick",),
                probe_name="pool_tick")
register_engine("pool_admit", example_builder("pool_admit"),
                probe=lambda: _pool_admit._cache_size(),
                covers=("repro.core.api:_pool_admit",),
                probe_name="pool_admit")
register_engine("pool_retire", example_builder("pool_retire"),
                probe=lambda: _pool_retire._cache_size(),
                covers=("repro.core.api:_pool_retire",),
                probe_name="pool_retire")


class AdmissionError(RuntimeError):
    """Raised by ``SkyscraperPool.admit`` when admission control
    determines the pool cannot serve one more stream even at every
    stream's cheapest configuration (the throughput guarantee would be
    unsatisfiable, so the stream is refused instead of admitted into
    guaranteed shedding)."""


class SkyscraperPool:
    """An ELASTIC pool of live streams sharing one fitted profile,
    switched by the batched engine: ONE fused jit dispatch decides all
    slots' knob configs per tick (paper App. D scenario 1 as an online
    serving runtime).

    Slots, not streams: capacity follows the power-of-two slot ladder
    (``_bucket_cap`` on the leading axis of every carried array), and
    an ``active`` mask makes retired/empty slots exact no-ops inside
    the fused tick. ``admit``/``retire`` flip VALUES only, so stream
    churn within a capacity bucket causes ZERO warm recompiles; only
    crossing a bucket boundary compiles once more (O(log V) compiles
    over a pool's lifetime).

        pool = SkyscraperPool(fitted_sky, n_streams=8)
        statuses, outputs = pool.process([seg0, ..., seg7])
        pool.admit(stream_id=99, priority=2.0)
        pool.retire(stream_id=3)
        statuses, outputs = pool.process({99: seg, ...})  # by stream id

    Overload behavior (``capacity_core_s`` / ``shed_watermark``): the
    fused tick sheds lowest-priority streams first when planned demand
    exceeds the pool's provisioned core-seconds per tick, or when a
    stream's buffer crosses the high-water-mark fraction of its
    capacity. Shed segments revert to the switch's drop semantics and
    land in telemetry's ``seg_dropped`` per stream; with a warehouse
    sink, standing alert subscriptions fire on the same tick's rows.
    ``joint_plan=True`` additionally replans all streams through ONE
    priority-weighted stacked LP under a single pool budget
    (``solve_lp_stacked`` weights) instead of independent per-stream
    budgets.

    Fused planning: per-stream category histories live in a device-side
    rolling label buffer (V_cap, hist_len) updated by a jitted shift
    each tick, and replanning is ONE compiled call (vmapped forecaster
    + stacked LP). The replan for window t+1 is ENQUEUED before the
    tick's decisions are pulled to host, so planning overlaps the
    host-side Transform work of window t (async double-buffering; JAX's
    async dispatch does the pipelining — no ``block_until_ready``
    anywhere on the tick path).

    ``sink``: an optional ``warehouse.SegmentStore`` (with
    ``out_dim == len(sky.configs)``) — every tick lands one row per
    ACTIVE stream in the warehouse, carrying the stream's REAL id. A
    ``warehouse.ShardedStore`` sink routes stream ``s``'s row to shard
    ``s % n_shards`` inside the same tick dispatch (after heavy
    admit/retire churn, ``runtime.elastic.rebalance`` re-partitions the
    accumulated rows). Standing queries registered on the sink refresh
    inside that dispatch too, and each tick's fired alert subscriptions
    surface in ``pool.alerts``.

    ``telemetry=True`` attaches the serving-loop flight recorder: a
    host-side sequential float32 accumulator (``repro.obs``'s
    ``HostTelemetry``) fed from the per-tick outs the pool already
    pulls to host for the Transform — zero extra device dispatches,
    and the same bit-exactness contract as the fused engines' carried
    counters. Read it with ``pool.telemetry()`` (active streams, slot
    order) and ``pool.shed_stats()`` (per-stream shed fractions,
    retired streams included).
    """

    def __init__(self, sky: Skyscraper, n_streams: int, sink=None,
                 telemetry: bool = False, *, priorities=None,
                 slot_chunk: int = 8, capacity_core_s=None,
                 shed_watermark=None, joint_plan: bool = False):
        assert sky._fitted, "fit() the Skyscraper first"
        from repro.warehouse.store import _bucket_cap
        self.sky = sky
        self.sink = sink
        self._chunk = max(1, int(slot_chunk))
        self._cap = _bucket_cap(max(int(n_streams), 1), self._chunk)
        self.capacity_core_s = capacity_core_s
        self.shed_watermark = shed_watermark
        self._joint_plan = bool(joint_plan)
        # slot-ladder carries: every leading axis is (cap,)
        self.tables = stack_tables([sky.tables] * self._cap)
        self.state = init_state_multi([sky.tables] * self._cap)
        self._hist_len = sky.n_split * sky.interval
        self._bufs = jnp.zeros((self._cap, self._hist_len), jnp.int32)
        self._alpha = jnp.broadcast_to(
            sky.alpha, (self._cap,) + sky.alpha.shape)
        act = np.zeros(self._cap, bool)
        act[:n_streams] = True
        self._active = jnp.asarray(act)
        prio = np.zeros(self._cap, np.float32)
        prio[:n_streams] = (1.0 if priorities is None
                            else np.asarray(priorities, np.float32))
        self._priority = jnp.asarray(prio)
        # host-side slot bookkeeping: stream s starts at slot s
        self._slot_of: Dict[int, int] = {v: v for v in range(n_streams)}
        self._stream_of: Dict[int, int] = {v: v for v in range(n_streams)}
        self._free = list(range(n_streams, self._cap))
        # last tick's measured qualities, folded into the NEXT tick's
        # carried classification state inside the tick kernel
        self._pending_q = np.zeros(self._cap, np.float32)
        self._pending_valid = np.zeros(self._cap, bool)
        self._seen = 0
        # last tick's fired standing-query alerts (see ``process``)
        self.alerts = []
        self._tel = None
        self._retired_tel: Dict[int, Dict] = {}
        if telemetry:
            from repro.obs.telemetry import HostTelemetry
            k0 = int(np.argmin(np.asarray(sky.tables.rank_pos)))
            self._tel = HostTelemetry(self._cap, k0)

    # -- lifecycle -----------------------------------------------------
    @property
    def V(self) -> int:
        """Number of ACTIVE streams (the slot capacity is ``cap``)."""
        return len(self._slot_of)

    @property
    def cap(self) -> int:
        """Current slot capacity (a power-of-two ladder rung)."""
        return self._cap

    @property
    def streams(self):
        """Active stream ids, slot order (the ``process`` list order)."""
        return [self._stream_of[s] for s in sorted(self._stream_of)]

    def _min_demand_core_s(self, extra: int = 0) -> float:
        """Lower bound on one tick's on-prem demand: every active
        stream (plus ``extra`` hypothetical ones) at its cheapest
        config — the admission-control feasibility test."""
        return float(np.min(self.sky.cost)) * (self.V + extra)

    def admit(self, stream_id: int, priority: float = 1.0, tables=None,
              force: bool = False) -> int:
        """Admit a live stream into a free slot (growing the slot
        ladder one bucket if none is free). ``tables`` optionally gives
        the stream its OWN ``SwitchTables`` row (same config set);
        ``priority`` orders it in the shed ladder and weights its
        quality term in the joint LP. Returns the assigned slot.

        Admission control: with ``capacity_core_s`` set, a stream whose
        admission would push the pool's cheapest-config demand past the
        provisioned capacity is REFUSED (``AdmissionError``) — the
        throughput guarantee could not hold even with every stream
        fully degraded. ``force=True`` admits anyway (and the priority
        shed ladder resolves the overload at tick time)."""
        if stream_id in self._slot_of:
            raise ValueError(f"stream {stream_id} already admitted")
        if (not force and self.capacity_core_s is not None
                and self._min_demand_core_s(extra=1)
                > float(self.capacity_core_s)):
            raise AdmissionError(
                f"admitting stream {stream_id} needs >= "
                f"{self._min_demand_core_s(extra=1):.3f} core-s/tick at "
                f"the cheapest config, over the provisioned "
                f"{float(self.capacity_core_s):.3f}")
        if not self._free:
            self._grow(self._cap * 2)
        slot = min(self._free)
        self._free.remove(slot)
        row = tables if tables is not None else self.sky.tables
        (self.tables, self.state, self._bufs, self._alpha, self._active,
         self._priority) = _pool_admit(
            self.tables, self.state, self._bufs, self._alpha,
            self._active, self._priority, jnp.int32(slot),
            jnp.float32(priority), row, jnp.asarray(self.sky.alpha))
        self._slot_of[stream_id] = slot
        self._stream_of[slot] = stream_id
        self._pending_valid[slot] = False
        if self._tel is not None:
            self._tel.reset_slot(slot)
        return slot

    def retire(self, stream_id: int) -> int:
        """Remove a stream: its slot goes inactive (an exact no-op in
        the fused tick) and returns to the free list for the next
        admission. Telemetry counters accumulated for the stream are
        preserved in ``shed_stats()``. Returns the freed slot."""
        slot = self._slot_of.pop(stream_id)
        del self._stream_of[slot]
        if self._tel is not None:
            self._retired_tel[stream_id] = {
                "segments": float(self._tel.counters["seg_total"][slot]),
                "dropped": float(self._tel.counters["seg_dropped"][slot]),
                "priority": float(np.asarray(self._priority)[slot]),
            }
        self._active = _pool_retire(self._active, jnp.int32(slot))
        self._pending_valid[slot] = False
        self._free.append(slot)
        return slot

    def _grow(self, new_cap: int) -> None:
        """Double the slot ladder: pad every carried array's leading
        axis with inactive template rows. The ONLY recompile point in
        the stream lifecycle — O(log V) growths over a pool's life."""
        pad = new_cap - self._cap
        sky = self.sky
        pad_tables = stack_tables([sky.tables] * pad)
        self.tables = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), self.tables, pad_tables)
        self.state = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), self.state,
            init_state_multi([sky.tables] * pad))
        self._bufs = jnp.concatenate(
            [self._bufs, jnp.zeros((pad, self._hist_len), jnp.int32)])
        self._alpha = jnp.concatenate(
            [self._alpha,
             jnp.broadcast_to(sky.alpha, (pad,) + sky.alpha.shape)])
        self._active = jnp.concatenate(
            [self._active, jnp.zeros((pad,), bool)])
        self._priority = jnp.concatenate(
            [self._priority, jnp.zeros((pad,), jnp.float32)])
        self._pending_q = np.concatenate(
            [self._pending_q, np.zeros(pad, np.float32)])
        self._pending_valid = np.concatenate(
            [self._pending_valid, np.zeros(pad, bool)])
        self._free.extend(range(self._cap, new_cap))
        if self._tel is not None:
            self._tel.grow(new_cap)
        self._cap = new_cap

    # -- observability -------------------------------------------------
    def telemetry(self):
        """Snapshot of the pool's flight recorder (``repro.obs``'s
        ``Telemetry``) restricted to the ACTIVE streams in slot order,
        or None when constructed without one."""
        if self._tel is None:
            return None
        return self._tel.snapshot(select=sorted(self._stream_of))

    def shed_stats(self) -> Dict[int, Dict]:
        """Per-stream shed accounting from the flight recorder:
        ``{stream_id: {segments, dropped, priority}}`` — retired
        streams keep the counters they accumulated while live."""
        out = {}
        if self._tel is None:
            return out
        prio = np.asarray(self._priority)
        for slot in sorted(self._stream_of):
            sid = self._stream_of[slot]
            out[sid] = {
                "segments": float(self._tel.counters["seg_total"][slot]),
                "dropped": float(self._tel.counters["seg_dropped"][slot]),
                "priority": float(prio[slot]),
            }
        for sid, rec in self._retired_tel.items():
            out.setdefault(sid, dict(rec))
        return out

    # -- planning ------------------------------------------------------
    def _replan(self):
        """Refresh every slot's plan in ONE fused device call. Default:
        independent per-stream LPs (forecast -> LP, vmapped). With
        ``joint_plan=True``: one stacked priority-weighted LP under a
        shared pool budget (``capacity_core_s`` when set, else the
        per-stream budget times the active count)."""
        sky = self.sky
        budget = (sky.budget_override
                  if getattr(sky, "budget_override", None)
                  else sky.num_cores * sky.tau)
        use_model = jnp.asarray(self._seen >= self._hist_len)
        centers = jnp.asarray(sky.centers, jnp.float32)
        if self._joint_plan:
            total = (float(self.capacity_core_s)
                     if self.capacity_core_s is not None
                     else float(budget) * max(self.V, 1))
            self._alpha = _pool_replan_stacked(
                sky.forecaster, self._bufs, centers, sky.tables.cost,
                jnp.float32(total), use_model, self._active,
                self._priority, n_split=sky.n_split,
                interval=sky.interval)
        else:
            self._alpha = _pool_replan(
                sky.forecaster, self._bufs, centers, sky.tables.cost,
                jnp.float32(budget), use_model,
                n_split=sky.n_split, interval=sky.interval)
        if self._tel is not None:
            self._tel.replans += 1

    # -- the tick ------------------------------------------------------
    def process(self, segments, arrival_mults: Optional[Sequence] = None):
        """One fused masked switch + shed decision, then per-stream
        Transform execution for the streams that were not shed.

        ``segments``: a length-V list in slot order (``pool.streams``
        gives the ids), or a ``{stream_id: segment}`` dict.
        ``arrival_mults`` likewise (list in slot order or dict).
        Returns ``(statuses, results)`` for the active streams in slot
        order; a dropped/shed stream's result is None."""
        slots = sorted(self._stream_of)
        if isinstance(segments, dict):
            segs = [segments[self._stream_of[s]] for s in slots]
        else:
            assert len(segments) == len(slots), \
                f"need {len(slots)} segments (one per active stream)"
            segs = list(segments)
        K = len(self.sky.configs)
        arr_np = np.ones(self._cap, np.float32)
        if arrival_mults is not None:
            if isinstance(arrival_mults, dict):
                for sid, m in arrival_mults.items():
                    arr_np[self._slot_of[sid]] = m
            else:
                arr_np[np.asarray(slots)] = np.asarray(arrival_mults,
                                                       np.float32)
        dummy = jnp.zeros((self._cap, K), jnp.float32)
        cap_op = jnp.float32(np.inf if self.capacity_core_s is None
                             else self.capacity_core_s)
        wm_op = jnp.float32(np.inf if self.shed_watermark is None
                            else self.shed_watermark)
        self.state, outs = _pool_tick(
            self.state, jnp.asarray(self._pending_q),
            jnp.asarray(self._pending_valid), dummy,
            jnp.asarray(arr_np), self._active, self._priority,
            self._alpha, self.tables, cap_op, wm_op)
        self._bufs = _pool_shift(self._bufs, outs["c"])
        # async double-buffering: when this tick closes a planning
        # window, ENQUEUE the replan dispatch now — before the host
        # blocks on the decisions — so planning for window t+1 overlaps
        # the Transform work of window t on the host
        if (self._seen + 1) % self.sky._plan_every == 0:
            self._replan()
        ks = np.asarray(outs["k"])
        cats = np.asarray(outs["c"])
        bufs_s = np.asarray(outs["buffer_s"])
        drops = np.asarray(outs["dropped"])
        sheds = np.asarray(outs["shed"])
        statuses, results = [], []
        q_np = np.zeros(self._cap, np.float32)
        q_valid = np.zeros(self._cap, bool)
        for i, slot in enumerate(slots):
            k = int(ks[slot])
            status = {"stream_id": self._stream_of[slot],
                      "config": self.sky.configs[k], "k": k,
                      "category": int(cats[slot]),
                      "buffer_s": float(bufs_s[slot]),
                      "dropped": bool(drops[slot]),
                      "shed": bool(sheds[slot])}
            if drops[slot]:
                # shed/dropped: the segment is NOT transformed (that is
                # the work the shed saves); quality 0 by contract
                status["quality"] = 0.0
                results.append(None)
            else:
                result, q = self.sky.proc_fn(segs[i], self.sky.configs[k])
                q_np[slot] = q
                q_valid[slot] = True
                status["quality"] = float(q)
                results.append(result)
            statuses.append(status)
        active_np = np.asarray(self._active)
        if self._tel is not None:
            self._tel.update(outs, valid=active_np)
        # measured qualities fold into the NEXT tick's carried state
        # (inside the tick kernel — no extra dispatch)
        self._pending_q = q_np
        self._pending_valid = q_valid
        if self.sink is not None:
            # Load: the decision traces are already on device; the only
            # host-born values are the measured qualities themselves.
            # One row per ACTIVE stream, carrying its real stream id.
            ids = np.zeros(self._cap, np.int64)
            for slot in slots:
                ids[slot] = self._stream_of[slot]
            q_dev = jnp.asarray(q_np)
            out_vec = (jax.nn.one_hot(outs["k"], K, dtype=jnp.float32)
                       * q_dev[:, None])
            self.sink.ingest_tick(outs, quality=q_dev, out_vecs=out_vec,
                                  t=self._seen, stream_ids=ids,
                                  valid=active_np)
            # the tick dispatch above already refreshed any registered
            # standing queries; surface the fired alert masks per tick
            from repro.core.ingest import _notify_standing
            self.alerts = _notify_standing(self.sink)
        self._seen += 1
        return statuses, results

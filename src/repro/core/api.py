"""User-facing Skyscraper API (paper App. F).

    sky = Skyscraper(fps=30, segment_seconds=2.0)
    sky.set_resources(num_cores=8, buffer_gb=4.0, cloud_budget_core_s=0)
    sky.register_knob("det_interval", [1, 5, 10])
    sky.fit(unlabeled_segments, proc_fn)
    status, out = sky.process(segment)        # online, content-adaptive

``proc_fn(segment, knobs) -> (output, quality)`` is the user's transform
(the V-ETL *T*). fit() profiles every knob configuration's wall-clock
runtime (the paper's offline profiling), Pareto-filters configurations,
builds content categories from measured quality vectors, and trains the
forecaster. process() is the online loop: classify -> look up plan ->
switch -> execute.
"""
from __future__ import annotations

import functools
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import example_builder, register_engine
from repro.core.categories import kmeans
from repro.core.forecaster import (forecast_from_labels, init_forecaster,
                                   make_dataset, train_forecaster)
from repro.core.planner import solve_lp_lagrangian
from repro.core.switcher import (SwitchTables, init_state, init_state_multi,
                                 register_cache_probe, stack_tables,
                                 switch_step, switch_step_multi)


class Skyscraper:
    def __init__(self, fps: int = 30, segment_seconds: float = 2.0,
                 n_categories: int = 4, seed: int = 0):
        self.fps = fps
        self.tau = segment_seconds
        self.n_categories = n_categories
        self.seed = seed
        self.knobs: Dict[str, Sequence] = {}
        self.num_cores = 1
        self.buffer_gb = 4.0
        self.cloud_budget = 0.0
        self._fitted = False

    def set_resources(self, *, num_cores: int, buffer_gb: float = 4.0,
                      cloud_budget_core_s: float = 0.0):
        self.num_cores = num_cores
        self.buffer_gb = buffer_gb
        self.cloud_budget = cloud_budget_core_s
        self.budget_override = None

    def set_budget(self, core_s_per_segment: float):
        """Override the per-segment compute budget used by the planner
        (defaults to num_cores * segment_seconds)."""
        self.budget_override = core_s_per_segment
        if getattr(self, "_fitted", False):
            self._replan()

    def register_knob(self, name: str, domain: Sequence):
        self.knobs[name] = tuple(domain)

    # ------------------------------------------------------------------
    def fit(self, unlabeled: Sequence, proc_fn: Callable, *,
            profile_repeats: int = 1, plan_segments: int = 512,
            n_split: int = 4, max_k: int = 10):
        """unlabeled: list of segments (opaque to Skyscraper)."""
        configs = [dict(zip(self.knobs, v))
                   for v in itertools.product(*self.knobs.values())]
        # --- profile runtimes + quality vectors on the unlabeled data ---
        sample = unlabeled[:: max(1, len(unlabeled) // 40)]
        runtimes = np.zeros(len(configs))
        quals = np.zeros((len(unlabeled), len(configs)), np.float32)
        for ki, kv in enumerate(configs):
            t0 = time.perf_counter()
            for _ in range(profile_repeats):
                for seg in sample:
                    proc_fn(seg, kv)
            runtimes[ki] = ((time.perf_counter() - t0)
                            / (profile_repeats * len(sample)))
            for si, seg in enumerate(unlabeled):
                _, q = proc_fn(seg, kv)
                quals[si, ki] = q
        # --- Pareto-filter configurations -------------------------------
        mq = quals.mean(axis=0)
        order = np.argsort(runtimes)
        keep = []
        best_q = -1.0
        for i in order:
            if mq[i] > best_q + 1e-6:
                keep.append(i)
                best_q = mq[i]
        keep = keep[:max_k]
        self.configs = [configs[i] for i in keep]
        self.cost = runtimes[keep] * self.num_cores  # core-s per segment
        quals = quals[:, keep]
        # --- categories + forecaster ------------------------------------
        import jax
        centers, labels = kmeans(quals, min(self.n_categories, len(unlabeled)),
                                 seed=self.seed)
        self.centers = np.asarray(centers)
        C = self.centers.shape[0]
        interval = max(1, len(labels) // (4 * n_split))
        horizon = max(1, min(plan_segments, len(labels) // 4))
        X, Y = make_dataset(np.asarray(labels), C, interval=interval,
                            n_split=n_split, horizon=horizon)
        params = init_forecaster(jax.random.PRNGKey(self.seed), n_split, C)
        self.forecaster, self.forecast_metrics = train_forecaster(params, X, Y)
        self.n_split, self.interval = n_split, interval
        # --- switcher tables (single all-on-prem placement per config) --
        K = len(self.configs)
        rt = (self.cost / self.num_cores)[:, None]
        self.tables = SwitchTables(
            centers=jnp.asarray(self.centers),
            power=jnp.asarray(mq[keep]),
            cost=jnp.asarray(self.cost, jnp.float32),
            place_rt=jnp.asarray(rt, jnp.float32),
            place_on=jnp.asarray(self.cost[:, None], jnp.float32),
            place_cl=jnp.zeros((K, 1), jnp.float32),
            place_valid=jnp.ones((K, 1), bool),
            rank_pos=jnp.asarray(np.argsort(np.argsort(-mq[keep])), jnp.int32),
            tau=self.tau,
            buffer_cap_s=self.buffer_gb * 1e9 / 90e3,
            cloud_budget=self.cloud_budget,
        )
        self.state = init_state(self.tables)
        self.proc_fn = proc_fn
        self._labels_hist: List[int] = []
        self._plan_every = plan_segments
        self._seen = 0
        self._replan()
        self._fitted = True
        return self

    def _replan(self):
        C = self.centers.shape[0]
        need = self.n_split * self.interval
        if len(self._labels_hist) >= need:
            lab = jnp.asarray(self._labels_hist[-need:], jnp.int32)
            r = np.asarray(forecast_from_labels(
                self.forecaster, lab, C, n_split=self.n_split,
                interval=self.interval))
        else:
            r = np.full(C, 1.0 / C)
        budget = (self.budget_override if getattr(self, "budget_override",
                                                  None)
                  else self.num_cores * self.tau)
        self.alpha = solve_lp_lagrangian(
            jnp.asarray(self.centers), self.tables.cost,
            jnp.asarray(r, jnp.float32), jnp.float32(budget))

    # ------------------------------------------------------------------
    def process(self, segment, arrival_mult: float = 1.0):
        """Run the V-ETL Transform on one segment with adaptive knobs."""
        assert self._fitted, "call fit() first"
        K = len(self.configs)
        dummy_quals = jnp.zeros((K,), jnp.float32)  # filled post-exec
        self.state, out = switch_step(self.state, dummy_quals,
                                      jnp.float32(arrival_mult),
                                      self.alpha, self.tables)
        k = int(out["k"])
        result, q = self.proc_fn(segment, self.configs[k])
        # report the measured quality back (drives the next classification)
        self.state["qual_prev"] = jnp.float32(q)
        self._labels_hist.append(int(out["c"]))
        self._seen += 1
        if self._seen % self._plan_every == 0:
            self._replan()
        return {"config": self.configs[k], "k": k, "category": int(out["c"]),
                "quality": float(q),
                "buffer_s": float(out["buffer_s"])}, result


@functools.partial(jax.jit, static_argnames=("n_split", "interval"))
def _pool_replan(params, bufs, centers, cost, budget, use_model, *,
                 n_split: int, interval: int):
    """Device-side batched replanning for V streams: each stream's
    rolling label buffer -> histogram features -> forecaster MLP -> LP,
    all vmapped into one dispatch. ``use_model`` (traced bool) falls
    back to the uniform prior until the buffers have filled once —
    flipping it never recompiles."""
    C = centers.shape[0]
    r_model = jax.vmap(lambda b: forecast_from_labels(
        params, b, C, n_split=n_split, interval=interval))(bufs)
    r = jnp.where(use_model, r_model,
                  jnp.full_like(r_model, 1.0 / C))
    return jax.vmap(lambda rv: solve_lp_lagrangian(centers, cost, rv,
                                                   budget))(r)


_pool_shift = jax.jit(lambda bufs, c: jnp.concatenate(
    [bufs[:, 1:], c[:, None].astype(jnp.int32)], axis=1))

register_cache_probe("pool_replan", lambda: _pool_replan._cache_size())
register_cache_probe("pool_shift", lambda: _pool_shift._cache_size())
register_engine("pool_replan", example_builder("pool_replan"),
                probe=lambda: _pool_replan._cache_size(),
                covers=("repro.core.api:_pool_replan",),
                probe_name="pool_replan")
register_engine("pool_shift", example_builder("pool_shift"),
                probe=lambda: _pool_shift._cache_size(),
                covers=("repro.core.api:_pool_shift",),
                probe_name="pool_shift")


class SkyscraperPool:
    """V live streams sharing one fitted profile, switched by the batched
    engine: ONE vmapped jit dispatch decides all V knob configs per tick
    (paper App. D scenario 1 as an online serving loop).

    Fused planning: per-stream category histories live in a device-side
    rolling label buffer (V, hist_len) updated by a jitted shift each
    tick, and replanning is ONE compiled call (vmapped forecaster +
    stacked LP) — zero host-side planning work per tick, and the same
    three executables (step / shift / replan) serve forever.

        pool = SkyscraperPool(fitted_sky, n_streams=8)
        statuses, outputs = pool.process([seg0, ..., seg7])

    ``sink``: an optional ``warehouse.SegmentStore`` (with
    ``out_dim == len(sky.configs)``) — every tick lands one row per
    stream in the warehouse: the batched switch decision straight off
    the device, plus the measured quality reported by the Transform. A
    ``warehouse.ShardedStore`` sink routes stream ``v``'s row to shard
    ``v % n_shards`` inside the same tick dispatch. Standing queries
    registered on the sink (``warehouse.standing``) refresh inside that
    dispatch too, and each tick's fired alert subscriptions surface in
    ``pool.alerts``.

    ``telemetry=True`` attaches the serving-loop flight recorder: a
    host-side sequential float32 accumulator (``repro.obs``'s
    ``HostTelemetry``) fed from the per-tick outs the pool already
    pulls to host for the Transform — zero extra device dispatches,
    and the same bit-exactness contract as the fused engines' carried
    counters. Read it with ``pool.telemetry()``.
    """

    def __init__(self, sky: Skyscraper, n_streams: int, sink=None,
                 telemetry: bool = False):
        assert sky._fitted, "fit() the Skyscraper first"
        self.sky = sky
        self.V = n_streams
        self.sink = sink
        # per-stream buffer/cloud state over shared tables
        self.tables = stack_tables([sky.tables] * n_streams)
        self.state = init_state_multi([sky.tables] * n_streams)
        # per-stream category history as a fixed-shape device carry
        self._hist_len = sky.n_split * sky.interval
        self._bufs = jnp.zeros((n_streams, self._hist_len), jnp.int32)
        self._alpha = jnp.broadcast_to(
            sky.alpha, (n_streams,) + sky.alpha.shape)
        self._seen = 0
        # last tick's fired standing-query alerts (see ``process``)
        self.alerts = []
        self._tel = None
        if telemetry:
            from repro.obs.telemetry import HostTelemetry
            k0 = int(np.argmin(np.asarray(sky.tables.rank_pos)))
            self._tel = HostTelemetry(n_streams, k0)

    def telemetry(self):
        """Snapshot of the pool's flight recorder (``repro.obs``'s
        ``Telemetry``), or None when constructed without one."""
        return None if self._tel is None else self._tel.snapshot()

    def _replan(self):
        """Per-stream plans from each stream's OWN recorded categories
        (forecast -> LP), one fused device call across all V streams."""
        sky = self.sky
        budget = (sky.budget_override
                  if getattr(sky, "budget_override", None)
                  else sky.num_cores * sky.tau)
        self._alpha = _pool_replan(
            sky.forecaster, self._bufs, jnp.asarray(sky.centers, jnp.float32),
            sky.tables.cost, jnp.float32(budget),
            jnp.asarray(self._seen >= self._hist_len),
            n_split=sky.n_split, interval=sky.interval)
        if self._tel is not None:
            self._tel.replans += 1

    def process(self, segments, arrival_mults: Optional[Sequence] = None):
        """One batched switch decision + per-stream Transform execution.
        segments: length-V list (one per stream)."""
        assert len(segments) == self.V
        K = len(self.sky.configs)
        arr = jnp.asarray(arrival_mults if arrival_mults is not None
                          else np.ones(self.V), jnp.float32)
        dummy = jnp.zeros((self.V, K), jnp.float32)
        self.state, outs = switch_step_multi(self.state, dummy, arr,
                                             self._alpha, self.tables)
        self._bufs = _pool_shift(self._bufs, outs["c"])
        ks = np.asarray(outs["k"])
        statuses, results, q_meas = [], [], np.zeros(self.V, np.float32)
        for v, seg in enumerate(segments):
            result, q = self.sky.proc_fn(seg, self.sky.configs[int(ks[v])])
            q_meas[v] = q
            results.append(result)
            statuses.append({"config": self.sky.configs[int(ks[v])],
                             "k": int(ks[v]),
                             "category": int(np.asarray(outs["c"])[v]),
                             "quality": float(q),
                             "buffer_s": float(np.asarray(outs["buffer_s"])[v])})
        if self._tel is not None:
            self._tel.update(outs)
        # report measured qualities back (drive the next classification)
        q_dev = jnp.asarray(q_meas)
        self.state["qual_prev"] = q_dev
        if self.sink is not None:
            # Load: the decision traces are already on device; the only
            # host-born values are the measured qualities themselves
            out_vec = (jax.nn.one_hot(outs["k"], K, dtype=jnp.float32)
                       * q_dev[:, None])
            self.sink.ingest_tick(outs, quality=q_dev, out_vecs=out_vec,
                                  t=self._seen)
            # the tick dispatch above already refreshed any registered
            # standing queries; surface the fired alert masks per tick
            from repro.core.ingest import _notify_standing
            self.alerts = _notify_standing(self.sink)
        self._seen += 1
        if self._seen % self.sky._plan_every == 0:
            self._replan()
        return statuses, results

"""Online video ingestion (paper §4) + the paper's baselines.

``run_skyscraper``: planning windows (forecast -> LP -> α) around a
jit-scanned switcher loop, driven by a host Python loop (one dispatch
per window). ``run_skyscraper_fused``: the SAME pipeline as ONE
compiled program — an outer ``lax.scan`` over planning windows whose
body inlines the forecaster (rolling label-histogram carry), the
Lagrangian LP on the in-carry cloud-budget ration, and the switcher
window scan — so a T-segment run is one dispatch instead of T/W.
Baselines: Static (fixed config), Chameleon* (periodic profiling,
buffer-agnostic), VideoStorm-like (query-load adaptive: always the most
qualitative feasible config), and Optimum (ground-truth knapsack —
solved exactly via the same Lagrangian machinery with one "category"
per segment).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import example_builder, register_engine
from repro.core.forecaster import forecast_from_labels
from repro.core.offline import Fitted
from repro.core.planner import (solve_lp_lagrangian, solve_lp_rationed,
                                solve_lp_stacked)
from repro.core.switcher import (SwitchTables, init_state, init_state_multi,
                                 pad_window, pad_window_multi,
                                 register_cache_probe, run_window,
                                 run_window_multi, stack_tables, window_scan,
                                 window_scan_multi)
from repro.data.stream import Stream
from repro.obs.telemetry import (Telemetry, tel_init, window_scan_multi_tel,
                                 window_scan_tel)

CLOUD_PREMIUM = 1.8      # App. L


@dataclass
class RunResult:
    """Aggregate outcome of one simulated stream run: quality sums,
    core-seconds by tier, buffer peak/overflow, and the config-choice
    histogram/trace the ablation tables report."""
    quality_sum: float
    quality_max_sum: float
    onprem_core_s: float
    cloud_core_s: float
    buffer_peak_s: float
    overflow: bool
    k_hist: np.ndarray
    c_trace: np.ndarray = None
    k_trace: np.ndarray = None
    buffer_trace: np.ndarray = None
    plans: List = field(default_factory=list)
    telemetry: Optional[Telemetry] = None
    # fired standing-query alerts from the sink's registry (one
    # ``warehouse.standing.Alert`` per subscription), polled right
    # after the run's rows landed — empty without a sink/registry
    alerts: List = field(default_factory=list)

    @property
    def quality_pct(self) -> float:
        return 100.0 * self.quality_sum / max(self.quality_max_sum, 1e-9)

    @property
    def work_core_s(self) -> float:
        return self.onprem_core_s + self.cloud_core_s


def _max_quality(stream: Stream, power: np.ndarray) -> np.ndarray:
    from repro.core.knobs import quality as qfn
    return qfn(power.max(), stream.difficulty)


def _assemble_result(cat: Dict[str, np.ndarray], qmax: np.ndarray, K: int,
                     plans: List) -> RunResult:
    """RunResult from a flattened trace dict — shared by the windowed
    and fused engines so their reported fields can never drift apart."""
    return RunResult(
        quality_sum=float(cat["qual"].sum()),
        quality_max_sum=float(qmax.sum()),
        onprem_core_s=float(cat["on_s"].sum()),
        cloud_core_s=float(cat["cl_s"].sum()),
        buffer_peak_s=float(cat["buffer_s"].max()),
        overflow=False,
        k_hist=np.bincount(cat["k"], minlength=K),
        c_trace=cat["c"], k_trace=cat["k"], buffer_trace=cat["buffer_s"],
        plans=plans)


def _oracle_rate(q_w, centers, valid, w_tf):
    """Nearest-center labels over a window -> valid-masked category
    rate. Works batched ((V, W, K) quals vs (V, C, K) centers) and
    unbatched; sentinel padding rows never win the argmin, so padded
    categories get rate 0. One definition keeps the single- and
    multi-stream fused engines' forecasts in lockstep."""
    d = ((q_w[..., :, None, :] - centers[..., None, :, :]) ** 2).sum(-1)
    oh = jax.nn.one_hot(jnp.argmin(d, axis=-1), centers.shape[-2],
                        dtype=jnp.float32)
    return (oh * valid[..., None]).sum(-2) / w_tf


def run_skyscraper(fitted: Fitted, stream: Stream, *, n_cores: int,
                   cloud_budget_core_s: float = 0.0, buffer_gb: float = 4.0,
                   plan_days: Optional[float] = None,
                   forecast_mode: str = "model",   # model | oracle | uniform
                   online_finetune: bool = False,  # App. E.2
                   seed: int = 0) -> RunResult:
    """Reference (non-fused) online loop from the paper: plan per window
    with the chosen forecast mode, then switch/process each segment;
    returns the run's aggregate ``RunResult``."""
    w = fitted.workload
    tau = w.segment_seconds
    plan_days = plan_days or fitted.horizon_segments * tau / 86400
    W = max(1, int(plan_days * 86400 / tau))
    tables = fitted.tables(buffer_gb=buffer_gb,
                           cloud_budget=cloud_budget_core_s)
    quals = jnp.asarray(stream.quality(fitted.power, seed=seed))
    arrivals = jnp.asarray(stream.arrival, jnp.float32)
    T = stream.n_segments
    C, K = fitted.centers.shape
    centers = jnp.asarray(fitted.centers)
    cost = jnp.asarray(fitted.cost)

    state = init_state(tables)
    labels_hist: List[np.ndarray] = []
    outs_all = {k: [] for k in ("k", "c", "qual", "on_s", "cl_s", "buffer_s")}
    plans = []
    t = 0
    while t < T:
        W_t = min(W, T - t)
        # ---- forecast r (category distribution over the window) ---------
        if forecast_mode == "oracle":
            q_true = np.asarray(quals[t:t + W_t])
            d = ((q_true[:, None, :] - fitted.centers[None]) ** 2).sum(-1)
            lab = d.argmin(1)
            r = np.bincount(lab, minlength=C) / W_t
        elif forecast_mode == "model" and labels_hist:
            need = fitted.interval_segments * fitted.n_split
            lab = np.concatenate(labels_hist)[-need:]
            if len(lab) < need:
                lab = np.concatenate([np.zeros(need - len(lab), np.int64),
                                      lab])
            r = np.asarray(forecast_from_labels(
                fitted.forecaster, jnp.asarray(lab, jnp.int32), C,
                n_split=fitted.n_split, interval=fitted.interval_segments))
        else:
            r = np.full(C, 1.0 / C)
        # ---- plan (budget = on-prem + rationed cloud, in core-s) --------
        cloud_left = cloud_budget_core_s - float(state["cloud_spent"])
        frac = W_t / (T - t)
        budget = n_cores * tau * W_t + max(cloud_left, 0.0) * frac / CLOUD_PREMIUM
        # LP cost is per segment; hand the planner the per-segment budget
        alpha = solve_lp_lagrangian(centers, cost, jnp.asarray(r, jnp.float32),
                                    jnp.float32(budget / W_t))
        plans.append((np.asarray(r), np.asarray(alpha)))
        # ---- reactive switching over the window --------------------------
        # pad the (possibly short) tail window to the fixed length W so
        # every window lowers to the same jaxpr — zero recompiles after
        # the first window; masked padding steps are exact no-ops.
        q_w, a_w, valid = pad_window(quals[t:t + W_t], arrivals[t:t + W_t], W)
        state, outs = run_window(state, q_w, a_w, alpha, tables, valid=valid)
        for kk in outs_all:
            outs_all[kk].append(np.asarray(outs[kk])[:W_t])
        labels_hist.append(np.asarray(outs["c"])[:W_t])
        t += W_t
        # App. E.2: continuous online fine-tuning of the forecaster on
        # the categories the switcher itself has been recording
        if online_finetune and forecast_mode == "model":
            lab = np.concatenate(labels_hist)
            need = fitted.interval_segments * (fitted.n_split + 2)
            if len(lab) >= need:
                from repro.core.forecaster import (make_dataset,
                                                   train_forecaster)
                X, Y = make_dataset(lab, C,
                                    interval=fitted.interval_segments,
                                    n_split=fitted.n_split,
                                    horizon=min(W, len(lab) // 4))
                if len(X) >= 8:
                    fitted.forecaster, _ = train_forecaster(
                        fitted.forecaster, X, Y, epochs=3, seed=seed)

    cat = {k: np.concatenate(v) for k, v in outs_all.items()}
    return _assemble_result(cat, _max_quality(stream, fitted.power), K,
                            plans)


@functools.partial(jax.jit,
                   static_argnames=("mode", "n_split", "interval",
                                    "telemetry"))
def _fused_run(state, buf, quals_w, arrs_w, valid_w, wts, fracs, tables,
               centers, cost, params, core_s_per_seg, cloud_budget, *,
               mode: str, n_split: int, interval: int,
               telemetry: bool = False):
    """The whole online phase as ONE compiled program: an outer scan over
    planning windows; each body = forecast -> LP -> inner window scan.

    quals_w (n_w, W, K); arrs_w/valid_w (n_w, W); wts (n_w,) int32 real
    segments per window; fracs (n_w,) the window's share of the remaining
    run (the cloud ration). ``buf`` is the rolling label buffer feeding
    the forecaster ("model" mode); the label bincounts that the host loop
    kept in numpy live entirely in the carry.

    ``telemetry`` (static) threads the flight-recorder counter pytree
    through the carry and snapshots it at every window boundary (an
    extra ys leaf) — still one dispatch. False keeps every branch below
    on the pre-telemetry code path, so the no-telemetry program traces
    to the exact same jaxpr as before the flag existed (the census
    equality test pins this).
    """
    C = centers.shape[0]
    need = n_split * interval

    def body(carry, xs):
        if telemetry:
            st, buf, n_seen, tel = carry
        else:
            st, buf, n_seen = carry
        q_w, a_w, valid, w_t, frac = xs
        w_tf = w_t.astype(jnp.float32)
        # ---- forecast r (category distribution over the window) -------
        if mode == "oracle":
            r = _oracle_rate(q_w, centers, valid, w_tf)
        elif mode == "model":
            r = jnp.where(n_seen > 0,
                          forecast_from_labels(params, buf, C,
                                               n_split=n_split,
                                               interval=interval),
                          jnp.full((C,), 1.0 / C, jnp.float32))
        else:
            r = jnp.full((C,), 1.0 / C, jnp.float32)
        # ---- plan: cloud ration computed from the in-carry spend ------
        alpha = solve_lp_rationed(
            centers, cost, r,
            core_s_per_segment=core_s_per_seg,
            cloud_left=cloud_budget - st["cloud_spent"],
            frac=frac, window_len=w_tf, cloud_premium=CLOUD_PREMIUM)
        # ---- reactive switching (the PR-1 window body, inlined) -------
        if telemetry:
            (st, tel), outs = window_scan_tel(st, tel, q_w, a_w, valid,
                                              alpha, tables)
        else:
            st, outs = window_scan(st, q_w, a_w, valid, alpha, tables)
        # ---- roll the W_t real labels into the history buffer ---------
        # (only the forecaster reads it; mode is static, so the roll
        # disappears from the oracle/uniform programs at trace time)
        if mode == "model":
            cat = jnp.concatenate([buf, outs["c"].astype(jnp.int32)])
            buf = jax.lax.dynamic_slice(cat, (w_t,), (need,))
        if telemetry:
            return (st, buf, n_seen + w_t, tel), (outs, r, alpha, tel)
        return (st, buf, n_seen + w_t), (outs, r, alpha)

    if telemetry:
        (state, _, _, _), (outs, rs, alphas, tels) = jax.lax.scan(
            body, (state, buf, jnp.int32(0), tel_init(state)),
            (quals_w, arrs_w, valid_w, wts, fracs))
        return state, outs, rs, alphas, tels
    (state, _, _), (outs, rs, alphas) = jax.lax.scan(
        body, (state, buf, jnp.int32(0)),
        (quals_w, arrs_w, valid_w, wts, fracs))
    return state, outs, rs, alphas


register_cache_probe("fused_single", lambda: _fused_run._cache_size())
register_engine("fused_single", example_builder("fused_single"),
                probe=lambda: _fused_run._cache_size(),
                covers=("repro.core.ingest:_fused_run",),
                probe_name="fused_single")
# telemetry=True variant: own jit cache entry (static flag), still one
# dispatch — audited separately so the flight recorder can never
# silently grow a second executable or a host transfer
register_engine("fused_single_telemetry",
                example_builder("fused_single_telemetry"),
                probe=lambda: _fused_run._cache_size(),
                covers=("repro.core.ingest:_fused_run",
                        "repro.obs.telemetry:window_scan_tel"),
                probe_name="fused_single")


def fused_cache_size() -> int:
    """jit cache entries of the fused whole-run engine (single-stream):
    exactly 1 after warmup means the entire T-segment run re-uses one
    executable."""
    return _fused_run._cache_size()


def _notify_standing(sink):
    """Poll the sink's standing-query alert subscriptions right after a
    run's rows landed (the ingest dispatch itself already refreshed the
    registered partials — see ``warehouse.standing``); returns the
    fired-alert list, [] when the sink has no registry/subscriptions."""
    reg = getattr(sink, "standing", None)
    if reg is None or not reg.has_subscriptions:
        return []
    return reg.poll()


def _window_layout(T: int, W: int):
    """Split a T-segment run into ceil(T/W) fixed-length windows: padded
    reshape layout plus per-window real lengths and cloud rations."""
    n_w = -(-T // W)
    pad = n_w * W - T
    starts = np.arange(n_w) * W
    wts = np.minimum(W, T - starts).astype(np.int32)
    fracs = (wts / (T - starts)).astype(np.float32)
    return n_w, pad, wts, fracs


def run_skyscraper_fused(fitted: Fitted, stream: Stream, *, n_cores: int,
                         cloud_budget_core_s: float = 0.0,
                         buffer_gb: float = 4.0,
                         plan_days: Optional[float] = None,
                         forecast_mode: str = "model",
                         seed: int = 0, sink=None, sink_stream_id: int = 0,
                         sink_t0: int = 0,
                         telemetry: bool = False) -> RunResult:
    """``run_skyscraper`` as one dispatch: same planning windows, same
    forecasts, same LP, same switcher — fused into a single outer scan
    (results match the windowed loop to float32 tolerance). No
    ``online_finetune``: training inside the scan would defeat the
    point; use the windowed loop for App. E.2 experiments.

    ``sink``: an optional ``warehouse.SegmentStore`` (or
    ``warehouse.ShardedStore``, which lands the run on the shard owning
    ``sink_stream_id`` device-side) — the Load side. The engine hands
    its still-device-resident stacked traces (plus the (T, K)
    measured-quality vectors as the per-segment output column) straight
    to ``sink.ingest_fused``, so ingestion -> store is zero per-segment
    host transfers.

    ``telemetry=True`` attaches the flight recorder: the run's
    ``RunResult.telemetry`` carries cumulative + per-window counters
    (drops, buffer high-water mark, on-prem/cloud core-seconds, config
    switches), accumulated inside the same single dispatch and
    bit-exact against ``repro.obs.telemetry_ref``."""
    w = fitted.workload
    tau = w.segment_seconds
    plan_days = plan_days or fitted.horizon_segments * tau / 86400
    W = max(1, int(plan_days * 86400 / tau))
    tables = fitted.tables(buffer_gb=buffer_gb,
                           cloud_budget=cloud_budget_core_s)
    quals = jnp.asarray(stream.quality(fitted.power, seed=seed), jnp.float32)
    arrivals = jnp.asarray(stream.arrival, jnp.float32)
    T = stream.n_segments
    C, K = fitted.centers.shape
    centers = jnp.asarray(fitted.centers, jnp.float32)
    cost = jnp.asarray(fitted.cost, jnp.float32)
    n_w, pad, wts, fracs = _window_layout(T, W)
    quals_w = jnp.pad(quals, ((0, pad), (0, 0))).reshape(n_w, W, K)
    arrs_w = jnp.pad(arrivals, (0, pad),
                     constant_values=1.0).reshape(n_w, W)
    valid_w = (jnp.arange(n_w * W) < T).reshape(n_w, W)
    need = fitted.interval_segments * fitted.n_split
    fused = _fused_run(
        init_state(tables), jnp.zeros((need,), jnp.int32), quals_w, arrs_w,
        valid_w, jnp.asarray(wts), jnp.asarray(fracs), tables, centers,
        cost, fitted.forecaster if forecast_mode == "model" else None,
        jnp.float32(n_cores * tau), jnp.float32(cloud_budget_core_s),
        mode=forecast_mode, n_split=fitted.n_split,
        interval=fitted.interval_segments, telemetry=telemetry)
    if telemetry:
        state, outs, rs, alphas, tels = fused
        tel = Telemetry.from_device(tels)
    else:
        state, outs, rs, alphas = fused
        tel = None
    alerts = []
    if sink is not None:
        # Load: the stacked (n_w, W) traces and the (T, K) quality
        # vectors never leave the device on their way into the store
        sink.ingest_fused(outs, quals, stream_id=sink_stream_id,
                          t0=sink_t0)
        alerts = _notify_standing(sink)
    # un-window the traces: padding only ever sits at the very end, so
    # the flattened prefix [:T] is the run in time order
    cat = {k: np.asarray(v).reshape((n_w * W,) + v.shape[2:])[:T]
           for k, v in outs.items()}
    rs, alphas = np.asarray(rs), np.asarray(alphas)
    res = _assemble_result(cat, _max_quality(stream, fitted.power), K,
                           [(rs[i], alphas[i]) for i in range(n_w)])
    res.telemetry = tel
    res.alerts = alerts
    return res


def _multi_prep(fitteds, streams, *, buffer_gb, cloud_budget_core_s, seed):
    """Shared multi-stream setup: sentinel-padded per-stream tables
    stacked to static (V, C_max, K) shapes + stacked stream data."""
    import dataclasses as _dc
    V = len(fitteds)
    T = min(s.n_segments for s in streams)
    K = len(fitteds[0].configs)
    assert all(len(f.configs) == K for f in fitteds), \
        "joint plan shares one cost table: config counts must match"
    Cs = [f.centers.shape[0] for f in fitteds]
    C_max = max(Cs)
    tables = []
    for f, C_v in zip(fitteds, Cs):
        tb = f.tables(buffer_gb=buffer_gb,
                      cloud_budget=cloud_budget_core_s / V)
        if C_v < C_max:
            # sentinel rows: |center - qual| is huge, so argmin never
            # classifies a segment into a padding category
            pad = jnp.full((C_max - C_v, K), 1e6, jnp.float32)
            tb = _dc.replace(tb, centers=jnp.concatenate([tb.centers, pad]))
        tables.append(tb)
    quals = jnp.stack([jnp.asarray(s.quality(f.power, seed=seed))[:T]
                       for s, f in zip(streams, fitteds)])      # (V,T,K)
    arrs = jnp.stack([jnp.asarray(s.arrival[:T], jnp.float32)
                      for s in streams])                        # (V,T)
    qmax = np.stack([np.asarray(_max_quality(s, f.power))[:T]
                     for s, f in zip(streams, fitteds)]).sum(axis=1)
    return V, T, K, Cs, C_max, tables, quals, arrs, qmax


@functools.partial(jax.jit, static_argnames=("with_traces", "telemetry"))
def _fused_run_multi(state, quals_w, arrs_w, valid_w, wts, tables,
                     cost, core_s_total, cloud_ration, *,
                     with_traces: bool = False, telemetry: bool = False):
    """Whole multi-stream run as one program: outer scan over windows;
    each body = per-stream oracle forecast -> joint stacked LP -> the
    batched V-stream window scan. quals_w (n_w, V, W, K); arrs_w/valid_w
    (n_w, V, W); wts (n_w,) int32. Returns the final state plus, with
    ``with_traces`` (a warehouse sink is attached), the full per-segment
    traces ((n_w, V, W) leaves, padding zeroed); otherwise just the
    per-window per-stream quality sums (n_w, V), so sink-less runs never
    materialize V*T traces they would discard.

    ``telemetry`` (static) adds the per-stream (V,) counter pytree to
    the carry plus its window-boundary snapshots to the ys — the False
    path is byte-identical to the pre-flag program."""
    centers = tables.centers                              # (V, C_max, K)

    def body(carry, xs):
        if telemetry:
            st, tel = carry
        else:
            st = carry
        q_w, a_w, valid, w_t = xs
        # per-stream oracle r over the window (App. D Eq. 7-9)
        r = _oracle_rate(q_w, centers, valid, w_t.astype(jnp.float32))
        # the LP's spend constraint is PER SEGMENT: on-prem capacity plus
        # the evenly-rationed premium-discounted cloud budget
        alpha = solve_lp_stacked(centers, cost, r,
                                 core_s_total + cloud_ration)
        if telemetry:
            (st, tel), outs = window_scan_multi_tel(st, tel, q_w, a_w,
                                                    valid, alpha, tables)
            res = outs if with_traces else outs["qual"].sum(axis=1)
            return (st, tel), (res, tel)
        st, outs = window_scan_multi(st, q_w, a_w, valid, alpha, tables)
        return st, (outs if with_traces else outs["qual"].sum(axis=1))

    if telemetry:
        carry0 = (state, tel_init(state))
    else:
        carry0 = state
    return jax.lax.scan(body, carry0, (quals_w, arrs_w, valid_w, wts))


register_cache_probe("fused_multi", lambda: _fused_run_multi._cache_size())
register_engine("fused_multi", example_builder("fused_multi"),
                probe=lambda: _fused_run_multi._cache_size(),
                covers=("repro.core.ingest:_fused_run_multi",),
                probe_name="fused_multi")
register_engine("fused_multi_telemetry",
                example_builder("fused_multi_telemetry"),
                probe=lambda: _fused_run_multi._cache_size(),
                covers=("repro.core.ingest:_fused_run_multi",
                        "repro.obs.telemetry:window_scan_multi_tel"),
                probe_name="fused_multi")


def run_skyscraper_multi(fitteds, streams, *, n_cores_each: int,
                         cloud_budget_core_s: float = 0.0,
                         buffer_gb: float = 4.0,
                         plan_days: float = 0.25, seed: int = 0,
                         sink=None, sink_stream_base: int = 0,
                         sink_t0: int = 0, telemetry: bool = False):
    """Multi-stream ingestion (paper App. D, scenario 1): each stream has
    its own cores + buffer; the cloud budget and the knob PLAN are joint —
    one LP over all streams' categories so the shared budget flows to the
    stream where it buys the most quality.

    Fused engine: the ENTIRE run is one compiled program — an outer scan
    over planning windows whose body computes every stream's forecast,
    solves the joint LP on device (``solve_lp_stacked`` over the static
    sentinel-padded (V, C_max, K) category stack), and executes the
    batched V-stream switcher window. Zero host planning work per
    window; one dispatch per run instead of T/W.

    ``sink``: optional ``warehouse.SegmentStore`` — all V streams'
    per-segment traces land in the store device-side (rows are
    stream-major; stream ids start at ``sink_stream_base``). A
    ``warehouse.ShardedStore`` sink routes each stream's whole trace to
    shard ``(sink_stream_base + v) % n_shards`` in the same single
    dispatch, without gathering anything through the host.

    ``telemetry=True`` adds a ``"telemetry"`` key to the result dict: a
    ``repro.obs.Telemetry`` with per-stream (V,) counters accumulated
    in the same single dispatch, bit-exact vs ``telemetry_ref``.
    """
    tau = fitteds[0].workload.segment_seconds
    W = max(1, int(plan_days * 86400 / tau))
    V, T, K, _, _, tables, quals, arrs, qmax = _multi_prep(
        fitteds, streams, buffer_gb=buffer_gb,
        cloud_budget_core_s=cloud_budget_core_s, seed=seed)
    n_w, pad, wts, _ = _window_layout(T, W)
    quals_w = jnp.pad(quals, ((0, 0), (0, pad), (0, 0))) \
        .reshape(V, n_w, W, K).transpose(1, 0, 2, 3)      # (n_w, V, W, K)
    arrs_w = jnp.pad(arrs, ((0, 0), (0, pad)), constant_values=1.0) \
        .reshape(V, n_w, W).transpose(1, 0, 2)            # (n_w, V, W)
    valid_w = jnp.broadcast_to((jnp.arange(n_w * W) < T).reshape(n_w, 1, W),
                               (n_w, V, W))
    _, ys = _fused_run_multi(
        init_state_multi(tables), quals_w, arrs_w, valid_w,
        jnp.asarray(wts), stack_tables(tables),
        jnp.asarray(fitteds[0].cost, jnp.float32),
        jnp.float32(V * n_cores_each * tau),
        jnp.float32(cloud_budget_core_s / (CLOUD_PREMIUM * max(T, 1))),
        with_traces=sink is not None, telemetry=telemetry)
    if telemetry:
        res, tels = ys
        tel = Telemetry.from_device(tels)
    else:
        res, tel = ys, None
    alerts = []
    if sink is not None:
        sink.ingest_fused_multi(res, quals, stream_base=sink_stream_base,
                                t0=sink_t0)
        alerts = _notify_standing(sink)
        # padded segments are exact no-ops, so summing over (n_w, W) is
        # the per-stream quality total
        sums = np.asarray(res["qual"]).sum(axis=(0, 2))
    else:
        sums = np.asarray(res).sum(axis=0)
    out = {"quality_pct": 100.0 * sums.sum() / max(qmax.sum(), 1e-9),
           "per_stream_pct": (100.0 * sums
                              / np.maximum(qmax, 1e-9)).tolist()}
    if alerts:
        out["alerts"] = alerts
    if telemetry:
        out["telemetry"] = tel
    return out


def run_skyscraper_multi_windowed(fitteds, streams, *, n_cores_each: int,
                                  cloud_budget_core_s: float = 0.0,
                                  buffer_gb: float = 4.0,
                                  plan_days: float = 0.25, seed: int = 0):
    """The PR-1 windowed host loop (one batched window scan dispatch per
    window, host-side forecast + LP between windows) — kept as the
    reference/baseline the fused engine is benchmarked against."""
    from repro.core.planner import solve_multi_stream
    tau = fitteds[0].workload.segment_seconds
    W = max(1, int(plan_days * 86400 / tau))
    V, T, K, Cs, C_max, tables, quals, arrs, qmax = _multi_prep(
        fitteds, streams, buffer_gb=buffer_gb,
        cloud_budget_core_s=cloud_budget_core_s, seed=seed)
    tab_stack = stack_tables(tables)
    state = init_state_multi(tables)
    sums = np.zeros(V)
    t = 0
    while t < T:
        W_t = min(W, T - t)
        # joint plan: per-stream oracle r over the window (App. D Eq. 7-9)
        rs, qs = [], []
        for v in range(V):
            q_true = np.asarray(quals[v, t:t + W_t])
            d = ((q_true[:, None, :] - fitteds[v].centers[None]) ** 2).sum(-1)
            lab = d.argmin(1)
            rs.append(np.bincount(lab, minlength=Cs[v]) / W_t)
            qs.append(fitteds[v].centers)
        # the LP's spend constraint is per segment: on-prem capacity plus
        # the evenly-rationed premium-discounted cloud budget
        budget = V * n_cores_each * tau + (cloud_budget_core_s
                                           / (CLOUD_PREMIUM * T))
        alphas = solve_multi_stream(qs, fitteds[0].cost, rs, budget)
        a_stack = np.zeros((V, C_max, K), np.float32)
        for v, a in enumerate(alphas):
            a_stack[v, :Cs[v]] = np.asarray(a)
        # pad the tail window to W (masked steps are exact no-ops) and
        # run ALL streams through the single fused scan
        q_w, a_w, valid = pad_window_multi(quals[:, t:t + W_t],
                                           arrs[:, t:t + W_t], W)
        state, outs = run_window_multi(state, q_w, a_w,
                                       jnp.asarray(a_stack), tab_stack,
                                       valid=valid)
        sums += np.asarray(outs["qual"]).sum(axis=1)   # padding is zeroed
        t += W_t
    return {"quality_pct": 100.0 * sums.sum() / max(qmax.sum(), 1e-9),
            "per_stream_pct": (100.0 * sums / np.maximum(qmax, 1e-9)).tolist()}


def _run_fixed_policy(fitted: Fitted, stream: Stream, pick_k, *,
                      n_cores: int, buffer_gb: float = 4.0,
                      cloud_budget_core_s: float = 0.0,
                      extra_backlog: Optional[np.ndarray] = None,
                      seed: int = 0) -> RunResult:
    """Shared numpy loop for Static / Chameleon* / VideoStorm baselines.
    pick_k(t, measured_qualities) -> config index. Buffer-agnostic
    policies may overflow: overflowing segments are dropped (quality 0).
    """
    w = fitted.workload
    tau = w.segment_seconds
    cap_s = buffer_gb * 1e9 / 90e3
    quals = stream.quality(fitted.power, seed=seed)
    K = len(fitted.configs)
    b = 0.0
    cloud = 0.0
    on_sum = cl_sum = q_sum = 0.0
    peak = 0.0
    overflow = False
    k_hist = np.zeros(K, np.int64)
    T = stream.n_segments
    for t in range(T):
        k = pick_k(t, quals[t])
        m = stream.arrival[t]
        # cheapest placement that fits buffer + cloud budget
        rts = fitted.place_rt[k] * m
        cls_ = fitted.place_cl[k] * m
        ons = fitted.place_on[k] * m
        feas = fitted.place_valid[k] & (rts <= tau + (cap_s - b)) \
            & (cloud + cls_ <= cloud_budget_core_s)
        if feas.any():
            p = np.where(feas, cls_, np.inf).argmin()
            rt, on_s, cl_s = rts[p], ons[p], cls_[p]
            q = quals[t, k]
        else:
            # buffer-agnostic baseline would overflow: drop the segment
            overflow = True
            rt, on_s, cl_s, q = 0.0, 0.0, 0.0, 0.0
        if extra_backlog is not None:
            b += extra_backlog[t] / n_cores
        b = max(0.0, b + rt - tau)
        peak = max(peak, b)
        cloud += cl_s
        on_sum += on_s
        cl_sum += cl_s
        q_sum += q
        k_hist[k] += 1
    qmax = _max_quality(stream, fitted.power)
    return RunResult(q_sum, float(qmax.sum()), on_sum, cl_sum, peak,
                     overflow, k_hist)


def run_static(fitted: Fitted, stream: Stream, k: int, **kw) -> RunResult:
    """Ablation baseline: run the whole stream pinned to config ``k``."""
    return _run_fixed_policy(fitted, stream, lambda t, q: k, **kw)


def best_static_config(fitted: Fitted, n_cores: int) -> int:
    """Most qualitative config that runs real-time all-on-prem (ablation 1a)."""
    tau = fitted.workload.segment_seconds
    ok = (fitted.cost / n_cores) <= tau
    if not ok.any():
        return int(np.argmin(fitted.cost))
    return int(np.argmax(np.where(ok, fitted.power, -1)))


def run_videostorm_like(fitted: Fitted, stream: Stream, *, n_cores: int,
                        **kw) -> RunResult:
    """Query-load adaptive (VideoStorm): most qualitative config whose
    cheapest placement currently fits — content-agnostic, greedy buffer."""
    order = np.argsort(-fitted.power)
    tau = fitted.workload.segment_seconds
    cap_s = kw.get("buffer_gb", 4.0) * 1e9 / 90e3
    state = {"b": 0.0}

    def pick(t, q):
        m = stream.arrival[t]
        for k in order:
            rts = fitted.place_rt[k] * m
            feas = fitted.place_valid[k] & (rts <= tau + (cap_s - state["b"]))
            if feas.any():
                state["b"] = max(0.0, state["b"]
                                 + rts[np.where(feas, fitted.place_cl[k],
                                                np.inf).argmin()] - tau)
                return int(k)
        return int(np.argmin(fitted.cost))

    return _run_fixed_policy(fitted, stream, pick, n_cores=n_cores, **kw)


def run_chameleon_star(fitted: Fitted, stream: Stream, *, n_cores: int,
                       epoch_segments: int = 50, profile_top: int = 6,
                       quality_floor: float = 0.9, seed: int = 0,
                       **kw) -> RunResult:
    """Chameleon* (§5.3): periodic profiling of the top configs (the
    profiling work is real and added to the backlog), then the cheapest
    config within ``quality_floor`` of the best profiled quality. Buffer
    added (vs. original Chameleon) but unmanaged."""
    quals = stream.quality(fitted.power, seed=seed)
    by_pow = np.argsort(-fitted.power)[:profile_top]
    current = {"k": int(np.argmin(fitted.cost))}
    extra = np.zeros(stream.n_segments)

    def pick(t, q):
        if t % epoch_segments == 0:
            prof = quals[t, by_pow]
            extra[min(t, len(extra) - 1)] = fitted.cost[by_pow].sum()
            ok = by_pow[prof >= quality_floor * prof.max()]
            current["k"] = int(ok[np.argmin(fitted.cost[ok])])
        return current["k"]

    return _run_fixed_policy(fitted, stream, pick, n_cores=n_cores,
                             extra_backlog=extra, seed=seed, **kw)


def run_optimum(fitted: Fitted, stream: Stream, *, n_cores: int,
                cloud_budget_core_s: float = 0.0, seed: int = 0,
                chunk: int = 40_000) -> RunResult:
    """Ground-truth knapsack (ablation 2c): per-segment config choice
    maximizing total quality under the total work budget — the LP bound,
    solved exactly with the Lagrangian planner (one category/segment)."""
    w = fitted.workload
    tau = w.segment_seconds
    T = stream.n_segments
    quals = stream.quality(fitted.power, seed=seed)      # (T,K)
    budget = n_cores * tau * T + cloud_budget_core_s / CLOUD_PREMIUM
    r = jnp.full((T,), 1.0 / T, jnp.float32)
    alpha = solve_lp_lagrangian(jnp.asarray(quals), jnp.asarray(fitted.cost),
                                r, jnp.float32(budget / T))
    a = np.asarray(alpha)
    k_sel = a.argmax(1)
    q_sum = float(quals[np.arange(T), k_sel].sum())
    work = float(fitted.cost[k_sel].sum())
    qmax = _max_quality(stream, fitted.power)
    return RunResult(q_sum, float(qmax.sum()), work, 0.0, 0.0, False,
                     np.bincount(k_sel, minlength=len(fitted.configs)))

"""V-ETL Transform over an assigned-architecture backbone.

This is the integration point between the paper's scheduling layer and
the model zoo: a V-ETL job whose UDF is a JAX forward pass. Knobs map to
the paper's families (§5.2):

- ``sample_every``: temporal sampling (frame-rate knob),
- ``resolution``: frame downsample factor (via the Pallas kernel),
- ``model_size``: small/medium/large backbone variants.

Quality = mean top-1 certainty of the model on the segment (the paper's
certainty-as-quality proxy, §5.2 MOT/MOSEI). The backbone is any arch
from the pool, built at reduced size for CPU; on TPU the same code path
serves the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import get
from repro.kernels import ops
from repro.models.model import Model
from repro.models.options import RunOptions

SIZES = {"small": (1, 32), "medium": (2, 48), "large": (3, 64)}


class BackboneVETL:
    """A V-ETL job: frames -> (stub frontend) -> backbone -> certainty."""

    def __init__(self, arch: str = "qwen1.5-0.5b", seed: int = 0):
        base = get(arch).reduced()
        self.models: Dict[str, Tuple[Model, dict]] = {}
        key = jax.random.PRNGKey(seed)
        opts = RunOptions(remat="none", layer_loop="scan",
                          compute_dtype="float32", q_chunk=64, kv_chunk=64)
        for name, (layers, width) in SIZES.items():
            cfg = dataclasses.replace(
                base, n_layers=layers, d_model=width, n_heads=4,
                n_kv_heads=min(base.n_kv_heads, 4) or 4, d_ff=2 * width,
                head_dim=width // 4, vocab=base.vocab)
            m = Model(cfg, opts)
            self.models[name] = (m, m.init(key))
        self._fwd = {}

    def _forward(self, name):
        if name not in self._fwd:
            m, _ = self.models[name]

            @jax.jit
            def f(params, tokens):
                logits = m.forward_logits(params, {"tokens": tokens})
                p = jax.nn.softmax(logits, axis=-1)
                return jnp.mean(jnp.max(p, axis=-1))

            self._fwd[name] = f
        return self._fwd[name]

    def _forward_batched(self, name):
        """vmapped certainty over a leading stream axis: tokens (N,F,S)
        -> (N,) per-stream quality in ONE dispatch."""
        key = ("batched", name)
        if key not in self._fwd:
            m, _ = self.models[name]

            @jax.jit
            def f(params, tokens):
                def one(tk):
                    logits = m.forward_logits(params, {"tokens": tk})
                    p = jax.nn.softmax(logits, axis=-1)
                    return jnp.mean(jnp.max(p, axis=-1))

                return jax.vmap(one)(tokens)

            self._fwd[key] = f
        return self._fwd[key]

    def proc_fn(self, segment, knobs):
        """segment: dict(frames=(F,H,W,C) float32, tokens=(F,S) int32).
        Returns (detections stub, quality)."""
        frames = segment["frames"][:: knobs.get("sample_every", 1)]
        tokens = segment["tokens"][:: knobs.get("sample_every", 1)]
        res = knobs.get("resolution", 1)
        if res > 1:
            frames = ops.downsample(frames, factor=res, block=16)
        m, params = self.models[knobs.get("model_size", "small")]
        cert = self._forward(knobs.get("model_size", "small"))(params, tokens)
        # certainty as the quality proxy; frames touched to emulate the
        # pixel path (downsample kernel exercised above)
        return {"n_frames": frames.shape[0]}, float(cert)

    def proc_batch(self, segments, knob_list):
        """Multi-stream Transform: segments/knob_list are per-stream (the
        batched switcher's V decisions). Streams whose knobs selected the
        SAME backbone + sampling are stacked and run through one vmapped
        forward — per-model-group dispatch instead of per-stream.
        Returns (results, qualities) in input order."""
        groups: Dict[tuple, list] = {}
        for i, (seg, kv) in enumerate(zip(segments, knob_list)):
            gkey = (kv.get("model_size", "small"),
                    kv.get("sample_every", 1), seg["tokens"].shape)
            groups.setdefault(gkey, []).append(i)
        results = [None] * len(segments)
        quals = [0.0] * len(segments)
        for (name, sample, _), idxs in groups.items():
            toks = jnp.stack([segments[i]["tokens"][::sample] for i in idxs])
            _, params = self.models[name]
            certs = self._forward_batched(name)(params, toks)
            for j, i in enumerate(idxs):
                kv = knob_list[i]
                frames = segments[i]["frames"][::sample]
                res = kv.get("resolution", 1)
                if res > 1:
                    frames = ops.downsample(frames, factor=res, block=16)
                results[i] = {"n_frames": frames.shape[0]}
                quals[i] = float(certs[j])
        return results, quals

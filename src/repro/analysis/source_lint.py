"""Pass 3 — source lint: AST sweep over ``src/`` for the JAX pitfalls
the repo bans by convention but that neither the jaxpr nor the HLO can
show (they happen *before* tracing, or only on the unhappy path).

The pass first discovers every jit boundary in a module:

- ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorated defs,
- ``name = jax.jit(fn, ...)`` module-level wrappings,

then computes the *traced set*: those functions, every def nested
inside them, every function handed to ``lax.scan`` / ``shard_map`` /
``vmap`` / ``cond`` / ``while_loop``, and (to a fixpoint) every
same-module function they call. Inside the traced set it flags:

- ``np_call_in_jit`` — ``np.foo(...)`` under trace produces a constant
  (silently wrong) or a TracerConversionError (loudly wrong); either
  way host numpy does not belong inside a jitted body.
- ``python_branch_on_operand`` — ``if param:`` / ``if param > x:`` on a
  *traced* parameter. (Branches on static argnames, attributes like
  ``x.shape``, or locals are exempt — those are trace-time values.)
- ``global_in_jit`` — a ``global`` statement inside a traced body is a
  tracer leak waiting to happen: the tracer outlives the trace and
  poisons the next call.
- ``unhashable_static_default`` — a static argname whose default is a
  list/dict/set literal fails at call time with an opaque hash error.
- ``static_name_missing`` — ``static_argnames`` naming a parameter the
  wrapped function does not have (jit silently ignores it and the arg
  gets traced, recompiling per value).

It also returns the set of module-level jitted definitions found in
``core/`` / ``warehouse/`` / ``distribution/`` so the auditor can
cross-reference them against the registry's ``covers`` union — a jitted
entry point nobody registered (no probe, no invariants) is itself a
violation (``unregistered_jit``).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

# modules whose jitted defs must be covered by the engine registry
REGISTRY_SCOPED = ("repro/core", "repro/warehouse", "repro/distribution")

# modules whose PUBLIC module-level functions and classes must carry
# docstrings (the user-facing E/T/L surface + observability); the rule
# rides in ANALYSIS.json, so coverage can only ratchet up
DOCSTRING_SCOPED = ("repro.core.", "repro.warehouse.", "repro.obs.")

_TRACING_CALLS = ("scan", "while_loop", "cond", "vmap", "shard_map",
                  "fori_loop", "switch", "checkpoint", "remat")


def _dotted(node) -> str:
    """Best-effort dotted name of an expression ('jax.jit', 'np.sum')."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit(node) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _static_names(call: ast.Call) -> Tuple[str, ...]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            if isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                return (kw.value.value,)
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return tuple(e.value for e in kw.value.elts
                             if isinstance(e, ast.Constant))
    return ()


class _JitSite:
    def __init__(self, public_name, target, statics, call, lineno,
                 toplevel=True, node=None):
        self.public_name = public_name    # module-level binding, if any
        self.target = target              # wrapped FunctionDef name/None
        self.statics = statics            # static argnames
        self.call = call                  # the jax.jit Call node (or None)
        self.lineno = lineno
        self.toplevel = toplevel          # module-level binding?
        self.node = node                  # the FunctionDef itself, if known


def _find_jit_sites(tree: ast.Module) -> List[_JitSite]:
    sites: List[_JitSite] = []
    top = {id(n) for n in tree.body if isinstance(n, ast.FunctionDef)}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            lvl = id(node) in top
            for dec in node.decorator_list:
                if _is_jit(dec):
                    sites.append(_JitSite(node.name, node.name, (),
                                          None, node.lineno, lvl, node))
                elif isinstance(dec, ast.Call):
                    if _is_jit(dec.func):
                        sites.append(_JitSite(node.name, node.name,
                                              _static_names(dec), dec,
                                              node.lineno, lvl, node))
                    elif _dotted(dec.func).endswith("partial") \
                            and dec.args and _is_jit(dec.args[0]):
                        sites.append(_JitSite(node.name, node.name,
                                              _static_names(dec), dec,
                                              node.lineno, lvl, node))
    for node in tree.body:                # module-level `x = jax.jit(f)`
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _is_jit(node.value.func):
            name = node.targets[0].id \
                if isinstance(node.targets[0], ast.Name) else None
            target = None
            if node.value.args:
                arg0 = node.value.args[0]
                if isinstance(arg0, ast.Name):
                    target = arg0.id
                elif isinstance(arg0, ast.Call):   # jax.jit(jax.vmap(f))
                    inner = [a.id for a in arg0.args
                             if isinstance(a, ast.Name)]
                    target = inner[0] if inner else None
            sites.append(_JitSite(name, target, _static_names(node.value),
                                  node.value, node.lineno))
    return sites


def _traced_set(tree: ast.Module, sites: List[_JitSite]
                ) -> Tuple[Set[str], Dict[str, ast.FunctionDef]]:
    """Names of functions that run under trace, to a same-module
    fixpoint, plus the name -> FunctionDef map."""
    defs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, node)
    traced: Set[str] = {s.target for s in sites if s.target}
    for node in ast.walk(tree):           # fns handed to scan/vmap/...
        if isinstance(node, ast.Call):
            tail = _dotted(node.func).rsplit(".", 1)[-1]
            if tail in _TRACING_CALLS:
                for a in node.args[:2]:
                    if isinstance(a, ast.Name) and a.id in defs:
                        traced.add(a.id)
    frontier = list(traced)
    while frontier:                       # same-module call closure
        fn = defs.get(frontier.pop())
        if fn is None:
            continue
        for node in ast.walk(fn):
            name = None
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node, ast.FunctionDef) and node is not fn:
                name = node.name          # nested def traces with parent
            if name and name in defs and name not in traced:
                traced.add(name)
                frontier.append(name)
    return traced, defs


def _lint_traced_fn(fn: ast.FunctionDef, statics: Set[str], module: str,
                    violations: List[Dict]):
    def violate(check, detail, lineno):
        violations.append({
            "pass": "source", "check": check, "detail": detail,
            "path": f"{module}:{fn.name}:{lineno}"})

    params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
    traced_params = params - statics

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            dn = _dotted(node.func)
            if dn.startswith("np.") or dn.startswith("numpy."):
                violate("np_call_in_jit",
                        f"{dn}() inside traced body (host numpy under "
                        f"jit is a constant-fold or a trace error)",
                        node.lineno)
        elif isinstance(node, ast.Global):
            violate("global_in_jit",
                    f"global {', '.join(node.names)} inside traced body "
                    f"(tracer leak via module state)", node.lineno)
        elif isinstance(node, (ast.If, ast.IfExp)):
            test = node.test
            # `if param:` or `param <op> x` where param is traced.
            # Attribute tests (x.shape...), locals and statics are
            # trace-time values and exempt.
            names = []
            if isinstance(test, ast.Name):
                names = [test.id]
            elif isinstance(test, ast.Compare):
                for sub in [test.left] + list(test.comparators):
                    if isinstance(sub, ast.Name):
                        names.append(sub.id)
                # `x is None` / `x == "lit"` style static dispatch is
                # fine even on params: only flag arithmetic comparisons
                if any(isinstance(op, (ast.Is, ast.IsNot, ast.In,
                                       ast.NotIn)) for op in test.ops):
                    names = []
                if any(isinstance(sub, ast.Constant)
                       and isinstance(sub.value, (str, type(None)))
                       for sub in [test.left] + list(test.comparators)):
                    names = []            # string/None compare = dispatch
            hits = [n for n in names if n in traced_params]
            if hits:
                violate("python_branch_on_operand",
                        f"Python branch on traced parameter "
                        f"{hits[0]!r} (trace error at runtime; use "
                        f"lax.cond / jnp.where)", node.lineno)


def _lint_jit_site(site: _JitSite, defs: Dict[str, ast.FunctionDef],
                   module: str, violations: List[Dict]):
    fn = site.node
    if fn is None and site.target:        # `x = jax.jit(f)` assign form
        fn = defs.get(site.target)
    if fn is None or not site.statics:
        return
    params = [a.arg for a in fn.args.args + fn.args.kwonlyargs]
    for s in site.statics:
        if s not in params:
            violations.append({
                "pass": "source", "check": "static_name_missing",
                "detail": f"static_argnames={s!r} not a parameter of "
                          f"{site.target} (jit traces it instead)",
                "path": f"{module}:{site.target}:{site.lineno}"})
    # unhashable defaults on static argnames
    pos = fn.args.args
    defaults = dict(zip([a.arg for a in pos[len(pos) - len(fn.args.defaults):]],
                        fn.args.defaults))
    for a, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if d is not None:
            defaults[a.arg] = d
    for s in site.statics:
        d = defaults.get(s)
        if isinstance(d, (ast.List, ast.Dict, ast.Set)):
            violations.append({
                "pass": "source", "check": "unhashable_static_default",
                "detail": f"static arg {s!r} defaults to an unhashable "
                          f"{type(d).__name__.lower()} literal",
                "path": f"{module}:{site.target}:{site.lineno}"})


def _lint_docstrings(tree: ast.Module, module: str,
                     violations: List[Dict]):
    """Require a docstring on every PUBLIC module-level function and
    class (name not ``_``-prefixed). Only runs for ``DOCSTRING_SCOPED``
    modules — the documented contract surface of the repo."""
    def violate(name, kind, lineno):
        violations.append({
            "pass": "source", "check": "missing_docstring",
            "detail": f"public {kind} {name!r} has no docstring",
            "path": f"{module}:{name}:{lineno}"})

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_") \
                    and ast.get_docstring(node) is None:
                violate(node.name, "function", node.lineno)
        elif isinstance(node, ast.ClassDef):
            if not node.name.startswith("_") \
                    and ast.get_docstring(node) is None:
                violate(node.name, "class", node.lineno)


def lint_source(text: str, module: str) -> Tuple[List[Dict], Set[str]]:
    """Lint one module's source. Returns ``(violations, jit_defs)``
    where ``jit_defs`` is the set of ``module:name`` jit bindings found
    (for the registry-coverage cross-reference). Modules under
    ``DOCSTRING_SCOPED`` additionally get the public-docstring-coverage
    rule."""
    violations: List[Dict] = []
    try:
        tree = ast.parse(text)
    except SyntaxError as e:              # pragma: no cover
        return ([{"pass": "source", "check": "syntax_error",
                  "detail": str(e), "path": module}], set())
    sites = _find_jit_sites(tree)
    traced, defs = _traced_set(tree, sites)
    statics_of: Dict[str, Set[str]] = {}
    for s in sites:
        if s.target:
            statics_of.setdefault(s.target, set()).update(s.statics)
    for name in sorted(traced):
        fn = defs.get(name)
        if fn is not None:
            _lint_traced_fn(fn, statics_of.get(name, set()), module,
                            violations)
    for s in sites:
        _lint_jit_site(s, defs, module, violations)
    if module.startswith(DOCSTRING_SCOPED) \
            or (module + ".").startswith(DOCSTRING_SCOPED):
        _lint_docstrings(tree, module, violations)
    # only module-level bindings are registrable entry points; jit
    # factories that close over a mesh (query's `run`, store's `kern`)
    # are exercised through the engines that build them
    jit_defs = {f"{module}:{s.public_name}" for s in sites
                if s.public_name and s.toplevel}
    return violations, jit_defs


def lint_tree(src_root: str) -> Tuple[List[Dict], Set[str]]:
    """Lint every ``.py`` under ``src_root``. ``jit_defs`` only
    collects modules inside ``REGISTRY_SCOPED`` (the packages whose
    engines must be registered)."""
    violations: List[Dict] = []
    jit_defs: Set[str] = set()
    for dirpath, _dirs, files in os.walk(src_root):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root)
            module = rel[:-3].replace(os.sep, ".")
            with open(path, "r") as fh:
                text = fh.read()
            v, j = lint_source(text, module)
            violations.extend(v)
            mod_path = rel.replace(os.sep, "/")
            if any(mod_path.startswith(scope + "/") or
                   mod_path.rsplit(".", 1)[0] == scope
                   for scope in REGISTRY_SCOPED):
                jit_defs.update(j)
    return violations, jit_defs

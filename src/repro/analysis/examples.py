"""Tiny deterministic example inputs for every registered engine.

Engine modules register lazy builders that call into here (the import
happens inside the builder, never at engine-module import time, so
there are no cycles and registering costs nothing until the auditor
runs). Shapes are deliberately small — each example traces and compiles
in well under a second on CPU — but structurally faithful: the same
static arguments, pytree layouts, and dtypes as production calls, so
the jaxpr/HLO the auditor sees is the real program at toy size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import EngineExample

K, C, P, V = 4, 3, 3, 2          # configs, categories, placements, streams
W, T, N_W = 6, 10, 2             # window len, run len, windows per run
N_SPLIT, INTERVAL = 2, 3         # forecaster history layout
OUT_DIM, CAP = 4, 64             # warehouse embedding width / capacity
N_SHARDS = 2


def demo_tables(seed: int = 0, tau: float = 2.0, cap: float = 30.0,
                cloud: float = 50.0, n_cores: int = 4):
    from repro.core.switcher import SwitchTables
    rng = np.random.default_rng(seed)
    power = np.sort(rng.random(K)).astype(np.float32)
    cost = np.sort(rng.random(K) * 20 + 0.5).astype(np.float32)
    cost[0] = min(cost[0], tau * n_cores * 0.9)
    centers = np.sort(rng.random((C, K)), axis=0).astype(np.float32)
    rt = np.stack([cost / n_cores, cost / n_cores * 0.6,
                   cost / n_cores * 0.3], 1)
    cl = np.stack([np.zeros(K), cost * 0.4, cost * 0.7], 1)
    on = np.stack([cost, cost * 0.6, cost * 0.3], 1)
    return SwitchTables(
        centers=jnp.asarray(centers), power=jnp.asarray(power),
        cost=jnp.asarray(cost), place_rt=jnp.asarray(rt, jnp.float32),
        place_on=jnp.asarray(on, jnp.float32),
        place_cl=jnp.asarray(cl, jnp.float32),
        place_valid=jnp.ones((K, P), bool),
        rank_pos=jnp.asarray(np.argsort(np.argsort(-power)), jnp.int32),
        tau=tau, buffer_cap_s=cap, cloud_budget=cloud)


def _alpha(rng):
    a = rng.random((C, K)).astype(np.float32)
    return jnp.asarray(a / a.sum(1, keepdims=True))


def _quals(rng, *shape):
    return jnp.asarray(rng.random(shape + (K,)), jnp.float32)


# ---- switcher --------------------------------------------------------------

def switch_step():
    from repro.core.switcher import _switch_jit, init_state
    rng = np.random.default_rng(0)
    t = demo_tables()
    return EngineExample(_switch_jit,
                         (init_state(t), _quals(rng), jnp.float32(1.2),
                          _alpha(rng), t), {})


def switch_step_multi():
    from repro.core.switcher import (_switch_multi_jit, init_state_multi,
                                     stack_tables)
    rng = np.random.default_rng(0)
    ts = [demo_tables(seed=s) for s in range(V)]
    alpha = jnp.stack([_alpha(rng) for _ in range(V)])
    return EngineExample(_switch_multi_jit,
                         (init_state_multi(ts), _quals(rng, V),
                          jnp.ones((V,), jnp.float32), alpha,
                          stack_tables(ts)), {})


def run_window():
    from repro.core.switcher import _run_window, init_state
    rng = np.random.default_rng(0)
    t = demo_tables()
    return EngineExample(_run_window,
                         (init_state(t), _quals(rng, W),
                          jnp.ones((W,), jnp.float32), jnp.ones((W,), bool),
                          _alpha(rng), t), {})


def run_window_multi():
    from repro.core.switcher import (_run_window_multi, init_state_multi,
                                     stack_tables)
    rng = np.random.default_rng(0)
    ts = [demo_tables(seed=s) for s in range(V)]
    alpha = jnp.stack([_alpha(rng) for _ in range(V)])
    return EngineExample(_run_window_multi,
                         (init_state_multi(ts), _quals(rng, V, W),
                          jnp.ones((V, W), jnp.float32),
                          jnp.ones((V, W), bool), alpha,
                          stack_tables(ts)), {})


# ---- fused ingestion engines ----------------------------------------------

def _windowed(rng):
    """(quals_w, arrs_w, valid_w, wts, fracs) for a T-segment run."""
    from repro.core.ingest import _window_layout
    n_w, pad, wts, fracs = _window_layout(T, W)
    quals = _quals(rng, T)
    quals_w = jnp.pad(quals, ((0, pad), (0, 0))).reshape(n_w, W, K)
    arrs_w = jnp.ones((n_w, W), jnp.float32)
    valid_w = (jnp.arange(n_w * W) < T).reshape(n_w, W)
    return quals_w, arrs_w, valid_w, jnp.asarray(wts), jnp.asarray(fracs)


def fused_single():
    from repro.core.forecaster import init_forecaster
    from repro.core.ingest import _fused_run
    from repro.core.switcher import init_state
    rng = np.random.default_rng(0)
    t = demo_tables()
    quals_w, arrs_w, valid_w, wts, fracs = _windowed(rng)
    params = init_forecaster(jax.random.PRNGKey(0), N_SPLIT, C)
    return EngineExample(
        _fused_run,
        (init_state(t), jnp.zeros((N_SPLIT * INTERVAL,), jnp.int32),
         quals_w, arrs_w, valid_w, wts, fracs, t, t.centers, t.cost,
         params, jnp.float32(8.0), jnp.float32(50.0)),
        {"mode": "model", "n_split": N_SPLIT, "interval": INTERVAL})


def fused_multi():
    from repro.core.ingest import _fused_run_multi
    from repro.core.switcher import init_state_multi, stack_tables
    rng = np.random.default_rng(0)
    ts = [demo_tables(seed=s) for s in range(V)]
    quals_w = jnp.asarray(rng.random((N_W, V, W, K)), jnp.float32)
    arrs_w = jnp.ones((N_W, V, W), jnp.float32)
    valid_w = jnp.broadcast_to((jnp.arange(N_W * W) < T).reshape(N_W, 1, W),
                               (N_W, V, W))
    wts = jnp.asarray(np.minimum(W, T - np.arange(N_W) * W), jnp.int32)
    return EngineExample(
        _fused_run_multi,
        (init_state_multi(ts), quals_w, arrs_w, valid_w, wts,
         stack_tables(ts), ts[0].cost, jnp.float32(16.0),
         jnp.float32(0.5)),
        {"with_traces": True})


def fused_single_telemetry():
    """``fused_single`` with the flight recorder threaded through the
    carry — the auditor proves the telemetry variant is still one
    executable with no host transfers."""
    ex = fused_single()
    return EngineExample(ex.fn, ex.args, dict(ex.kwargs, telemetry=True))


def fused_multi_telemetry():
    ex = fused_multi()
    return EngineExample(ex.fn, ex.args, dict(ex.kwargs, telemetry=True))


# ---- serving pool ----------------------------------------------------------

def pool_replan():
    from repro.core.api import _pool_replan
    rng = np.random.default_rng(0)
    from repro.core.forecaster import init_forecaster
    params = init_forecaster(jax.random.PRNGKey(0), N_SPLIT, C)
    bufs = jnp.asarray(rng.integers(0, C, (V, N_SPLIT * INTERVAL)),
                       jnp.int32)
    centers = jnp.asarray(np.sort(rng.random((C, K)), axis=0), jnp.float32)
    cost = jnp.asarray(np.sort(rng.random(K) * 10 + 0.5), jnp.float32)
    return EngineExample(
        _pool_replan,
        (params, bufs, centers, cost, jnp.float32(8.0),
         jnp.asarray(True)),
        {"n_split": N_SPLIT, "interval": INTERVAL})


def pool_shift():
    from repro.core.api import _pool_shift
    bufs = jnp.zeros((V, N_SPLIT * INTERVAL), jnp.int32)
    return EngineExample(_pool_shift,
                         (bufs, jnp.ones((V,), jnp.int32)), {})


def pool_replan_stacked():
    from repro.core.api import _pool_replan_stacked
    ex = pool_replan()
    params, bufs, centers, cost, budget, use_model = ex.args
    return EngineExample(
        _pool_replan_stacked,
        (params, bufs, centers, cost, budget, use_model,
         jnp.ones((V,), bool), jnp.ones((V,), jnp.float32)),
        dict(ex.kwargs))


def pool_tick():
    from repro.core.api import _pool_tick
    from repro.core.switcher import init_state_multi, stack_tables
    rng = np.random.default_rng(0)
    ts = [demo_tables(seed=s) for s in range(V)]
    alpha = jnp.stack([_alpha(rng) for _ in range(V)])
    return EngineExample(
        _pool_tick,
        (init_state_multi(ts), jnp.ones((V,), jnp.float32),
         jnp.ones((V,), bool), _quals(rng, V),
         jnp.ones((V,), jnp.float32), jnp.ones((V,), bool),
         jnp.ones((V,), jnp.float32), alpha, stack_tables(ts),
         jnp.float32(np.inf), jnp.float32(np.inf)), {})


def pool_admit():
    from repro.core.api import _pool_admit
    from repro.core.switcher import init_state_multi, stack_tables
    rng = np.random.default_rng(0)
    ts = [demo_tables(seed=s) for s in range(V)]
    alpha = jnp.stack([_alpha(rng) for _ in range(V)])
    bufs = jnp.zeros((V, N_SPLIT * INTERVAL), jnp.int32)
    return EngineExample(
        _pool_admit,
        (stack_tables(ts), init_state_multi(ts), bufs, alpha,
         jnp.zeros((V,), bool), jnp.zeros((V,), jnp.float32),
         jnp.int32(0), jnp.float32(1.0), ts[0], _alpha(rng)), {})


def pool_retire():
    from repro.core.api import _pool_retire
    return EngineExample(_pool_retire,
                         (jnp.ones((V,), bool), jnp.int32(0)), {})


# ---- forecaster / categories / planner -------------------------------------

def adam_step():
    from repro.core.forecaster import _adam_step, init_forecaster
    params = init_forecaster(jax.random.PRNGKey(0), N_SPLIT, C)
    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.int32(0)}
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.random((8, N_SPLIT, C)), jnp.float32)
    Y = jnp.asarray(rng.random((8, C)), jnp.float32)
    return EngineExample(_adam_step, (params, opt, X, Y,
                                      jnp.float32(3e-3)), {})


def lloyd_step():
    from repro.core.categories import _lloyd_step
    rng = np.random.default_rng(0)
    centers = jnp.asarray(rng.random((C, K)), jnp.float32)
    Q = jnp.asarray(rng.random((20, K)), jnp.float32)
    return EngineExample(_lloyd_step, (centers, Q), {})


def classify_full():
    from repro.core.categories import classify_full as fn
    rng = np.random.default_rng(0)
    return EngineExample(fn, (jnp.asarray(rng.random(K), jnp.float32),
                              jnp.asarray(rng.random((C, K)),
                                          jnp.float32)), {})


def classify_1d():
    from repro.core.categories import classify_1d as fn
    rng = np.random.default_rng(0)
    return EngineExample(fn, (jnp.float32(0.5), jnp.int32(1),
                              jnp.asarray(rng.random((C, K)),
                                          jnp.float32)), {})


def lp_lagrangian():
    from repro.core.planner import solve_lp_lagrangian
    rng = np.random.default_rng(0)
    qual = jnp.asarray(np.sort(rng.random((C, K)), axis=0), jnp.float32)
    cost = jnp.asarray(np.sort(rng.random(K) * 10 + 0.5), jnp.float32)
    r = jnp.full((C,), 1.0 / C, jnp.float32)
    return EngineExample(solve_lp_lagrangian,
                         (qual, cost, r, jnp.float32(4.0)), {})


# ---- warehouse: query engines ----------------------------------------------

def _store_cols(stacked: bool = False):
    from repro.warehouse.store import _empty_columns
    cols = _empty_columns(CAP, OUT_DIM)
    if stacked:
        cols = {k: jnp.broadcast_to(v[None], (N_SHARDS,) + v.shape)
                for k, v in cols.items()}
    return cols


def _plan(kind: str):
    from repro.warehouse.query import (Filter, GroupBy, MultiGroupBy, TopK,
                                       WindowAgg)
    if kind == "filter_groupby":
        return (Filter("quality", "ge", 0.25),
                GroupBy("category", "quality", agg="mean", num_groups=C))
    if kind == "window_sum":
        return (WindowAgg(window=4, value="on_core_s", agg="sum",
                          num_windows=8),)
    if kind == "multi_topk":
        return (MultiGroupBy(keys=("t", "category"), value="quality",
                             agg="sum", nums=(8, C), windows=(4, 0)),
                TopK(5, "quality"))
    if kind == "topk":
        return (Filter("t", "lt", 48), TopK(5, "quality"))
    if kind == "group_max":
        # int-column filter + max agg: the fused kernel's in-register
        # int_pred path and ∓inf-sentinel accumulator path
        return (Filter("k", "gt", 0.5),
                GroupBy("category", "quality", agg="max", num_groups=C))
    raise ValueError(kind)


def query(kind: str):
    from repro.warehouse.query import _run_plan, normalize
    spec, fvals = normalize(_plan(kind))
    return EngineExample(_run_plan,
                         (_store_cols(), jnp.int32(50), fvals),
                         {"spec": spec})


def query_pallas(kind: str):
    """Same plans as ``query`` but through the fused Pallas
    filter+group+aggregate kernel (interpret mode on CPU) — the
    auditor's scatter census over these engines is the
    scatter-floor-broken proof (0 executed scatters)."""
    from repro.warehouse.query import _run_plan, normalize
    spec, fvals = normalize(_plan(kind))
    return EngineExample(_run_plan,
                         (_store_cols(), jnp.int32(50), fvals),
                         {"spec": spec, "use_pallas": True})


def query_sharded(kind: str, use_pallas: bool = False):
    from repro.launch.mesh import make_shard_mesh
    from repro.warehouse.query import _sharded_kernel, normalize
    spec, fvals = normalize(_plan(kind))
    kern = _sharded_kernel(make_shard_mesh(N_SHARDS), N_SHARDS)
    n_valid = jnp.asarray([50, 40], jnp.int32)
    return EngineExample(kern,
                         (_store_cols(stacked=True), n_valid, fvals,
                          jax.random.PRNGKey(0)),
                         {"spec": spec, "compressed": False,
                          "use_pallas": bool(use_pallas)})


# ---- warehouse: ingest engines ---------------------------------------------

def _traces(*lead):
    rng = np.random.default_rng(0)
    tr = {}
    for src, dt in (("c", jnp.int32), ("k", jnp.int32),
                    ("qual", jnp.float32), ("on_s", jnp.float32),
                    ("cl_s", jnp.float32), ("buffer_s", jnp.float32)):
        x = rng.integers(0, C, lead) if dt == jnp.int32 \
            else rng.random(lead)
        tr[src] = jnp.asarray(x, dt)
    return tr


def store_scatter():
    from repro.warehouse.store import OUT_COLUMN, SCALAR_COLUMNS, _scatter
    n = 5
    upd = {name: jnp.zeros((n,), dt) for name, dt in SCALAR_COLUMNS}
    upd[OUT_COLUMN] = jnp.zeros((n, OUT_DIM), jnp.float32)
    return EngineExample(_scatter, (_store_cols(), upd, jnp.int32(0)), {})


def store_ingest_fused():
    from repro.warehouse.store import _ingest_fused
    return EngineExample(
        _ingest_fused,
        (_store_cols(), _traces(N_W, W),
         jnp.zeros((T, OUT_DIM), jnp.float32), jnp.int32(0), jnp.int32(0),
         jnp.int32(0)), {"T": T})


def store_ingest_fused_multi():
    from repro.warehouse.store import _ingest_fused_multi
    return EngineExample(
        _ingest_fused_multi,
        (_store_cols(), _traces(N_W, V, W),
         jnp.zeros((V, T, OUT_DIM), jnp.float32), jnp.int32(0),
         jnp.int32(0), jnp.int32(0)), {"T": T})


def store_ingest_tick():
    from repro.warehouse.store import _ingest_tick
    return EngineExample(
        _ingest_tick,
        (_store_cols(), _traces(V), jnp.ones((V,), jnp.float32),
         jnp.zeros((V, OUT_DIM), jnp.float32), jnp.int32(0),
         jnp.int32(0)), {})


def store_ingest_tick_masked():
    from repro.warehouse.store import _ingest_tick_masked
    return EngineExample(
        _ingest_tick_masked,
        (_store_cols(), _traces(V), jnp.ones((V,), jnp.float32),
         jnp.zeros((V, OUT_DIM), jnp.float32), jnp.int32(0),
         jnp.int32(0), jnp.arange(V, dtype=jnp.int32),
         jnp.ones((V,), bool)), {})


def _sharded_append_args():
    n_rows = jnp.zeros((N_SHARDS,), jnp.int32)
    return _store_cols(stacked=True), n_rows


def store_sharded(kind: str):
    from repro.launch.mesh import make_shard_mesh
    from repro.warehouse.store import (OUT_COLUMN, SCALAR_COLUMNS,
                                       _shard_kernel)
    mesh = make_shard_mesh(N_SHARDS)
    kern = _shard_kernel(kind, mesh, N_SHARDS)
    cols, n_rows = _sharded_append_args()
    if kind == "append":
        n = 6
        upd = {name: jnp.zeros((n,), dt) for name, dt in SCALAR_COLUMNS}
        upd[OUT_COLUMN] = jnp.zeros((n, OUT_DIM), jnp.float32)
        return EngineExample(kern, (cols, n_rows, upd), {})
    if kind == "fused_multi":
        return EngineExample(
            kern, (cols, n_rows, _traces(N_W, V, W),
                   jnp.zeros((V, T, OUT_DIM), jnp.float32), jnp.int32(0),
                   jnp.int32(0)), {"T": T})
    if kind == "tick":
        return EngineExample(
            kern, (cols, n_rows, _traces(V), jnp.ones((V,), jnp.float32),
                   jnp.zeros((V, OUT_DIM), jnp.float32), jnp.int32(0)), {})
    if kind == "tick_ids":
        return EngineExample(
            kern, (cols, n_rows, _traces(V), jnp.ones((V,), jnp.float32),
                   jnp.zeros((V, OUT_DIM), jnp.float32), jnp.int32(0),
                   jnp.arange(V, dtype=jnp.int32), jnp.ones((V,), bool)),
            {})
    raise ValueError(kind)


def store_rebalance():
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime.elastic import _rebalance_kernel
    mesh = make_shard_mesh(N_SHARDS)
    kern = _rebalance_kernel(mesh, N_SHARDS, N_SHARDS)
    cols, n_rows = _sharded_append_args()
    return EngineExample(kern, (cols, n_rows), {"cap_new": CAP})


# ---- warehouse: standing queries -------------------------------------------

_Q_STAND = 2                     # stacked query slots in the examples


def _standing_args(kind: str, q: int = _Q_STAND, sharded: bool = False):
    """(spec, stacked (Q, F) threshold operands, init state) for a
    standing-query group of ``q`` same-shape queries — the operand
    layout ``StandingQueries`` threads through the ingest kernels."""
    from repro.warehouse.query import normalize, split_plan
    from repro.warehouse.standing import _num_groups
    spec, fv = normalize(_plan(kind))
    fvq = tuple(jnp.broadcast_to(a[None], (q,) + a.shape) for a in fv)
    _pre, node, _post = split_plan(spec)
    num = _num_groups(node)
    lead = (N_SHARDS, q) if sharded else (q,)
    fill = {"max": -jnp.inf, "min": jnp.inf}.get(node.agg, 0.0)
    state = {"acc": jnp.full(lead + (num,), fill, jnp.float32),
             "cnt": jnp.zeros(lead + (num,), jnp.float32)}
    return spec, fvq, state


def standing_backfill(kind: str, use_pallas: bool = False):
    from repro.warehouse.standing import _backfill
    spec, fvq, state = _standing_args(kind)
    return EngineExample(_backfill,
                         (_store_cols(), jnp.int32(50), fvq, state),
                         {"sspec": (spec, bool(use_pallas))})


def standing_fold_sharded():
    from repro.launch.mesh import make_shard_mesh
    from repro.warehouse.standing import _sharded_fold_kernel
    spec, fvq, state = _standing_args("filter_groupby", sharded=True)
    kern = _sharded_fold_kernel(make_shard_mesh(N_SHARDS), N_SHARDS)
    return EngineExample(kern,
                         (_store_cols(stacked=True),
                          jnp.asarray([50, 40], jnp.int32), fvq, state),
                         {"sspec": (spec, False)})


def standing_answer(sharded: bool):
    from repro.warehouse.standing import _answer_kernel
    spec, fvq, state = _standing_args("filter_groupby",
                                      sharded=bool(sharded))
    return EngineExample(_answer_kernel, (state, fvq),
                         {"spec": spec, "sharded": bool(sharded)})


def store_scatter_standing():
    """``append_rows`` with a registered standing query: the scatter
    AND the incremental fold in the one jitted program."""
    from repro.warehouse.store import (OUT_COLUMN, SCALAR_COLUMNS,
                                      _scatter_fold)
    n = 5
    upd = {name: jnp.zeros((n,), dt) for name, dt in SCALAR_COLUMNS}
    upd[OUT_COLUMN] = jnp.zeros((n, OUT_DIM), jnp.float32)
    spec, fvq, state = _standing_args("filter_groupby")
    return EngineExample(_scatter_fold,
                         (_store_cols(), upd, jnp.int32(0), (state,),
                          (fvq,)),
                         {"sspecs": ((spec, False),)})


def store_ingest_tick_standing():
    from repro.warehouse.store import _ingest_tick
    spec, fvq, state = _standing_args("filter_groupby")
    return EngineExample(
        _ingest_tick,
        (_store_cols(), _traces(V), jnp.ones((V,), jnp.float32),
         jnp.zeros((V, OUT_DIM), jnp.float32), jnp.int32(0),
         jnp.int32(0), (state,), (fvq,)),
        {"sspecs": ((spec, False),)})


def store_sharded_standing():
    """Sharded tick ingest with a standing fold: one ``shard_map``
    dispatch writes the rows AND refreshes the per-shard partials."""
    from repro.launch.mesh import make_shard_mesh
    from repro.warehouse.store import _shard_kernel
    kern = _shard_kernel("tick", make_shard_mesh(N_SHARDS), N_SHARDS)
    cols, n_rows = _sharded_append_args()
    spec, fvq, state = _standing_args("filter_groupby", sharded=True)
    return EngineExample(
        kern,
        (cols, n_rows, _traces(V), jnp.ones((V,), jnp.float32),
         jnp.zeros((V, OUT_DIM), jnp.float32), jnp.int32(0),
         (state,), (fvq,)),
        {"sspecs": ((spec, False),)})


# ---- warehouse: tiers ------------------------------------------------------

_CHUNK, _N_SPILL = 4, 8


def tiers_quantize():
    from repro.warehouse.tiers import _quantize_chunks
    return EngineExample(_quantize_chunks,
                         (_store_cols(), jax.random.PRNGKey(0)),
                         {"n": _N_SPILL, "chunk": _CHUNK})


def tiers_compact():
    from repro.warehouse.tiers import _compact
    return EngineExample(_compact, (_store_cols(),),
                         {"n_spill": _N_SPILL})


def tiers_materialize():
    from repro.warehouse.tiers import _materialize, _quantize_chunks
    cols = _store_cols()
    q, scales, ints = _quantize_chunks(cols, jax.random.PRNGKey(0),
                                       n=_N_SPILL, chunk=_CHUNK)
    return EngineExample(_materialize, (q, scales, ints, cols),
                         {"chunk": _CHUNK})


def tiers_quantize_sharded():
    from repro.warehouse.tiers import _quantize_chunks_sharded
    return EngineExample(_quantize_chunks_sharded,
                         (_store_cols(stacked=True), jax.random.PRNGKey(0)),
                         {"n": _N_SPILL, "chunk": _CHUNK})


def tiers_cold_write():
    from repro.warehouse.tiers import _cold_write
    dst = {"x": jnp.zeros((N_SHARDS, 16, 3), jnp.float32)}
    src = {"x": jnp.ones((N_SHARDS, _N_SPILL, 3), jnp.float32)}
    return EngineExample(_cold_write,
                         (dst, src, jnp.zeros((N_SHARDS,), jnp.int32)), {})


def tiers_compact_ragged():
    from repro.warehouse.tiers import _compact_ragged
    cols = {"x": jnp.ones((N_SHARDS, 16, 3), jnp.float32)}
    return EngineExample(_compact_ragged,
                         (cols, jnp.asarray([4, 0], jnp.int32)), {})


def tiers_materialize_sharded():
    from repro.warehouse.tiers import (_materialize_sharded,
                                       _quantize_chunks_sharded)
    cols = _store_cols(stacked=True)
    q, scales, ints = _quantize_chunks_sharded(cols, jax.random.PRNGKey(0),
                                               n=_N_SPILL, chunk=_CHUNK)
    return EngineExample(
        _materialize_sharded,
        (q, scales, ints, cols, jnp.asarray([_N_SPILL, 0], jnp.int32)),
        {"chunk": _CHUNK})

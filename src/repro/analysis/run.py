"""The auditor driver: run all three passes over every registered
engine, write ``ANALYSIS.json``, and (``--compare``) fail on
regressions against a committed baseline.

Per engine:

1. build the tiny example, trace it, **jaxpr-lint** the closed jaxpr
   (callbacks / f64 / weak outputs / scatter+gather modes) and take the
   trip-weighted scatter census;
2. lower + compile, **HLO-audit** the optimized module (host
   transfers, collective balance) and record its op accounting;
3. **dispatch-account** with the engine's jit-cache probe: one warm
   call must add at most ``max_new_executables`` executables and a
   second identical call must add zero (``zero_recompile``).

Then one **source lint** over ``src/`` (np-under-jit, Python branches
on operands, tracer-leaking globals, static-arg hygiene) plus the
registry-coverage cross-reference: a jitted def in ``core/`` /
``warehouse/`` / ``distribution/`` that no engine ``covers`` is an
``unregistered_jit`` violation, and a registered engine without a
cache probe is ``missing_probe``.

Exit status: non-zero on any violation, or on ``--compare``
regressions (new violations, per-engine dispatch-count growth, or a
baseline engine disappearing).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

import jax

from repro.analysis import registry
from repro.analysis.hlo_audit import audit_hlo
from repro.analysis.jaxpr_lint import lint_jaxpr, trace_closed_jaxpr
from repro.analysis.source_lint import lint_tree

SCHEMA = 1
_SRC_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", ".."))


def audit_engine(engine: registry.Engine) -> Dict:
    """All three per-engine passes. Returns the engine's record for
    ``ANALYSIS.json`` (violations list included, possibly empty)."""
    record: Dict = {"violations": []}

    try:
        ex = engine.build()
    except registry.SkipEngine as e:
        record["skipped"] = str(e)
        return record

    # -- pass 1: jaxpr lint + census ------------------------------------
    closed = trace_closed_jaxpr(ex.fn, ex.args, ex.kwargs)
    v, census = lint_jaxpr(closed, engine.invariants)
    record["violations"].extend(v)
    record["jaxpr_census"] = census

    # -- pass 2: HLO audit ----------------------------------------------
    lowered = ex.fn.lower(*ex.args, **ex.kwargs)
    hlo = lowered.compile().as_text()
    v, info = audit_hlo(hlo, engine.invariants)
    record["violations"].extend(v)
    record["hlo"] = info

    # -- pass 3: dispatch accounting ------------------------------------
    if engine.probe is None:
        record["violations"].append({
            "pass": "registry", "check": "missing_probe",
            "detail": "engine registered without a jit-cache probe "
                      "(dispatch count is unverifiable)",
            "path": engine.name})
    else:
        p0 = engine.probe()
        jax.block_until_ready(ex.fn(*ex.args, **ex.kwargs))
        p1 = engine.probe()
        jax.block_until_ready(ex.fn(*ex.args, **ex.kwargs))
        p2 = engine.probe()
        new_exec, recompiles = p1 - p0, p2 - p1
        record["dispatch"] = {"new_executables": new_exec,
                              "recompiles": recompiles}
        cap = engine.invariants.get("max_new_executables")
        if cap is not None and new_exec > cap:
            record["violations"].append({
                "pass": "dispatch", "check": "dispatch_count",
                "detail": f"one warm call added {new_exec} executables "
                          f"(max {cap})", "path": engine.name})
        if engine.invariants.get("zero_recompile") and recompiles > 0:
            record["violations"].append({
                "pass": "dispatch", "check": "recompile",
                "detail": f"second identical call added {recompiles} "
                          f"executables", "path": engine.name})
    return record


def coverage_violations() -> List[Dict]:
    """Cross-reference the three observability registries (satellite of
    the obs PR — a counter that exists but is never audited or traced is
    a blind spot, so all three must agree):

    - every ``register_cache_probe`` site must be claimed by at least
      one registry engine via ``probe_name=`` (``probe_without_engine``),
    - every ``probe_name`` must point at a probe that actually exists
      (``unknown_probe_name`` — catches typos and renames),
    - every registered engine must be traceable by ``repro.obs``
      (``untraced_engine`` — i.e. it has a probe).
    """
    # deferred imports: switcher/obs both (transitively) import this
    # package's registry at module scope
    from repro.core.switcher import _CACHE_PROBES
    from repro.obs.trace import traceable_engine_names

    registry.import_engine_modules()
    violations: List[Dict] = []
    probes = set(_CACHE_PROBES)
    claimed = registry.claimed_probe_names()
    for name in sorted(probes - claimed):
        violations.append({
            "pass": "coverage", "check": "probe_without_engine",
            "detail": "cache probe has no registry engine claiming it "
                      "via probe_name= (recompiles there are invisible "
                      "to the auditor and the obs tracer)",
            "path": name})
    for name in sorted(claimed - probes):
        violations.append({
            "pass": "coverage", "check": "unknown_probe_name",
            "detail": "engine probe_name= does not match any "
                      "register_cache_probe site", "path": name})
    traced = traceable_engine_names()
    for name in sorted(set(registry.engines()) - traced):
        violations.append({
            "pass": "coverage", "check": "untraced_engine",
            "detail": "registered engine is invisible to the obs "
                      "tracer (no jit-cache probe)", "path": name})
    return violations


def run_audit(only: Optional[str] = None, skip_source: bool = False
              ) -> Dict:
    registry.import_engine_modules()
    engines = registry.engines()
    if only:
        engines = {k: v for k, v in engines.items() if only in k}

    report: Dict = {"schema": SCHEMA,
                    "topology": {"n_devices": jax.device_count()},
                    "engines": {}, "violations": []}
    for name, engine in engines.items():
        rec = audit_engine(engine)
        for v in rec["violations"]:
            v.setdefault("engine", name)
        report["engines"][name] = rec
        report["violations"].extend(rec["violations"])

    if not skip_source and not only:
        src_v, jit_defs = lint_tree(_SRC_ROOT)
        covered = registry.covered_jit_names()
        for missing in sorted(jit_defs - covered):
            src_v.append({"pass": "source", "check": "unregistered_jit",
                          "detail": "jitted entry point has no analysis-"
                                    "registry entry (no invariants, no "
                                    "probe)", "path": missing})
        report["source"] = {"violations": src_v,
                            "jit_defs": sorted(jit_defs)}
        report["violations"].extend(src_v)
        cov_v = coverage_violations()
        report["coverage"] = {"violations": cov_v}
        report["violations"].extend(cov_v)

    report["n_violations"] = len(report["violations"])
    return report


def compare(new: Dict, old: Dict) -> List[str]:
    """Regressions of ``new`` vs a committed baseline ``old``."""
    regressions: List[str] = []
    if new.get("n_violations", 0) > 0:
        regressions.append(
            f"{new['n_violations']} violations (baseline is clean)")
    if new["topology"] != old.get("topology"):
        # per-engine numbers are topology-dependent; violations above
        # still count, dispatch growth does not.
        print(f"[analysis] topology changed "
              f"{old.get('topology')} -> {new['topology']}; "
              f"skipping per-engine dispatch compare", file=sys.stderr)
        return regressions
    for name, old_rec in old.get("engines", {}).items():
        new_rec = new["engines"].get(name)
        if new_rec is None:
            regressions.append(f"engine {name!r} disappeared from audit")
            continue
        od = old_rec.get("dispatch", {}).get("new_executables")
        nd = new_rec.get("dispatch", {}).get("new_executables")
        if od is not None and nd is not None and nd > od:
            regressions.append(
                f"{name}: dispatch count grew {od} -> {nd}")
    return regressions


def _summary(report: Dict) -> str:
    lines = [f"audit: {len(report['engines'])} engines, "
             f"{report['n_violations']} violations "
             f"({report['topology']['n_devices']} devices)"]
    for name, rec in report["engines"].items():
        if "skipped" in rec:
            lines.append(f"  {name:28s} SKIP ({rec['skipped']})")
            continue
        t = rec["jaxpr_census"]["totals"]
        d = rec.get("dispatch", {})
        lines.append(
            f"  {name:28s} dispatch={d.get('new_executables', '?')} "
            f"recompile={d.get('recompiles', '?')} "
            f"scatter x{t['scatter_executed']:.0f} "
            f"gather x{t['gather_executed']:.0f} "
            f"viol={len(rec['violations'])}")
    for v in report["violations"]:
        lines.append(f"  VIOLATION [{v['pass']}/{v['check']}] "
                     f"{v.get('engine', v.get('path', ''))}: {v['detail']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static program auditor (jaxpr lint, HLO audit, "
                    "source lint) over every registered engine")
    ap.add_argument("--json", default="ANALYSIS.json",
                    help="output path (default ./ANALYSIS.json)")
    ap.add_argument("--compare", metavar="OLD",
                    help="fail on regressions vs a baseline ANALYSIS.json")
    ap.add_argument("--only", help="substring filter on engine names "
                    "(debug; disables source lint + compare coverage)")
    ap.add_argument("--no-source", action="store_true",
                    help="skip the source-lint pass")
    args = ap.parse_args(argv)

    old = None
    if args.compare:
        with open(args.compare) as fh:
            old = json.load(fh)

    report = run_audit(only=args.only, skip_source=args.no_source)
    print(_summary(report))

    with open(args.json, "w") as fh:
        json.dump(report, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"[analysis] wrote {args.json}")

    rc = 0
    if report["n_violations"] > 0:
        rc = 1
    if old is not None:
        regs = compare(report, old)
        for r in regs:
            print(f"[analysis] REGRESSION: {r}")
        if regs:
            rc = 1
        else:
            print(f"[analysis] compare vs {args.compare}: OK")
    return rc


if __name__ == "__main__":
    sys.exit(main())

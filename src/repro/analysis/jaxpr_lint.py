"""Pass 1 — jaxpr lint: walk a closed jaxpr recursively and verify the
graph-level invariants the runtime cache probes cannot see.

Checks (each gated by the engine's invariants dict):

- **host callbacks**: ``pure_callback`` / ``debug_callback`` /
  ``io_callback`` / ``outside_call`` primitives anywhere in the program
  (including inside scan/while/cond/pjit/shard_map sub-jaxprs). A
  callback inside the fused scan re-enters Python T times per run.
- **f64 leaks**: any equation producing float64/complex128 — an
  ``x64`` leak silently doubles bytes and breaks the fp32 bit-exactness
  contracts the warehouse tests assert.
- **weak-type outputs**: top-level outputs with ``weak_type=True``
  re-promote whatever consumes them (the classic Python-scalar
  promotion pitfall surviving through a public boundary).
- **scatter/gather modes**: scatters must carry explicit
  drop/in-bounds semantics (``FILL_OR_DROP`` / ``PROMISE_IN_BOUNDS``);
  ``CLIP`` — the silent clamp — redirects out-of-bounds writes onto
  valid rows. The ShardedStore's masked cumulative-rank scatter RELIES
  on drop semantics, so the mode being explicit is a correctness
  invariant, not style. Same for gathers (CLIP reads a wrong row
  instead of a fill value).

The walk also emits a **scatter/gather census** per engine: static op
counts plus trip-weighted executed counts (scan lengths multiply; while
trip counts are unknowable statically and count as 1). The census is
the scatter-floor baseline every future Pallas query kernel must beat
(ROADMAP "Break the scatter floor").
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Tuple

import jax
import numpy as np

# primitive names that re-enter the host per execution
_CALLBACK_PRIMS = ("pure_callback", "debug_callback", "io_callback",
                   "outside_call", "callback")

# scatter-family primitive prefixes (scatter, scatter-add, scatter-mul,
# scatter-min, scatter-max) and the gather family
_SCATTER_PREFIX = "scatter"
_GATHER_PRIMS = ("gather",)

_BANNED_DTYPES = ("float64", "complex128")


def _mode_name(mode) -> str:
    """GatherScatterMode (or None) -> stable lowercase name."""
    if mode is None:
        return "unspecified"
    return str(getattr(mode, "name", mode)).lower()


# modes with explicit, clamp-free out-of-bounds semantics
_SAFE_MODES = ("fill_or_drop", "promise_in_bounds")


def _sub_jaxprs(params: Mapping[str, Any]):
    """Yield every sub-jaxpr in an equation's params (scan/while/cond
    bodies, pjit/shard_map inner jaxprs, custom_* call jaxprs)."""
    for v in params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for b in v:
                if isinstance(b, jax.core.ClosedJaxpr):
                    yield b.jaxpr
                elif isinstance(b, jax.core.Jaxpr):
                    yield b


def lint_jaxpr(closed, invariants: Mapping[str, Any]
               ) -> Tuple[List[Dict], Dict]:
    """Lint one ``ClosedJaxpr``. Returns ``(violations, census)``.

    Each violation is ``{"pass": "jaxpr", "check": ..., "detail": ...,
    "path": ...}``. The census maps scatter/gather primitive names to
    ``{"count": static, "executed": trip-weighted}`` plus aggregate
    totals and the deepest scan-nesting trip product observed.
    """
    violations: List[Dict] = []
    census: Dict[str, Dict[str, float]] = {}
    totals = {"scatter_ops": 0, "gather_ops": 0,
              "scatter_executed": 0.0, "gather_executed": 0.0,
              "eqns": 0, "max_trip_product": 1.0}

    def bump(prim: str, mult: float):
        c = census.setdefault(prim, {"count": 0, "executed": 0.0})
        c["count"] += 1
        c["executed"] += mult

    def violate(check: str, detail: str, path: str):
        violations.append({"pass": "jaxpr", "check": check,
                           "detail": detail, "path": path})

    seen = set()

    def walk(jaxpr, mult: float, path: str):
        if id(jaxpr) in seen:       # pjit jaxprs can be shared
            return
        seen.add(id(jaxpr))
        for eqn in jaxpr.eqns:
            totals["eqns"] += 1
            name = eqn.primitive.name
            here = f"{path}/{name}"
            if invariants.get("no_callbacks") and any(
                    cb in name for cb in _CALLBACK_PRIMS):
                violate("host_callback",
                        f"host callback primitive {name!r}", here)
            if invariants.get("no_f64"):
                for var in eqn.outvars:
                    dt = getattr(getattr(var, "aval", None), "dtype", None)
                    if dt is not None and str(dt) in _BANNED_DTYPES:
                        violate("f64",
                                f"{name} produces {dt} (x64 leak)", here)
                        break
            if name.startswith(_SCATTER_PREFIX):
                bump(name, mult)
                totals["scatter_ops"] += 1
                totals["scatter_executed"] += mult
                mode = _mode_name(eqn.params.get("mode"))
                if invariants.get("no_clip_scatter") \
                        and mode not in _SAFE_MODES:
                    violate("scatter_mode",
                            f"{name} mode={mode} (needs explicit "
                            f"drop/in-bounds semantics)", here)
            elif name in _GATHER_PRIMS:
                bump(name, mult)
                totals["gather_ops"] += 1
                totals["gather_executed"] += mult
                mode = _mode_name(eqn.params.get("mode"))
                if invariants.get("no_clip_gather") \
                        and mode not in _SAFE_MODES:
                    violate("gather_mode",
                            f"{name} mode={mode} (silent index clamp)",
                            here)
            # recurse with the trip multiplier
            sub_mult = mult
            sub_path = here
            if name == "scan":
                sub_mult = mult * float(eqn.params.get("length", 1))
                sub_path = f"{here}[{eqn.params.get('length', '?')}]"
                totals["max_trip_product"] = max(
                    totals["max_trip_product"], sub_mult)
            elif name == "while":
                sub_path = f"{here}[?]"   # trip count unknown: count 1
            for sub in _sub_jaxprs(eqn.params):
                walk(sub, sub_mult, sub_path)

    walk(closed.jaxpr, 1.0, "")

    if invariants.get("no_weak_outputs"):
        for i, var in enumerate(closed.jaxpr.outvars):
            aval = getattr(var, "aval", None)
            if getattr(aval, "weak_type", False):
                violations.append({
                    "pass": "jaxpr", "check": "weak_type_output",
                    "detail": f"output #{i} is weakly typed "
                              f"({aval.dtype}, weak_type=True)",
                    "path": "/outputs"})

    census["totals"] = {k: (float(v) if isinstance(v, float) else v)
                        for k, v in totals.items()}
    return violations, census


def trace_closed_jaxpr(fn, args, kwargs):
    """ClosedJaxpr of a (possibly jitted) callable on example args.
    Prefers ``fn.trace`` (jax >= 0.4.34 pjit API); falls back to
    ``jax.make_jaxpr`` with the kwargs closed over (static kwargs can't
    be passed through make_jaxpr directly)."""
    trace = getattr(fn, "trace", None)
    if trace is not None:
        try:
            return trace(*args, **kwargs).jaxpr
        except Exception:                 # pragma: no cover - jax quirks
            pass
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)

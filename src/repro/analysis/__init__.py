"""Static program auditor: jaxpr lint, compiled-HLO audit and source
lint over every registered engine. ``python -m repro.analysis`` runs
all passes and writes ``ANALYSIS.json``; see ``repro.analysis.run``.

Import surface is kept light: the registry has no repro dependencies
so engine modules can register at import time without cycles.
"""
from repro.analysis.registry import (DEFAULT_INVARIANTS, Engine,
                                     EngineExample, SkipEngine, engines,
                                     register_engine)

__all__ = ["DEFAULT_INVARIANTS", "Engine", "EngineExample", "SkipEngine",
           "engines", "register_engine"]

"""Pass 2 — HLO audit: verify the *compiled* program (post-XLA) keeps
the promises the jaxpr made.

Layered on ``launch.hlo_analysis``: that module's parser already
attributes ops to computations and propagates while trip counts; this
pass adds the call-graph edge *types* needed for control-flow-sensitive
checks and audits:

- **host transfers**: no ``infeed`` / ``outfeed``, no
  ``is_host_transfer=true`` send/recv/copy, no host-callback
  custom-calls survive compilation. (A host hop the jaxpr lint missed —
  e.g. introduced by lowering — still fails here.)
- **collective balance**: no collective op is reachable from ENTRY
  through a ``conditional`` branch. Inside a ``shard_map`` body every
  shard must execute the identical collective sequence; a
  partition-id-predicated ``psum`` deadlocks the mesh (or silently
  corrupts under ``check_rep=False``). The sharded property suite can
  only catch this probabilistically — the call graph catches it
  structurally.
- **op accounting**: ``launch.hlo_analysis.analyze`` op counts plus its
  ``scatter_census`` (trip-weighted scatter/gather ops and bytes) — the
  compiled-side view of the query-latency floor.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Mapping, Tuple

from repro.launch.hlo_analysis import (COLLECTIVES, analyze, scatter_census)

_COMP_RE = re.compile(r"^(ENTRY )?%?([\w\.\-_]+)\s*\(.*\)\s*->.*\{")
_OPCODE_RE = re.compile(r"^\s*(?:ROOT )?%\S+ = \S+ ([\w\-\.]+)\(")
_CALLEE_RES = {
    "call": re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-_]+)"),
    "branch": re.compile(
        r"(?:true_computation|false_computation)=%?([\w\.\-_]+)"),
}
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_NAME_RE = re.compile(r"%?([\w\.\-_]+)")


def _parse_graph(hlo_text: str):
    """computations -> {ops: [opcode/line], edges: [(callee, kind)]}
    plus the ENTRY computation name. ``kind`` is 'branch' for
    conditional branch computations, 'call' otherwise."""
    comps: Dict[str, Dict] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and "->" in line:
            cur = mc.group(2)
            comps[cur] = {"ops": [], "edges": []}
            if mc.group(1):
                entry = cur
            continue
        if cur is None or not line.strip().startswith(("%", "ROOT")):
            continue
        mo = _OPCODE_RE.match(line)
        if not mo:
            continue
        opcode = mo.group(1)
        comps[cur]["ops"].append((opcode, line.strip()))
        is_cond = opcode.split(".")[0] == "conditional"
        mb = _BRANCHES_RE.search(line)
        if mb:
            for name in _NAME_RE.findall(mb.group(1)):
                comps[cur]["edges"].append((name, "branch"))
        for kind, rx in _CALLEE_RES.items():
            for name in rx.findall(line):
                comps[cur]["edges"].append(
                    (name, "branch" if (is_cond and kind == "call")
                     else kind))
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _is_collective(opcode: str) -> bool:
    base = opcode.split(".")[0]
    return any(base == k or base == k + "-start" for k in COLLECTIVES)


def audit_hlo(hlo_text: str, invariants: Mapping[str, object]
              ) -> Tuple[List[Dict], Dict]:
    """Audit one compiled HLO module. Returns ``(violations, info)``
    where ``info`` carries the op accounting (``analyze`` aggregates +
    ``scatter_census``)."""
    violations: List[Dict] = []

    def violate(check: str, detail: str, path: str):
        violations.append({"pass": "hlo", "check": check,
                           "detail": detail, "path": path})

    comps, entry = _parse_graph(hlo_text)

    # ---- host transfers ------------------------------------------------
    if invariants.get("no_host_transfers"):
        for cname, c in comps.items():
            for opcode, line in c["ops"]:
                base = opcode.split(".")[0]
                if base in ("infeed", "outfeed"):
                    violate("host_transfer", f"{base} op", cname)
                elif "is_host_transfer=true" in line:
                    violate("host_transfer",
                            f"{base} with is_host_transfer=true", cname)
                elif base == "custom-call":
                    m = re.search(r'custom_call_target="([^"]+)"', line)
                    target = m.group(1) if m else ""
                    if "callback" in target.lower() \
                            or "host" in target.lower():
                        violate("host_transfer",
                                f"host custom-call {target!r}", cname)

    # ---- collective balance -------------------------------------------
    if invariants.get("balanced_collectives"):
        # DFS from ENTRY; remember whether the path crossed a
        # conditional-branch edge. A collective in a computation only
        # reachable through a branch is shard-divergent.
        reach: Dict[str, bool] = {}      # comp -> reachable-under-branch

        def visit(cname: str, under_branch: bool):
            if cname not in comps:
                return
            prev = reach.get(cname)
            if prev is not None and (prev or not under_branch):
                return                    # already visited at least as bad
            reach[cname] = under_branch or bool(prev)
            for callee, kind in comps[cname]["edges"]:
                visit(callee, under_branch or kind == "branch")

        if entry is not None:
            visit(entry, False)
        for cname, under in reach.items():
            if not under:
                continue
            for opcode, _line in comps[cname]["ops"]:
                if _is_collective(opcode):
                    violate("unbalanced_collective",
                            f"{opcode} under a conditional branch "
                            f"(shards would diverge)", cname)

    # ---- op accounting -------------------------------------------------
    stats = analyze(hlo_text)
    info = {
        "op_counts": {
            "collective_counts": stats["collective_counts"],
            "scatter_ops": stats["scatter_ops"],
            "gather_ops": stats["gather_ops"],
            "dot_flops": stats["dot_flops"],
            "bytes_touched": stats["bytes_touched"],
            "scatter_bytes": stats["scatter_bytes"],
            "gather_bytes": stats["gather_bytes"],
        },
        "scatter_census": scatter_census(hlo_text),
        "n_computations": len(comps),
    }
    return violations, info

"""Engine registry for the static program auditor.

Every jitted entry point in the stack registers itself here — right
next to its ``register_cache_probe`` call — as a *lazy* triple:

    register_engine("fused_single", build_example,
                    invariants={...},
                    probe=lambda: _fused_run._cache_size(),
                    covers=("repro.core.ingest:_fused_run",))

``build_example`` is a zero-argument callable returning an
``EngineExample(fn, args, kwargs)``: the jitted callable plus small
example arguments (kwargs are the static ones) that trace in
milliseconds. Nothing is built at import time, so registering costs
nothing unless the auditor actually runs.

``covers`` lists the module-level jitted definitions this entry
exercises (``"module.path:function_name"``). The source-lint pass
cross-references the set of jitted definitions it finds in
``core/``, ``warehouse/`` and ``distribution/`` against the union of
all ``covers`` — a jitted entry point nobody registered is itself a
lint violation (the registry is the enforcement point, not a wiki).

This module is imported by the engine packages themselves, so it must
not import anything from ``repro`` (no cycles) and must stay cheap.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional, Tuple


class SkipEngine(Exception):
    """Raised by a ``build`` callable when the engine cannot run on
    this topology (e.g. a sharded kernel on a 1-device host). The
    auditor records the skip + reason instead of failing."""


class EngineExample(NamedTuple):
    """A jitted callable plus tiny example arguments for tracing.

    ``kwargs`` are the call's keyword arguments (static argnames
    included); ``args`` the positional operands.
    """
    fn: Callable
    args: Tuple[Any, ...]
    kwargs: Dict[str, Any] = {}


class Engine(NamedTuple):
    name: str
    build: Callable[[], EngineExample]
    invariants: Mapping[str, Any]
    probe: Optional[Callable[[], int]]
    covers: Tuple[str, ...]
    probe_name: Optional[str] = None


# What a registered engine promises unless it overrides. These are the
# stack's headline claims (ROADMAP / benchmark asserts) restated as
# statically-checkable invariants:
#   no_callbacks          no pure_/debug_/io_callback anywhere in the
#                         program (host round-trips on the hot path)
#   no_f64                no float64/complex128 value is ever produced
#                         (an x64 leak doubles bytes and breaks fp32
#                         bit-exactness contracts)
#   no_weak_outputs       engine outputs are strongly typed (weak types
#                         re-promote downstream consumers)
#   no_clip_scatter       every scatter states drop/in-bounds semantics;
#                         CLIP silently redirects out-of-bounds writes
#                         onto valid rows (the ShardedStore routed
#                         append RELIES on drop)
#   no_clip_gather        same for gathers: CLIP reads a wrong row
#                         instead of a fill value
#   max_new_executables   jit cache entries one warm call may add
#                         (1 = the engine is ONE dispatch)
#   zero_recompile        a second identical call adds no executables
#   no_host_transfers     compiled HLO has no infeed/outfeed/
#                         host-transfer ops
#   balanced_collectives  no collective sits under a conditional branch
#                         in compiled HLO (every shard must execute the
#                         identical collective sequence or the mesh
#                         deadlocks — the bug class the sharded property
#                         suite can only catch probabilistically)
DEFAULT_INVARIANTS: Dict[str, Any] = {
    "no_callbacks": True,
    "no_f64": True,
    "no_weak_outputs": True,
    "no_clip_scatter": True,
    "no_clip_gather": True,
    "max_new_executables": 1,
    "zero_recompile": True,
    "no_host_transfers": True,
    "balanced_collectives": True,
}

_ENGINES: Dict[str, Engine] = {}


def register_engine(name: str, build: Callable[[], EngineExample], *,
                    invariants: Optional[Mapping[str, Any]] = None,
                    probe: Optional[Callable[[], int]] = None,
                    covers: Tuple[str, ...] = (),
                    probe_name: Optional[str] = None) -> None:
    """Register a jitted engine for static verification. ``invariants``
    overrides individual ``DEFAULT_INVARIANTS`` keys; ``probe`` is the
    engine's jit-cache probe (the same callable handed to
    ``register_cache_probe``); ``covers`` names the module-level jitted
    definitions this entry exercises; ``probe_name`` is the
    ``register_cache_probe`` key this engine's probe corresponds to —
    the coverage lint cross-references the probe table against the
    union of all engines' probe names, so a probe nobody claims (or an
    engine claiming a nonexistent probe) fails the audit."""
    inv = dict(DEFAULT_INVARIANTS)
    if invariants:
        unknown = set(invariants) - set(DEFAULT_INVARIANTS)
        assert not unknown, f"unknown invariants: {sorted(unknown)}"
        inv.update(invariants)
    _ENGINES[name] = Engine(name, build, inv, probe, tuple(covers),
                            probe_name)


def example_builder(name: str, *args: Any) -> Callable[[], EngineExample]:
    """Lazy builder bound to ``repro.analysis.examples.<name>(*args)``.
    The import happens at build time, never at registration time, so
    engine modules can register without pulling in the example deps."""
    def build() -> EngineExample:
        from repro.analysis import examples
        return getattr(examples, name)(*args)
    return build


def engines() -> Dict[str, Engine]:
    """Name -> Engine, in registration order."""
    return dict(_ENGINES)


def covered_jit_names() -> set:
    """Union of every registered engine's ``covers`` set."""
    out = set()
    for e in _ENGINES.values():
        out.update(e.covers)
    return out


def claimed_probe_names() -> set:
    """Union of every registered engine's ``probe_name`` — the cache
    probes the registry actually verifies dispatch counts through."""
    return {e.probe_name for e in _ENGINES.values()
            if e.probe_name is not None}


def import_engine_modules() -> None:
    """Import every module that registers engines (idempotent). The
    auditor calls this before reading the registry."""
    import importlib
    for mod in ("repro.core.switcher", "repro.core.ingest",
                "repro.core.api", "repro.core.forecaster",
                "repro.core.categories", "repro.core.planner",
                "repro.warehouse.query", "repro.warehouse.store",
                "repro.warehouse.tiers", "repro.warehouse.standing",
                "repro.runtime.elastic"):
        importlib.import_module(mod)

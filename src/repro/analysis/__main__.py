import sys

from repro.analysis.run import main

sys.exit(main())

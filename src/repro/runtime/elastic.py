"""Elastic scaling + failure handling.

Checkpoints are mesh-agnostic (host numpy), so recovery after losing
devices is: build a new mesh from the surviving devices, derive fresh
shardings from the SAME logical rules, and restore. ``shrink_mesh``
picks the largest (data' x model) grid that fits the survivors while
keeping the model axis intact (TP degree is a property of the lowered
program; DP/FSDP degree is elastic).

``rebalance`` is the warehouse's elastic move: re-partition a
``ShardedStore``'s rows onto a different shard count in ONE collective
dispatch (the same routed-scatter program every ingest uses, pointed at
the full row set), preserving the ``stream_id % n_shards`` ownership
rule and the 1-shard==N-shard bit-exactness contract.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.analysis.registry import example_builder, register_engine
from repro.checkpoint import ckpt as CK
from repro.core.switcher import register_cache_probe
from repro.launch.mesh import make_shard_mesh
from repro.runtime.steps import train_state_shardings


def make_mesh_from(devices: Sequence, model_axis: int,
                   pod_axis: int = 1) -> Mesh:
    n = len(devices)
    if n % model_axis:
        raise ValueError(f"{n} devices not divisible by model={model_axis}")
    data_axis = n // (model_axis * pod_axis)
    shape = ((pod_axis, data_axis, model_axis) if pod_axis > 1
             else (data_axis, model_axis))
    names = (("pod", "data", "model") if pod_axis > 1 else ("data", "model"))
    devs = np.asarray(devices[:pod_axis * data_axis * model_axis]).reshape(shape)
    return Mesh(devs, names)


def shrink_mesh(old_mesh: Mesh, surviving: Sequence) -> Mesh:
    """Largest elastic mesh on the survivors with the same model degree."""
    model_axis = old_mesh.shape.get("model", 1)
    usable = (len(surviving) // model_axis) * model_axis
    if usable == 0:
        raise RuntimeError("not enough devices for one model shard")
    return make_mesh_from(list(surviving)[:usable], model_axis)


def restore_elastic(ckpt_dir: str, model, mesh: Mesh, step=None):
    """Restore the latest checkpoint resharded onto ``mesh``."""
    step = CK.latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None
    shardings = train_state_shardings(model, mesh)
    state = CK.restore(ckpt_dir, step, mesh=mesh, shardings=shardings)
    return state, step


# ---------------------------------------------------------------------------
# warehouse shard rebalancing: ShardedStore rows -> a new shard count
# ---------------------------------------------------------------------------

# (mesh_new, s_old, s_new) -> jitted repartition kernel; plain dict so
# the cache probe can sum executable counts (same idiom as the store's
# _SHARD_KERNELS)
_REBALANCE_KERNELS: Dict = {}


def _rebalance_kernel(mesh_new, s_old: int, s_new: int):
    """The one-dispatch repartition program: flatten the old stacked
    columns to a single shard-major row block, mask rows past each old
    shard's valid count, re-derive ownership as ``stream_id % s_new``,
    and run the store's routed scatter (``_route_write``) into fresh
    columns — shard_map on the new mesh (each device keeps exactly its
    rows) or the vmapped stacked fallback. The scatter's drop semantics
    do all the masking: invalid rows' owner points past the last shard,
    so they land nowhere."""
    key = (mesh_new, s_old, s_new)
    kern = _REBALANCE_KERNELS.get(key)
    if kern is not None:
        return kern
    from repro.warehouse.store import _route_write

    @functools.partial(jax.jit, static_argnames=("cap_new",))
    def kern(cols, n_rows_dev, *, cap_new):
        cap_old = cols["t"].shape[1]
        flat = {k: v.reshape((s_old * cap_old,) + v.shape[2:])
                for k, v in cols.items()}
        valid = (jnp.arange(cap_old)[None, :]
                 < n_rows_dev[:, None]).reshape(-1)
        owner = jnp.where(valid,
                          flat["stream_id"].astype(jnp.int32) % s_new,
                          jnp.int32(s_new))

        def empty_like(u):
            return {k: jnp.zeros((cap_new,) + v.shape[1:], v.dtype)
                    for k, v in u.items()}

        if mesh_new is None:
            def one(sid):
                return _route_write(empty_like(flat), jnp.int32(0),
                                    flat, owner, sid)

            return jax.vmap(one)(jnp.arange(s_new, dtype=jnp.int32))

        def body(u, ow):
            sid = jax.lax.axis_index("shard")
            new, nn = _route_write(empty_like(u), jnp.int32(0), u, ow,
                                   sid)
            return jax.tree.map(lambda x: x[None], new), nn[None]

        return shard_map(body, mesh=mesh_new, in_specs=(P(), P()),
                         out_specs=(P("shard"), P("shard")),
                         check_rep=False)(flat, owner)

    _REBALANCE_KERNELS[key] = kern
    return kern


def _rebalance_cache_size():
    return sum(k._cache_size() for k in _REBALANCE_KERNELS.values())


register_cache_probe("store_rebalance", _rebalance_cache_size)
register_engine("store_rebalance", example_builder("store_rebalance"),
                probe=_rebalance_cache_size,
                probe_name="store_rebalance")


def rebalance(store, new_shards: int, mesh="auto"):
    """Re-partition a ``ShardedStore`` onto ``new_shards`` shards in ONE
    collective dispatch; returns a NEW store (the input is untouched).

    The elastic pool's ownership rule is ``stream_id % n_shards``, so
    admitting/retiring streams — or resizing the serving fleet — skews
    the row distribution the rule originally balanced. ``rebalance``
    re-derives every row's owner under the new shard count and routes it
    there with the exact scatter program the ingest paths use, on
    device: no host gathers, no per-row loops, one dispatch regardless
    of row count. Row payloads move bit-identically, so the result obeys
    the 1-shard == N-shard property contract: row sets, counts, and
    masks are exact; float aggregates match to the suite's partial-sum
    ordering tolerance (a different shard count is a different but
    equally valid reduction tree).

    Standing queries registered on ``store`` are re-registered on the
    new store IN HANDLE ORDER (alert subscriptions included), so
    existing handles remain valid against ``new_store.standing``; their
    state is rebuilt by the registration backfill over the repartitioned
    rows.

    ``mesh``: "auto" builds a mesh over the first ``new_shards`` devices
    (stacked fallback when the host has fewer), or pass an explicit mesh
    / None."""
    assert new_shards >= 1
    from repro.warehouse.store import ShardedStore, _bucket_cap
    assert isinstance(store, ShardedStore), "rebalance takes a ShardedStore"
    mesh_new = make_shard_mesh(new_shards) if mesh == "auto" else mesh
    # one shard could own every row; sizing for the total keeps the
    # repartition a single fixed-shape dispatch with no host read of ids
    cap_new = _bucket_cap(max(store.n_rows, 1), store.chunk_rows)
    kern = _rebalance_kernel(mesh_new, store.n_shards, new_shards)
    # the source columns are committed to the OLD mesh's devices; move
    # them onto the new placement (replicated over the new mesh, or the
    # default device for the stacked fallback) so the repartition
    # dispatch sees one coherent device set
    if mesh_new is not None:
        target = jax.sharding.NamedSharding(mesh_new, P())
    else:
        target = jax.devices()[0]
    cols_in = jax.device_put(store.columns, target)
    nrd_in = jax.device_put(store.n_rows_dev, target)
    cols, n_rows_dev = kern(cols_in, nrd_in, cap_new=cap_new)
    counts = np.asarray(n_rows_dev, np.int64)   # (new_shards,) host pull
    new = ShardedStore._from_parts(
        out_dim=store.out_dim, n_shards=new_shards,
        chunk_rows=store.chunk_rows, mesh=mesh_new, columns=cols,
        n_rows_dev=n_rows_dev, n_rows_by_shard=counts, t_max=store.t_max)
    old_reg = getattr(store, "standing", None)
    if old_reg is not None and len(old_reg._queries):
        from repro.warehouse.standing import StandingQueries
        reg = StandingQueries(new)
        subs_by_handle = {s.handle: s for s in old_reg._subs.values()}
        for h in sorted(old_reg._queries):
            q = old_reg._queries[h]
            sub = subs_by_handle.get(h)
            if sub is not None:
                reg.subscribe(list(q.plan), sub.predicate, name=sub.name)
            else:
                reg.register(list(q.plan), name=q.name)
    return new

"""Elastic scaling + failure handling.

Checkpoints are mesh-agnostic (host numpy), so recovery after losing
devices is: build a new mesh from the surviving devices, derive fresh
shardings from the SAME logical rules, and restore. ``shrink_mesh``
picks the largest (data' x model) grid that fits the survivors while
keeping the model axis intact (TP degree is a property of the lowered
program; DP/FSDP degree is elastic).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import ckpt as CK
from repro.runtime.steps import train_state_shardings


def make_mesh_from(devices: Sequence, model_axis: int,
                   pod_axis: int = 1) -> Mesh:
    n = len(devices)
    if n % model_axis:
        raise ValueError(f"{n} devices not divisible by model={model_axis}")
    data_axis = n // (model_axis * pod_axis)
    shape = ((pod_axis, data_axis, model_axis) if pod_axis > 1
             else (data_axis, model_axis))
    names = (("pod", "data", "model") if pod_axis > 1 else ("data", "model"))
    devs = np.asarray(devices[:pod_axis * data_axis * model_axis]).reshape(shape)
    return Mesh(devs, names)


def shrink_mesh(old_mesh: Mesh, surviving: Sequence) -> Mesh:
    """Largest elastic mesh on the survivors with the same model degree."""
    model_axis = old_mesh.shape.get("model", 1)
    usable = (len(surviving) // model_axis) * model_axis
    if usable == 0:
        raise RuntimeError("not enough devices for one model shard")
    return make_mesh_from(list(surviving)[:usable], model_axis)


def restore_elastic(ckpt_dir: str, model, mesh: Mesh, step=None):
    """Restore the latest checkpoint resharded onto ``mesh``."""
    step = CK.latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None
    shardings = train_state_shardings(model, mesh)
    state = CK.restore(ckpt_dir, step, mesh=mesh, shardings=shardings)
    return state, step

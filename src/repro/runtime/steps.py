"""Step builders: jit-able train / prefill / decode steps with logical
sharding specs — shared by the launcher, the dry-run, and tests."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution import sharding as shd
from repro.models.model import Model
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               warmup_cosine)


def init_train_state(model: Model, key):
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: Model):
    params = model.abstract_params()
    zeros = jax.tree.map(lambda s: s, params)
    return {"params": params,
            "opt": {"m": zeros, "v": zeros,
                    "count": jax.ShapeDtypeStruct((), jnp.int32)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_shardings(model: Model, mesh):
    p = model.param_shardings(mesh)
    rep = shd.named(mesh, shd.spec_for((), (), mesh))
    return {"params": p, "opt": {"m": p, "v": p, "count": rep}, "step": rep}


def make_train_step(model: Model, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    clip: float = 1.0, weight_decay: float = 0.1):
    opts = model.opts

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state, batch):
        params = state["params"]
        n_mb = opts.microbatches
        if n_mb > 1:
            mb = jax.tree.map(
                lambda a: a.reshape((n_mb, a.shape[0] // n_mb) + a.shape[1:]),
                batch)

            def acc(carry, b):
                l, g = jax.value_and_grad(loss_fn)(params, b)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.float32(0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(acc, zero, mb)
            loss = loss / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        lr = warmup_cosine(state["opt"]["count"], peak_lr=peak_lr,
                           warmup=warmup, total=total_steps)
        new_params, new_opt = adamw_update(grads, state["opt"], params,
                                           lr=lr, weight_decay=weight_decay)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "gnorm": gnorm, "lr": lr}

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, token):
        return model.decode_step(params, cache, token)
    return decode_step

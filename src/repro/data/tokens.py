"""Token data pipeline for LM training: deterministic synthetic corpus
(zipfian unigrams + markov bigram structure so loss decreases are
meaningful), host-sharded batch iterator, and frontend-stub inputs for
the VLM / audio archs."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticCorpus:
    """Zipf-distributed tokens with a learnable bigram structure."""

    def __init__(self, vocab: int, seed: int = 0, order_mix: float = 0.7):
        self.vocab = vocab
        self.seed = seed
        self.order_mix = order_mix
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # each token prefers a small successor set
        self.succ = rng.integers(0, vocab, size=(vocab, 4))

    def batch(self, batch: int, seq: int, step: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed + 977 * step)
        out = np.empty((batch, seq), np.int64)
        out[:, 0] = rng.choice(self.vocab, size=batch, p=self.unigram)
        for t in range(1, seq):
            use_bigram = rng.random(batch) < self.order_mix
            succ_pick = self.succ[out[:, t - 1],
                                  rng.integers(0, 4, size=batch)]
            uni = rng.choice(self.vocab, size=batch, p=self.unigram)
            out[:, t] = np.where(use_bigram, succ_pick, uni)
        return out


def make_batch_iter(cfg, *, global_batch: int, seq_len: int, seed: int = 0,
                    mesh=None, shardings: Optional[Dict] = None
                    ) -> Iterator[Dict]:
    """Yields batches matching Model.input_specs(train) shapes. With
    (mesh, shardings) arrays are device_put sharded (the host-sharded
    ingestion path)."""
    corpus = SyntheticCorpus(cfg.vocab, seed)
    rng = np.random.default_rng(seed + 1)
    step = 0
    while True:
        if cfg.family == "encdec":
            dec = min(cfg.max_target_len, seq_len)
            b = {"frames": rng.normal(0, 1, (global_batch, seq_len,
                                             cfg.d_model)).astype(np.float32),
                 "tokens": corpus.batch(global_batch, dec, step)}
        elif cfg.frontend_tokens:
            F = cfg.frontend_tokens
            b = {"embeds": rng.normal(0, 1, (global_batch, F, cfg.d_model)
                                      ).astype(np.float32),
                 "tokens": corpus.batch(global_batch, seq_len - F, step)}
        else:
            b = {"tokens": corpus.batch(global_batch, seq_len, step)}
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if shardings is not None:
            b = {k: jax.device_put(v, shardings[k]) for k, v in b.items()}
        yield b
        step += 1

"""Synthetic V-ETL content streams with ground-truth quality oracle.

The real sources (Shibuya traffic cams, CMU-MOSEI, Twitch counts) are
unavailable offline, so streams are re-synthesized to the published
statistics: semi-Markov latent content states with the paper's mean
dwell times (§5.3: COVID 42 s, MOT 43 s, MOSEI 30/24 s), a diurnal
difficulty cycle for the traffic workloads, and the MOSEI HIGH/LONG
arrival spikes (§5.2). Each segment carries a scalar difficulty in
[0,1]; ground-truth quality of config k is 1 - difficulty*(1 - power_k).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.configs.workloads import WorkloadCfg

DAY_SECONDS = 86_400.0


@dataclass
class Stream:
    workload: str
    segment_seconds: float
    latent: np.ndarray          # (T,) int
    difficulty: np.ndarray      # (T,) float [0,1]
    arrival: np.ndarray         # (T,) float work multiplier (stream count)
    state_difficulty: np.ndarray  # (n_latent,)

    @property
    def n_segments(self) -> int:
        return len(self.latent)

    def quality(self, power: np.ndarray, noise_sigma: float = 0.02,
                seed: int = 0) -> np.ndarray:
        """(T, K) ground-truth quality of each config on each segment."""
        from repro.core.knobs import quality as qfn
        rng = np.random.default_rng(seed)
        q = qfn(power[None, :], self.difficulty[:, None])
        q = q + rng.normal(0, noise_sigma, q.shape)
        return np.clip(q, 0.0, 1.0)


def generate(w: WorkloadCfg, days: float, seed: int = 0) -> Stream:
    rng = np.random.default_rng(seed)
    tau = w.segment_seconds
    T = int(days * DAY_SECONDS / tau)
    n = w.n_latent
    state_diff = np.linspace(0.08, 0.92, n)
    dwell = max(2, int(w.dwell_seconds / tau))

    # time-of-day difficulty weighting (traffic: hard during the day)
    t_sec = np.arange(T) * tau
    tod = (t_sec % DAY_SECONDS) / DAY_SECONDS
    if w.diurnal:
        # smooth day bump centred at 13:00 plus rush-hour shoulders
        day = np.exp(-0.5 * ((tod - 0.55) / 0.22) ** 2)
        rush = (np.exp(-0.5 * ((tod - 0.35) / 0.04) ** 2)
                + np.exp(-0.5 * ((tod - 0.73) / 0.04) ** 2))
        hardness = 0.15 + 0.6 * day + 0.5 * rush
    else:
        hardness = 0.5 + 0.25 * np.sin(2 * np.pi * t_sec / (DAY_SECONDS / 3))
    hardness = np.clip(hardness, 0.05, 1.1)

    latent = np.zeros(T, np.int64)
    cur = 0
    t = 0
    while t < T:
        run = 1 + rng.geometric(1.0 / dwell)
        latent[t:t + run] = cur
        t += run
        # next state: biased towards difficulty ~ hardness(t)
        target = hardness[min(t, T - 1)] * (n - 1)
        w_states = np.exp(-0.5 * ((np.arange(n) - target) / 0.9) ** 2)
        w_states /= w_states.sum()
        cur = rng.choice(n, p=w_states)

    difficulty = state_diff[latent] + rng.normal(0, 0.03, T)
    difficulty = np.clip(difficulty, 0.0, 1.0)

    arrival = np.ones(T)
    if w.spike == "high":
        # short, tall spikes: every ~6h, 5-minute bursts of 62/12 ~ 5x work
        period = int(6 * 3600 / tau)
        width = int(300 / tau)
        for s in range(period // 2, T, period):
            arrival[s:s + width] = 5.0
    elif w.spike == "long":
        # one sustained peak per day lasting ~6 h at 2.2x
        period = int(DAY_SECONDS / tau)
        width = int(6 * 3600 / tau)
        for s in range(period // 3, T, period):
            arrival[s:s + width] = 2.2
    elif not w.diurnal:
        arrival = 1.0 + 0.3 * np.sin(2 * np.pi * t_sec / DAY_SECONDS)

    return Stream(w.name, tau, latent, difficulty, arrival, state_diff)

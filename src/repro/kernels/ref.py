"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """Naive masked softmax attention. q (B,Sq,H,D); k/v (B,Skv,G,D)."""
    B, Sq, H, D = q.shape
    _, Skv, G, _ = k.shape
    R = H // G
    qg = q.reshape(B, Sq, G, R, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bqgrd,bsgd->bgrqs", qg, k.astype(jnp.float32))
    qp, kp = jnp.arange(Sq), jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqs,bsgd->bgrqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, D).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence (see models.ssd.ssd_ref)."""
    from repro.models.ssd import ssd_ref as _r
    y, _ = _r(x, dt, A, Bm, Cm)
    return y


def downsample_ref(frame, factor: int):
    squeeze = frame.ndim == 3
    if squeeze:
        frame = frame[None]
    B, H, W, C = frame.shape
    x = frame.astype(jnp.float32).reshape(
        B, H // factor, factor, W // factor, factor, C)
    out = x.mean(axis=(2, 4)).astype(frame.dtype)
    return out[0] if squeeze else out

"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA).

Canonical TPU structure: grid (B, H, nq, nk) with the KV dimension
innermost (sequential on TPU), fp32 running-softmax state in VMEM
scratch, MXU-aligned (multiples of 128) blocks. ``pl.when`` guards
initialize scratch at ki==0 and write the output at the last KV block.

Validated on CPU in interpret mode against ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, seq_q: int, seq_k: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq,bk)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q (B,Sq,H,D); k,v (B,Skv,G,D), H = G*R. Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    _, Skv, G, _ = k.shape
    scale = D ** -0.5
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))
    nq, nk = -(-Sq // block_q), -(-Skv // block_k)
    pq, pk = nq * block_q - Sq, nk * block_k - Skv
    qt = jnp.moveaxis(q, 2, 1)                            # (B,H,Sq,D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))

    rep = H // G

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, seq_q=Sq,
                          seq_k=Skv, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)[:, :Sq]

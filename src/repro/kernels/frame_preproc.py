"""Pallas TPU kernel for V-ETL frame preprocessing: box-downsample by an
integer factor (the paper's *resolution* knob) — the only pixel-touching
hot loop Skyscraper itself owns (UDF-internal compute belongs to the
models). Tiling (the paper's 1x1/2x2 *tiling* knob) is a pure reshape in
``ops.tile_frames``.

Each grid instance reduces a (bh*f, bw*f, C) input tile to a (bh, bw, C)
output tile in VMEM — one load, one store, arithmetic intensity f^2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, factor: int, bh: int, bw: int):
    x = x_ref[0].astype(jnp.float32)                     # (bh*f, bw*f, C)
    C = x.shape[-1]
    x = x.reshape(bh, factor, bw, factor, C)
    o_ref[0] = x.mean(axis=(1, 3)).astype(o_ref.dtype)


def downsample(frame, factor: int, *, block: int = 64,
               interpret: bool = True):
    """frame (H,W,C) or (B,H,W,C), H,W divisible by factor."""
    squeeze = frame.ndim == 3
    if squeeze:
        frame = frame[None]
    B, H, W, C = frame.shape
    assert H % factor == 0 and W % factor == 0
    oh, ow = H // factor, W // factor
    bh = min(block, oh)
    bw = min(block, ow)
    # pad output dims to block multiples
    gh, gw = -(-oh // bh), -(-ow // bw)
    ph, pw = gh * bh * factor - H, gw * bw * factor - W
    if ph or pw:
        frame = jnp.pad(frame, ((0, 0), (0, ph), (0, pw), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, factor=factor, bh=bh, bw=bw),
        grid=(B, gh, gw),
        in_specs=[pl.BlockSpec((1, bh * factor, bw * factor, C),
                               lambda b, i, j: (b, i, j, 0))],
        out_specs=pl.BlockSpec((1, bh, bw, C), lambda b, i, j: (b, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, gh * bh, gw * bw, C), frame.dtype),
        interpret=interpret,
    )(frame)
    out = out[:, :oh, :ow]
    return out[0] if squeeze else out

"""Pallas fused filter+group+aggregate kernel for the warehouse query
engine — the "break the scatter floor" primitive (ROADMAP).

The XLA query path bottoms out on scatter-based ``segment_sum``: one
executed scatter per groupby-style plan (the static auditor's census
pins it — ``scatter_ops.*`` in ANALYSIS.json / the bench snapshots).
This kernel removes the scatter entirely: ONE pass over chunk-tiled
columns per grid step, the plan's predicate mask evaluated in-register
(never materialized to memory), and the segment aggregation expressed
as a one-hot ``(n_groups, block_rows)`` contraction accumulated
directly into a ``(n_groups[, lanes])`` on-chip accumulator that every
grid step revisits. Accumulators follow the engine's partial
convention exactly — ``{"acc", "cnt"}``, with ``∓inf`` sentinels for
``max``/``min`` — so the caller reuses ``_seg_finalize`` verbatim and
the fused partial is mergeable by the same sharded combiners
(psum/pmax) as the XLA partial.

The sequential-grid accumulation pattern (output block index map
pinned to 0, ``pl.when(step == 0)`` init) relies on Pallas' in-order
grid execution, and runs in interpret mode on CPU — that is the
tier-1-testable path in this container; on TPU the same kernel
compiles with the one-hot contraction as an MXU ``dot_general``.

fp32 exactness contract: ``count``/``max``/``min`` and integer-valued
sums are exact vs the XLA path and the numpy mirror; float ``sum`` /
``mean`` regroup the addition order across row tiles (tile-level
partial sums) and match to the same tolerance as multi-shard merges.

This module is import-light on purpose: ``repro.warehouse.query``
imports the kernel AND the predicate helpers (``CMP``/``int_pred``)
from here, never the other way around.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
}


def int_pred(x, op, i, is_int, oob):
    """Exact real-number comparison of an INTEGER column ``x`` against a
    threshold hoisted host-side as ``(floor(v), integral?, oob)`` — the
    float64 host computation means neither side ever rounds through f32
    (which collapses ints past 2^24; the append-only ``t`` column
    crosses that after ~388 days of 2 s segments). All three operands
    are dynamic: changing the threshold never recompiles.

    Every rewrite is closed-form in ``floor(v)`` with NO ``±1``
    arithmetic (the old ``x >= i + 1`` form both truncation-vs-floor
    mis-bucketed negative non-integral thresholds and overflowed at the
    int32 clamp edge):

        x >= v  <=>  x >= floor(v)  when v integral, else x > floor(v)
        x >  v  <=>  x > floor(v)          (integral or not)
        x <= v  <=>  x <= floor(v)         (integral or not)
        x <  v  <=>  x < floor(v)   when v integral, else x <= floor(v)

    ``oob`` (int32: -1/0/+1) marks thresholds outside int32 entirely
    (incl. ∓inf), where the comparison is constant for every possible
    x: below-range makes ge/gt/ne all-true, above-range makes le/lt/ne
    all-true."""
    i = i.astype(x.dtype)
    if op == "eq":
        return is_int & (x == i) & (oob == 0)
    if op == "ne":
        return ~is_int | (x != i) | (oob != 0)
    if op == "ge":
        p = jnp.where(is_int, x >= i, x > i)
        return jnp.where(oob == 0, p, oob < 0)
    if op == "gt":
        return jnp.where(oob == 0, x > i, oob < 0)
    if op == "le":
        return jnp.where(oob == 0, x <= i, oob > 0)
    if op == "lt":
        p = jnp.where(is_int, x < i, x <= i)
        return jnp.where(oob == 0, p, oob > 0)
    raise ValueError(f"unknown filter op {op!r}")


@dataclass(frozen=True)
class FusedAggSpec:
    """Static (hashable) shape of one fused filter+group+aggregate
    pass — the partial phase of a plan up to and including its first
    segment-reducing node.

    ``filters[j] = (column, op, idx)`` with ``idx`` indexing the
    dynamic operand vectors; ``keys[j] = (column, num_ids, window)``
    is the fused multi-key encoding (``window > 1`` divides the key
    column first; ids clip into ``[0, num_ids)``), identical to the
    engine's ``_seg_ids``."""
    filters: Tuple[Tuple[str, str, int], ...]
    keys: Tuple[Tuple[str, int, int], ...]
    value: str
    agg: str  # sum | mean | count | max | min

    @property
    def num_groups(self) -> int:
        return math.prod(n for _, n, _ in self.keys)


def _agg_kernel(*refs, filters, keys, num, bn, wide, agg):
    """One grid step: rows ``[step*bn, step*bn+bn)`` of every operand
    column -> mask in-register -> one-hot contraction into the
    revisited ``(num[, D])`` accumulators. ``filters``/``keys`` carry
    positions into ``col_refs`` (baked static, loops fully unrolled)."""
    n_ref, vals_ref, floors_ref, isint_ref, oob_ref = refs[:5]
    col_refs = refs[5:-2]
    acc_ref, cnt_ref = refs[-2], refs[-1]
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        if agg == "max":
            acc_ref[...] = jnp.full_like(acc_ref, -jnp.inf)
        elif agg == "min":
            acc_ref[...] = jnp.full_like(acc_ref, jnp.inf)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    # validity mask, (1, bn), never materialized outside registers
    rows = step * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    mask = rows < n_ref[0]
    for pos, op, fidx in filters:
        x = col_refs[pos][...]
        if jnp.issubdtype(x.dtype, jnp.integer):
            p = int_pred(x, op, floors_ref[fidx], isint_ref[fidx] != 0,
                         oob_ref[fidx])
        else:
            p = CMP[op](x.astype(jnp.float32), vals_ref[fidx])
        mask = mask & p[None, :]

    # fused multi-key group ids, (1, bn) — same clip/encode as _seg_ids
    gid = None
    for pos, n_ids, window in keys:
        ids = col_refs[pos][...].astype(jnp.int32)
        if window > 1:
            ids = ids // window
        ids = jnp.clip(ids, 0, n_ids - 1)
        gid = ids if gid is None else gid * n_ids + ids
    gid = gid[None, :]

    # one-hot (num, bn): the scatter-free segment reduction
    oh = (jax.lax.broadcasted_iota(jnp.int32, (num, bn), 0) == gid) & mask
    cnt_ref[...] += jnp.sum(oh.astype(jnp.float32), axis=1)
    v = col_refs[-1][...].astype(jnp.float32)
    if agg in ("sum", "mean", "count"):
        if wide:                                 # (bn, D) value column
            acc_ref[...] += jax.lax.dot_general(
                oh.astype(jnp.float32), v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            acc_ref[...] += jnp.sum(jnp.where(oh, v[None, :], 0.0), axis=1)
    elif agg == "max":
        acc_ref[...] = jnp.maximum(
            acc_ref[...], jnp.max(jnp.where(oh, v[None, :], -jnp.inf),
                                  axis=1))
    else:                                        # min
        acc_ref[...] = jnp.minimum(
            acc_ref[...], jnp.min(jnp.where(oh, v[None, :], jnp.inf),
                                  axis=1))


def _full(shape):
    """BlockSpec for an operand every grid step sees whole."""
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def _vec(vec, pad_to=1):
    """Dynamic operand vector -> non-empty f32/i32 array the kernel can
    take a BlockSpec over (zero filters still needs a (1,) ref)."""
    if vec.shape[0] == 0:
        return jnp.zeros((pad_to,), vec.dtype)
    return vec


def fused_segment_agg(cols, n_rows, fvals, *, spec: FusedAggSpec,
                      block_rows: int = 1024, interpret=None):
    """Run ONE fused filter+group+aggregate pass over ``cols`` and
    return the engine's partial ``{"acc", "cnt"}`` (finalize with
    ``_seg_finalize``; merge across shards with sum/pmax/pmin like any
    XLA partial). ``cols`` is the store's column dict (only the spec's
    operand columns are read); ``n_rows`` masks capacity padding;
    ``fvals`` is the ``normalize()`` operand tuple
    ``(vals, floors, isint, oob)``.

    ``interpret=None`` picks interpret mode off-TPU (the CPU test
    path); pass an explicit bool to force either."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    vals, floors, isint, oob = fvals
    v = cols[spec.value]
    wide = v.ndim == 2
    cap = v.shape[0]
    num = spec.num_groups

    # operand columns: filters first (dedup by first use), keys, value
    names = []
    for col, _, _ in spec.filters:
        if col not in names:
            names.append(col)
    fpos = [(names.index(col), op, fidx)
            for col, op, fidx in spec.filters]
    kpos = []
    for col, n_ids, window in spec.keys:
        if col not in names:
            names.append(col)
        kpos.append((names.index(col), n_ids, window))
    names.append(spec.value)                      # always last

    bn = max(1, min(block_rows, cap))
    # at least one grid step even for a zero-capacity store (an empty
    # store still answers the query: every group empty), so the init
    # step always runs and the outputs are never left unwritten
    pad = max(bn, cap + (-cap % bn)) - cap
    operands = []
    for name in names:
        arr = cols[name]
        if pad:
            arr = jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1))
        operands.append(arr)
    n_arr = jnp.reshape(n_rows.astype(jnp.int32), (1,))
    dyn = (n_arr, _vec(vals), _vec(floors),
           _vec(isint.astype(jnp.int32)), _vec(oob))

    col_specs = []
    for arr in operands:
        if arr.ndim == 2:
            col_specs.append(pl.BlockSpec((bn, arr.shape[1]),
                                          lambda i: (i, 0)))
        else:
            col_specs.append(pl.BlockSpec((bn,), lambda i: (i,)))
    acc_shape = (num, v.shape[1]) if wide else (num,)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, filters=tuple(fpos),
                          keys=tuple(kpos), num=num, bn=bn, wide=wide,
                          agg=spec.agg),
        grid=((cap + pad) // bn,),
        in_specs=[_full(d.shape) for d in dyn] + col_specs,
        out_specs=[_full(acc_shape), _full((num,))],
        out_shape=[jax.ShapeDtypeStruct(acc_shape, jnp.float32),
                   jax.ShapeDtypeStruct((num,), jnp.float32)],
        interpret=interpret,
    )(*dyn, *operands)
    return {"acc": out[0], "cnt": out[1]}


# cost-model bounds for the auto dispatch: the one-hot contraction does
# O(num_groups) lane work per row where the scatter does O(1), so the
# fused kernel wins only while the whole accumulator set stays on-chip
# and the group count is modest (the scatter's serialization penalty it
# removes is large but not unbounded)
_AUTO_MAX_GROUPS = 2048
_AUTO_MAX_ACC_BYTES = 4 << 20


def pallas_auto(spec: FusedAggSpec, value_width: int = 1) -> bool:
    """Cost-based dispatch decision for ``use_pallas=None``: True only
    on a real TPU backend (interpret mode on CPU is a correctness
    path, not a fast path) and only when the accumulator footprint
    fits comfortably on-chip."""
    if jax.default_backend() != "tpu":
        return False
    num = spec.num_groups
    return (num <= _AUTO_MAX_GROUPS
            and num * max(1, value_width) * 4 <= _AUTO_MAX_ACC_BYTES)

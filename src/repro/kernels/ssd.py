"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid (B, H, nc) with the chunk dimension innermost (sequential on TPU);
the (P, N) inter-chunk state lives in VMEM scratch and is carried across
the chunk dimension — the TPU-native analogue of the CUDA SSD kernel's
persistent-CTA state. Intra-chunk work is two MXU matmuls:
(Q,N)x(N,Q) for C.B^T and (Q,Q)x(Q,P) for the masked-decay attention.

Validated in interpret mode against ``repro.models.ssd.ssd_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
            chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)               # (Q,P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # (Q,)
    A = a_ref[0]                                         # ()
    Bm = b_ref[0, :, 0].astype(jnp.float32)              # (Q,N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)              # (Q,N)

    la = dt * A                                          # (Q,) log decay
    cum = jnp.cumsum(la)                                 # (Q,)
    total = cum[-1]
    state = state_scr[...]                               # (P,N)

    # intra-chunk: y[t] = sum_{s<=t} (C_t.B_s) exp(cum_t-cum_s) dt_s x_s
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    seg = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    w = jnp.where(tri, jnp.exp(seg) * dt[None, :], 0.0)
    y = jax.lax.dot_general(CB * w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,P)
    # inter-chunk: y[t] += exp(cum_t) * C_t . state   (state (P,N))
    cs = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,P)
    y = y + cs * jnp.exp(cum)[:, None]
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: S' = exp(total) S + sum_s exp(total-cum_s) dt_s x_s (x) B_s
    decay_out = (jnp.exp(total - cum) * dt)[:, None]     # (Q,1)
    xs = x * decay_out                                   # (Q,P)
    upd = jax.lax.dot_general(xs, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P,N)
    state_scr[...] = jnp.exp(total) * state + upd


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = True):
    """x (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative;
    Bm/Cm (B,S,G,N). Returns y (B,S,H,P) (final state not emitted)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    grid = (B, H, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=Q, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, ci: (b, ci, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, ci: (b, ci, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return out[:, :S]

"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes with jnp semantics, validating BlockSpec indexing and the
streaming-softmax/state-carry logic. On TPU set ``interpret=False`` (the
default flips automatically based on the backend).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import frame_preproc as _fp
from repro.kernels import ssd as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
             interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("factor", "block", "interpret"))
def downsample(frame, *, factor: int, block: int = 64,
               interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _fp.downsample(frame, factor, block=block, interpret=interpret)


def tile_frames(frame, tiles: int):
    """Paper's tiling knob: split (B,H,W,C) into t x t tiles stacked on
    batch (t = sqrt(tiles))."""
    t = int(tiles ** 0.5)
    if t * t != tiles:
        raise ValueError("tiles must be a square number")
    if t == 1:
        return frame
    B, H, W, C = frame.shape
    x = frame.reshape(B, t, H // t, t, W // t, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B * t * t, H // t, W // t, C)

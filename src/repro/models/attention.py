"""Attention: RoPE + chunked (flash-style) GQA attention in pure jnp.

Three paths, all sharing the same math as ``repro.kernels``:

- ``mha``: training/prefill. Streaming-softmax over KV chunks (memory
  O(q_chunk x kv_chunk), never materializes S x S), GQA without
  materializing repeated KV heads.
- ``banded_mha``: sliding-window prefill. Each query chunk attends to a
  gathered [qs-window, qs+qc) KV band, so FLOPs are O(S*(W+qc)) instead
  of O(S^2) — this is the sub-quadratic path used by SWA archs.
- ``decode_attend``: one query step against a (possibly ring-buffer) KV
  cache with per-slot absolute positions.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------- RoPE --------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, D); positions broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------- full / causal MHA ------------------------------
def _gqa_scores(qg, kc):
    # qg (B,qc,G,R,D) x kc (B,kc,G,D) -> (B,G,R,qc,kc)
    return jnp.einsum("bqgrd,bsgd->bgrqs", qg, kc,
                      preferred_element_type=jnp.float32)


def mha(q, k, v, *, causal: bool = True, q_offset: int = 0,
        q_chunk: int = 512, kv_chunk: int = 1024, scale: Optional[float] = None):
    """q (B,Sq,H,D); k,v (B,Skv,G,D) with H = G*R. Returns (B,Sq,H,D).

    Streaming softmax: outer scan over query chunks, inner scan over KV
    chunks with running (max, denom, acc) in fp32.
    """
    B, Sq, H, D = q.shape
    _, Skv, G, _ = k.shape
    R = H // G
    scale = scale or D ** -0.5
    if k.dtype != q.dtype:        # e.g. fp8 cache: upcast at the matmul
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq, nk = -(-Sq // q_chunk), -(-Skv // kv_chunk)
    pad_q, pad_k = nq * q_chunk - Sq, nk * kv_chunk - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qr = (q * scale).reshape(B, nq, q_chunk, G, R, D)
    kr = k.reshape(B, nk, kv_chunk, G, D)
    vr = v.reshape(B, nk, kv_chunk, G, D)

    kv_pos = jnp.arange(nk * kv_chunk)
    valid_k = kv_pos < Skv

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            s = _gqa_scores(q_blk, k_blk)                 # (B,G,R,qc,kc)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = valid_k[ki * kv_chunk + jnp.arange(kv_chunk)]
            if causal:
                mask = mask[None, :] & (q_pos[:, None] >= kpos[None, :])
            else:
                mask = jnp.broadcast_to(mask[None, :], (q_chunk, kv_chunk))
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bgrqs,bsgd->bgrqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), ()

        m0 = jnp.full((B, G, R, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, G, R, q_chunk, D), jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (ks, jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,G,R,qc,D)
        return jnp.moveaxis(out, 3, 1)                    # (B,qc,G,R,D)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


# ------------------------- banded (sliding-window) --------------------------
def banded_mha(q, k, v, *, window: int, q_chunk: int = 512,
               scale: Optional[float] = None):
    """Causal sliding-window attention, FLOPs O(Sq * (window + q_chunk)).

    Each query chunk [qs, qs+qc) attends to KV band [qs-window, qs+qc).
    """
    B, Sq, H, D = q.shape
    _, Skv, G, _ = k.shape
    R = H // G
    scale = scale or D ** -0.5
    q_chunk = min(q_chunk, Sq)
    nq = -(-Sq // q_chunk)
    pad_q = nq * q_chunk - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    band = window + q_chunk
    # left-pad kv by `window` so slice [qs, qs+band) = original [qs-W, qs+qc);
    # right-pad by pad_q so the final chunk's dynamic_slice never clamps.
    kp = jnp.pad(k, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    qr = (q * scale).reshape(B, nq, q_chunk, G, R, D)

    def q_block(args):
        qi, q_blk = args
        qs = qi * q_chunk
        k_blk = jax.lax.dynamic_slice_in_dim(kp, qs, band, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, qs, band, axis=1)
        s = _gqa_scores(q_blk, k_blk)                     # (B,G,R,qc,band)
        q_pos = qs + jnp.arange(q_chunk)
        k_pos = qs - window + jnp.arange(band)            # absolute (may be <0)
        mask = ((k_pos[None, :] <= q_pos[:, None])
                & (k_pos[None, :] > q_pos[:, None] - window)
                & (k_pos[None, :] >= 0) & (k_pos[None, :] < Skv))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqs,bsgd->bgrqd", p.astype(v_blk.dtype), v_blk,
                       preferred_element_type=jnp.float32)
        return jnp.moveaxis(o, 3, 1)                      # (B,qc,G,R,D)

    outs = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


# ------------------------------- decode ------------------------------------
def decode_attend(q, k_cache, v_cache, slot_pos, cur_pos, *,
                  window: Optional[int] = None,
                  scale: Optional[float] = None):
    """One decode step.

    q: (B,1,H,D); caches (B,Sc,G,D); slot_pos (B,Sc) absolute position per
    slot (-1 = empty); cur_pos (B,) current absolute position.
    """
    B, _, H, D = q.shape
    _, Sc, G, _ = k_cache.shape
    R = H // G
    scale = scale or D ** -0.5
    # low-precision caches (e.g. fp8) are upcast at the matmul
    k_cache = k_cache.astype(q.dtype)
    v_cache = v_cache.astype(q.dtype)
    qg = (q * scale).reshape(B, 1, G, R, D)
    s = jnp.einsum("bqgrd,bsgd->bgrqs", qg, k_cache,
                   preferred_element_type=jnp.float32)    # (B,G,R,1,Sc)
    ok = (slot_pos >= 0) & (slot_pos <= cur_pos[:, None])
    if window is not None:
        ok &= slot_pos > (cur_pos[:, None] - window)
    s = jnp.where(ok[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqs,bsgd->bgrqd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)


def attend(q, k, v, *, causal: bool, window: Optional[int], q_offset: int = 0,
           q_chunk: int = 512, kv_chunk: int = 1024):
    """Dispatch: banded path when a window makes it cheaper, else chunked."""
    Sq = q.shape[1]
    if window is not None and causal and Sq > 2 * window:
        return banded_mha(q, k, v, window=window, q_chunk=min(q_chunk, window))
    if window is not None and causal:
        # short sequence: window degenerates to causal-with-band mask; use
        # banded only if it saves work, else plain causal with window mask
        return banded_mha(q, k, v, window=window, q_chunk=min(q_chunk, Sq))
    return mha(q, k, v, causal=causal, q_offset=q_offset,
               q_chunk=q_chunk, kv_chunk=kv_chunk)

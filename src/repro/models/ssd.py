"""Mamba2 SSD (state-space duality) — chunked scan + single-step decode.

Math (per head h, state S in R^{P x N}):
    S_t = a_t * S_{t-1} + dt_t * x_t (x) B_t        a_t = exp(dt_t * A_h), A_h < 0
    y_t = C_t . S_t + D_h * x_t

Chunked form (chunk length Q, scan over chunks carrying S):
    cum_t   = cumsum(log a) within chunk (inclusive)
    y_intra = [(C_t . B_s) * exp(cum_t - cum_s) * dt_s]_{s<=t} @ x
    y_inter = exp(cum_t) * (C_t . S_in)
    S_out   = exp(cum_Q) * S_in + sum_s exp(cum_Q - cum_s) * dt_s * (x_s (x) B_s)

This module is the pure-jnp oracle shared with ``repro.kernels.ssd``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_chunk_body(x_c, dt_c, la_c, B_c, C_c, state):
    """One chunk. Shapes: x_c (B,Q,G,R,P); dt_c/la_c (B,Q,G,R);
    B_c/C_c (B,Q,G,N); state (B,G,R,P,N) fp32. Returns (y_c, new_state)."""
    cum = jnp.cumsum(la_c, axis=1)                       # (B,Q,G,R)
    total = cum[:, -1]                                   # (B,G,R)
    Q = x_c.shape[1]
    # intra-chunk (quadratic in Q)
    CB = jnp.einsum("bqgn,bsgn->bgqs", C_c, B_c,
                    preferred_element_type=jnp.float32)  # (B,G,Q,Q)
    seg = cum[:, :, None] - cum[:, None, :]              # (B,Q,S,G,R) t,s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    w = jnp.where(tri[None, :, :, None, None], jnp.exp(seg), 0.0)
    w = w * dt_c[:, None]                                # * dt_s  (B,Q,S,G,R)
    # scores[t,s] = CB[b,g,t,s] * w[b,t,s,g,r]
    y_intra = jnp.einsum("bgts,btsgr,bsgrp->btgrp", CB, w,
                         x_c.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    # inter-chunk
    y_inter = jnp.einsum("bqgn,bgrpn->bqgrp", C_c, state,
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    # state update
    decay_out = jnp.exp(total[:, None] - cum) * dt_c     # (B,Q,G,R)
    new_state = (jnp.exp(total)[..., None, None] * state
                 + jnp.einsum("bqgrp,bqgn,bqgr->bgrpn",
                              x_c.astype(jnp.float32), B_c, decay_out,
                              preferred_element_type=jnp.float32))
    return (y_intra + y_inter), new_state


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
             init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P); dt (B,S,H) [post-softplus]; A (H,) negative;
    Bm/Cm (B,S,G,N). Returns y (B,S,H,P), final state (B,H,P,N)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    la = dt * A[None, None, :]                           # (B,S',H) log decay
    xr = x.reshape(B, nc, Q, G, R, P)
    dtr = dt.reshape(B, nc, Q, G, R)
    lar = la.reshape(B, nc, Q, G, R)
    Br = Bm.reshape(B, nc, Q, G, N)
    Cr = Cm.reshape(B, nc, Q, G, N)

    if init_state is None:
        state0 = jnp.zeros((B, G, R, P, N), jnp.float32)
    else:
        state0 = init_state.reshape(B, G, R, P, N).astype(jnp.float32)

    def body(state, inp):
        xc, dtc, lac, bc, cc = inp
        y, state = ssd_chunk_body(xc, dtc, lac, bc, cc, state)
        return state, y

    state, ys = jax.lax.scan(
        body, state0,
        (jnp.moveaxis(xr, 1, 0), jnp.moveaxis(dtr, 1, 0),
         jnp.moveaxis(lar, 1, 0), jnp.moveaxis(Br, 1, 0),
         jnp.moveaxis(Cr, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), state.reshape(B, H, P, N)


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    """O(S) sequential reference (oracle for tests)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G
    state = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
             else init_state.astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp                        # (B,H,P),(B,H),(B,G,N)
        a = jnp.exp(dt_t * A[None, :])                   # (B,H)
        Bh = jnp.repeat(B_t, R, axis=1)                  # (B,H,N)
        Ch = jnp.repeat(C_t, R, axis=1)
        state = (a[..., None, None] * state
                 + (dt_t[..., None] * x_t)[..., None] * Bh[:, :, None, :])
        y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
        return state, y

    state, ys = jax.lax.scan(
        step, state,
        (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """Single decode step. state (B,H,P,N) fp32; x_t (B,H,P); dt_t (B,H);
    B_t/C_t (B,G,N). Returns (y (B,H,P), new state)."""
    H = x_t.shape[1]
    R = H // B_t.shape[1]
    a = jnp.exp(dt_t * A[None, :])
    Bh = jnp.repeat(B_t, R, axis=1)
    Ch = jnp.repeat(C_t, R, axis=1)
    state = (a[..., None, None] * state
             + (dt_t[..., None] * x_t.astype(jnp.float32))[..., None]
             * Bh[:, :, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x_t.dtype), state


def causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,C); w (cw,C); b (C,)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(cw):
        y = y + xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i]
    return (y + b).astype(x.dtype)


def causal_conv_step(conv_state, x_t, w, b):
    """conv_state (B,cw-1,C); x_t (B,C). Returns (y_t, new_state)."""
    cw = w.shape[0]
    hist = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,cw,C)
    y = jnp.einsum("bic,ic->bc", hist.astype(jnp.float32), w) + b
    return y.astype(x_t.dtype), hist[:, 1:]

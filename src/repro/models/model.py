"""Unified Model facade: init / loss / prefill / decode plus the
ShapeDtypeStruct ``input_specs`` used by the multi-pod dry-run (no device
allocation, weak-type-correct, shardable)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.distribution import sharding as shd
from repro.distribution.sharding import ParamMeta
from repro.models import transformer as tf
from repro.models import whisper as wp
from repro.models.options import RunOptions

PM = ParamMeta
WHISPER_ENC_FRAMES = 1500   # cross-attention source length for decode cells


class Model:
    def __init__(self, cfg: ArchConfig, opts: RunOptions = RunOptions()):
        self.cfg = cfg
        self.opts = opts

    # ----------------------------- params --------------------------------
    def meta(self) -> Dict[str, Any]:
        m = (wp.model_meta(self.cfg) if self.cfg.family == "encdec"
             else tf.model_meta(self.cfg))
        if self.opts.param_dtype != "float32":
            # serving-mode weights (e.g. bf16): matrices only, norms fp32
            def cast(pm):
                if len(pm.shape) >= 2 and pm.dtype == "float32":
                    return PM(pm.shape, pm.axes, pm.init,
                              self.opts.param_dtype, pm.fan_in_dims)
                return pm
            m = jax.tree.map(cast, m,
                             is_leaf=lambda x: isinstance(x, PM))
        return m

    def init(self, key):
        return shd.init_tree(self.meta(), key)

    def abstract_params(self):
        return shd.abstract_tree(self.meta())

    def param_specs(self, mesh):
        return shd.spec_tree(self.meta(), mesh, self.opts.rules())

    def param_shardings(self, mesh):
        return shd.sharding_tree(self.meta(), mesh, self.opts.rules())

    # ----------------------------- steps ---------------------------------
    def loss(self, params, batch):
        if self.cfg.family == "encdec":
            return wp.loss_fn(params, self.cfg, self.opts, batch)
        return tf.lm_loss(params, self.cfg, self.opts, batch)

    def forward_logits(self, params, batch):
        if self.cfg.family == "encdec":
            enc = wp.encode(params, self.cfg, self.opts, batch["frames"])
            return wp.decode_train(params, self.cfg, self.opts,
                                   batch["tokens"], enc)
        logits, _, _ = tf.lm_forward(params, self.cfg, self.opts,
                                     batch["tokens"], batch.get("embeds"))
        return logits

    def prefill(self, params, batch, cache_len: Optional[int] = None):
        if self.cfg.family == "encdec":
            return wp.prefill(params, self.cfg, self.opts, batch,
                              cache_len=cache_len)
        return tf.lm_prefill(params, self.cfg, self.opts, batch["tokens"],
                             batch.get("embeds"), cache_len=cache_len)

    def decode_step(self, params, cache, token):
        if self.cfg.family == "encdec":
            return wp.decode_step(params, self.cfg, self.opts, cache, token)
        return tf.lm_decode_step(params, self.cfg, self.opts, cache, token)

    # ------------------------- cache metadata ----------------------------
    def cache_len(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.window is not None and not cfg.global_layers:
            return min(seq_len, cfg.window)  # uniform SWA: ring buffer
        return seq_len

    def cache_meta(self, batch: int, seq_len: int) -> Dict[str, Any]:
        cfg, opts = self.cfg, self.opts
        L, G, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        cdt = opts.compute_dtype
        kvdt = opts.kv_cache_dtype or cdt
        Sc = self.cache_len(seq_len)

        def kv(sl):
            return PM((L, batch, sl, G, hd),
                      (None, "batch", "cache_seq", None, None), "zeros",
                      kvdt)

        def ssm_pm(di):
            s = cfg.ssm
            H = di // s.head_dim
            GN = s.n_groups * s.d_state
            cw = s.conv_width - 1
            return {
                "ssm": PM((L, batch, H, s.head_dim, s.d_state),
                          (None, "batch", "tensor", None, None), "zeros",
                          "float32"),
                "conv_x": PM((L, batch, cw, di),
                             (None, "batch", None, "tensor"), "zeros", cdt),
                "conv_b": PM((L, batch, cw, GN),
                             (None, "batch", None, "tensor"), "zeros", cdt),
                "conv_c": PM((L, batch, cw, GN),
                             (None, "batch", None, "tensor"), "zeros", cdt),
            }

        pos = PM((), (), "zeros", "int32")
        slot = PM((Sc,), (None,), "zeros", "int32")
        if cfg.family == "ssm":
            return {"layers": ssm_pm(cfg.d_inner), "pos": pos}
        if cfg.family == "hybrid":
            di = cfg.n_heads * cfg.hd
            return {"layers": {"k": kv(Sc), "v": kv(Sc), **ssm_pm(di)},
                    "pos": pos, "slot_pos": slot}
        if cfg.family == "encdec":
            H = cfg.n_heads
            xkv = PM((L, batch, WHISPER_ENC_FRAMES, H, hd),
                     (None, "batch", None, None, None), "zeros", cdt)
            return {"k": kv(Sc), "v": kv(Sc), "xk": xkv, "xv": xkv,
                    "pos": pos, "slot_pos": slot}
        return {"layers": {"k": kv(Sc), "v": kv(Sc)}, "pos": pos,
                "slot_pos": slot}

    def init_cache(self, batch: int, seq_len: int):
        return shd.init_tree(self.cache_meta(batch, seq_len),
                             jax.random.PRNGKey(0))

    # ------------------------- input specs -------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every step input (plus their
        logical axes) for the given assigned shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        cdt = jnp.dtype(self.opts.compute_dtype)

        def tok(b, s):
            return jax.ShapeDtypeStruct((b, s), i32)

        if shape.kind in ("train", "prefill"):
            if cfg.family == "encdec":
                dec = min(cfg.max_target_len, S)
                return {
                    "batch": {
                        "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cdt),
                        "tokens": tok(B, dec)},
                    "axes": {"frames": ("batch", None, None),
                             "tokens": ("batch", None)},
                }
            if cfg.frontend_tokens:
                F = cfg.frontend_tokens
                return {
                    "batch": {
                        "embeds": jax.ShapeDtypeStruct((B, F, cfg.d_model), cdt),
                        "tokens": tok(B, S - F)},
                    "axes": {"embeds": ("batch", None, None),
                             "tokens": ("batch", None)},
                }
            return {"batch": {"tokens": tok(B, S)},
                    "axes": {"tokens": ("batch", None)}}

        # decode: one new token against a cache of seq_len
        cm = self.cache_meta(B, S)
        return {
            "cache": shd.abstract_tree(cm),
            "cache_meta": cm,
            "token": jax.ShapeDtypeStruct((B,), i32),
            "token_axes": ("batch",),
        }

    def batch_shardings(self, shape: ShapeSpec, mesh):
        spec = self.input_specs(shape)
        rules = self.opts.rules()
        if shape.kind in ("train", "prefill"):
            return {
                k: shd.named(mesh, shd.spec_for(v.shape, spec["axes"][k],
                                                mesh, rules))
                for k, v in spec["batch"].items()}
        cache_sh = shd.sharding_tree(spec["cache_meta"], mesh, rules)
        tok_sh = shd.named(mesh, shd.spec_for((shape.global_batch,),
                                              spec["token_axes"], mesh, rules))
        return {"cache": cache_sh, "token": tok_sh}


def build(arch_name: str, opts: RunOptions = RunOptions(),
          reduced: bool = False) -> Model:
    from repro.configs.base import get
    cfg = get(arch_name)
    if reduced:
        cfg = cfg.reduced()
    return Model(cfg, opts)

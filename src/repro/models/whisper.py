"""Whisper-style encoder-decoder backbone. The conv/mel audio frontend is
a STUB per the assignment brief: ``input_specs()`` supplies precomputed
frame embeddings (B, S_enc, d_model).

LayerNorm + biased projections + GELU MLPs (whisper conventions),
sinusoidal positions on both sides (deviation: whisper uses learned
decoder positions capped at 448; sinusoidal keeps the 32k-cache decode
cell structurally well-defined — noted in DESIGN.md)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distribution.sharding import ParamMeta, shard
from repro.models.attention import attend, decode_attend, mha
from repro.models.layers import (embed_tokens, layer_norm, lm_logits,
                                 padded_vocab, sinusoidal_positions,
                                 softmax_xent)
from repro.models.options import RunOptions

PM = ParamMeta


def _ln_meta(d):
    return {"w": PM((d,), (None,), "ones"), "b": PM((d,), (None,), "zeros")}


def _attn_meta(cfg, prefix=""):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        prefix + "ln": _ln_meta(d),
        prefix + "wq": PM((d, H * hd), ("fsdp", "tensor")),
        prefix + "bq": PM((H * hd,), ("tensor",), "zeros"),
        prefix + "wk": PM((d, H * hd), ("fsdp", "tensor")),
        prefix + "wv": PM((d, H * hd), ("fsdp", "tensor")),
        prefix + "bv": PM((H * hd,), ("tensor",), "zeros"),
        prefix + "wo": PM((H * hd, d), ("tensor", "fsdp")),
        prefix + "bo": PM((d,), (None,), "zeros"),
    }


def _mlp_meta(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln2": _ln_meta(d),
        "w_up": PM((d, f), ("fsdp", "tensor")),
        "b_up": PM((f,), ("tensor",), "zeros"),
        "w_down": PM((f, d), ("tensor", "fsdp")),
        "b_down": PM((d,), (None,), "zeros"),
    }


def _stack(meta, L):
    def go(m):
        if isinstance(m, dict):
            return {k: go(v) for k, v in m.items()}
        return PM((L,) + m.shape, (None,) + tuple(m.axes), m.init, m.dtype,
                  tuple(x + 1 for x in m.fan_in_dims))
    return go(meta)


def model_meta(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    Vp = padded_vocab(cfg.vocab)
    enc_layer = {**_attn_meta(cfg), **_mlp_meta(cfg)}
    dec_layer = {**_attn_meta(cfg), **_attn_meta(cfg, "x_"), **_mlp_meta(cfg)}
    return {
        "embed": PM((Vp, d), ("vocab", "fsdp"), "embed"),
        "enc_layers": _stack(enc_layer, cfg.n_enc_layers),
        "dec_layers": _stack(dec_layer, cfg.n_layers),
        "enc_ln": _ln_meta(d),
        "final_ln": _ln_meta(d),
        "head": PM((d, Vp), ("fsdp", "vocab")),
    }


def _proj_qkv(p, xq, xkv, cfg, prefix=""):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    H, hd = cfg.n_heads, cfg.hd
    q = (xq @ p[prefix + "wq"] + p[prefix + "bq"]).reshape(B, Sq, H, hd)
    k = (xkv @ p[prefix + "wk"]).reshape(B, Skv, H, hd)
    v = (xkv @ p[prefix + "wv"] + p[prefix + "bv"]).reshape(B, Skv, H, hd)
    return q, k, v


def _attn(p, xq, xkv, cfg, opts, *, causal, prefix=""):
    q, k, v = _proj_qkv(p, xq, xkv, cfg, prefix)
    o = attend(q, k, v, causal=causal, window=None,
               q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk)
    B, Sq = xq.shape[:2]
    return o.reshape(B, Sq, -1) @ p[prefix + "wo"] + p[prefix + "bo"]


def _ffn(p, x, cfg, opts):
    xn = layer_norm(x, p["ln2"]["w"], p["ln2"]["b"], cfg.norm_eps)
    h = jax.nn.gelu(xn @ p["w_up"] + p["b_up"])
    h = shard(h, "batch", None, "tensor")
    return x + (h @ p["w_down"] + p["b_down"])


def encode(params, cfg: ArchConfig, opts: RunOptions, frames):
    """frames (B, S_enc, d) precomputed embeddings (frontend stub)."""
    cdt = jnp.dtype(opts.compute_dtype)
    x = frames.astype(cdt) + sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(cdt)
    x = shard(x, "batch", None, None)

    def block(lp, x):
        xn = layer_norm(x, lp["ln"]["w"], lp["ln"]["b"], cfg.norm_eps)
        x = x + _attn(lp, xn, xn, cfg, opts, causal=False)
        return _ffn(lp, x, cfg, opts)

    if opts.remat != "none":
        block = jax.checkpoint(block)

    if opts.layer_loop == "unroll":
        for li in range(cfg.n_enc_layers):
            lp = jax.tree.map(lambda a: a[li], params["enc_layers"])
            x = block(lp, x)
    else:
        x, _ = jax.lax.scan(lambda c, lp: (block(lp, c), None),
                            x, params["enc_layers"])
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"],
                      cfg.norm_eps)


def _dec_block(lp, x, enc_out, cfg, opts):
    xn = layer_norm(x, lp["ln"]["w"], lp["ln"]["b"], cfg.norm_eps)
    x = x + _attn(lp, xn, xn, cfg, opts, causal=True)
    xn = layer_norm(x, lp["x_ln"]["w"], lp["x_ln"]["b"], cfg.norm_eps)
    x = x + _attn(lp, xn, enc_out, cfg, opts, causal=False, prefix="x_")
    return _ffn(lp, x, cfg, opts)


def decode_train(params, cfg, opts, tokens, enc_out):
    cdt = jnp.dtype(opts.compute_dtype)
    x = embed_tokens(params["embed"], tokens).astype(cdt)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cdt)

    block = _dec_block
    if opts.remat != "none":
        block = jax.checkpoint(block, static_argnums=(3, 4))

    if opts.layer_loop == "unroll":
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["dec_layers"])
            x = block(lp, x, enc_out, cfg, opts)
    else:
        x, _ = jax.lax.scan(
            lambda c, lp: (block(lp, c, enc_out, cfg, opts), None),
            x, params["dec_layers"])
    x = layer_norm(x, params["final_ln"]["w"], params["final_ln"]["b"],
                   cfg.norm_eps)
    return lm_logits(x, params["head"], cfg.vocab)


def _cast(params, cdt):
    return jax.tree.map(
        lambda a: a.astype(cdt) if a.dtype == jnp.float32 and a.ndim > 1 else a,
        params)


def loss_fn(params, cfg: ArchConfig, opts: RunOptions, batch):
    params = _cast(params, jnp.dtype(opts.compute_dtype))
    enc_out = encode(params, cfg, opts, batch["frames"])
    logits = decode_train(params, cfg, opts, batch["tokens"], enc_out)
    return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], cfg.vocab)


def prefill(params, cfg: ArchConfig, opts: RunOptions, batch,
            cache_len: Optional[int] = None):
    """Encode source, prefill decoder prompt; emits self-KV + cross-KV cache."""
    params = _cast(params, jnp.dtype(opts.compute_dtype))
    cdt = jnp.dtype(opts.compute_dtype)
    enc_out = encode(params, cfg, opts, batch["frames"])
    tokens = batch["tokens"]
    B, St = tokens.shape
    x = embed_tokens(params["embed"], tokens).astype(cdt)
    x = x + sinusoidal_positions(St, cfg.d_model).astype(cdt)
    self_ks, self_vs, x_ks, x_vs = [], [], [], []
    for li in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[li], params["dec_layers"])
        xn = layer_norm(x, lp["ln"]["w"], lp["ln"]["b"], cfg.norm_eps)
        q, k, v = _proj_qkv(lp, xn, xn, cfg)
        o = attend(q, k, v, causal=True, window=None, q_chunk=opts.q_chunk,
                   kv_chunk=opts.kv_chunk)
        x = x + (o.reshape(B, St, -1) @ lp["wo"] + lp["bo"])
        self_ks.append(k), self_vs.append(v)
        xn = layer_norm(x, lp["x_ln"]["w"], lp["x_ln"]["b"], cfg.norm_eps)
        qx, kx, vx = _proj_qkv(lp, xn, enc_out, cfg, "x_")
        ox = attend(qx, kx, vx, causal=False, window=None,
                    q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk)
        x = x + (ox.reshape(B, St, -1) @ lp["x_wo"] + lp["x_bo"])
        x_ks.append(kx), x_vs.append(vx)
        x = _ffn(lp, x, cfg, opts)
    x = layer_norm(x, params["final_ln"]["w"], params["final_ln"]["b"],
                   cfg.norm_eps)
    logits = lm_logits(x[:, -1], params["head"], cfg.vocab)
    k, v = jnp.stack(self_ks), jnp.stack(self_vs)
    slot_pos = jnp.arange(St, dtype=jnp.int32)
    if cache_len is not None and cache_len > St:
        pad = cache_len - St
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        slot_pos = jnp.concatenate([slot_pos,
                                    jnp.full((pad,), -1, jnp.int32)])
    cache = {
        "k": k, "v": v,
        "xk": jnp.stack(x_ks), "xv": jnp.stack(x_vs),
        "pos": jnp.int32(St),
        "slot_pos": slot_pos,
    }
    return jnp.argmax(logits, -1).astype(jnp.int32), cache


def decode_step(params, cfg: ArchConfig, opts: RunOptions, cache, token):
    params = _cast(params, jnp.dtype(opts.compute_dtype))
    cdt = jnp.dtype(opts.compute_dtype)
    cur = cache["pos"]
    B = token.shape[0]
    Sc = cache["k"].shape[2]
    x = embed_tokens(params["embed"], token[:, None]).astype(cdt)
    # sinusoidal position at `cur`
    div = jnp.exp(jnp.arange(0, cfg.d_model, 2).astype(jnp.float32)
                  * (-jnp.log(10000.0) / cfg.d_model))
    ang = cur.astype(jnp.float32) * div
    pos_vec = jnp.zeros((cfg.d_model,), jnp.float32)
    pos_vec = pos_vec.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
    x = x + pos_vec.astype(cdt)
    slot = jnp.mod(cur, Sc)
    slot_pos = jax.lax.dynamic_update_slice(cache["slot_pos"], cur[None],
                                            (slot,))
    new_k, new_v = [], []
    for li in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[li], params["dec_layers"])
        kc, vc = cache["k"][li], cache["v"][li]
        xn = layer_norm(x, lp["ln"]["w"], lp["ln"]["b"], cfg.norm_eps)
        q, k, v = _proj_qkv(lp, xn, xn, cfg)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
        o = decode_attend(q, kc, vc, slot_pos[None, :],
                          jnp.broadcast_to(cur, (B,)))
        x = x + (o.reshape(B, 1, -1) @ lp["wo"] + lp["bo"])
        new_k.append(kc), new_v.append(vc)
        xn = layer_norm(x, lp["x_ln"]["w"], lp["x_ln"]["b"], cfg.norm_eps)
        qx = (xn @ lp["x_wq"] + lp["x_bq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        ox = mha(qx, cache["xk"][li], cache["xv"][li], causal=False,
                 q_chunk=1, kv_chunk=opts.kv_chunk)
        x = x + (ox.reshape(B, 1, -1) @ lp["x_wo"] + lp["x_bo"])
        x = _ffn(lp, x, cfg, opts)
    x = layer_norm(x, params["final_ln"]["w"], params["final_ln"]["b"],
                   cfg.norm_eps)
    logits = lm_logits(x[:, 0], params["head"], cfg.vocab)
    new_cache = {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                 "xk": cache["xk"], "xv": cache["xv"],
                 "pos": cur + 1, "slot_pos": slot_pos}
    return jnp.argmax(logits, -1).astype(jnp.int32), new_cache

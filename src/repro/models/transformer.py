"""Unified decoder stack: dense (GQA), MoE, SSM (mamba2), hybrid (hymba),
VLM (patch-embed frontend stub) — train / prefill / decode paths.

Layer params are stacked on a leading L dim and run through either
``lax.scan`` (compact HLO, fast compiles) or an unrolled python loop
(exact ``cost_analysis``; required for per-layer heterogeneity such as
hymba's 3 global-attention layers).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distribution.sharding import ParamMeta, shard, ctx
from repro.models import ssd
from repro.models.attention import apply_rope, attend, decode_attend
from repro.models.layers import (embed_tokens, lm_logits, mlp, padded_vocab,
                                 rms_norm, softmax_xent)
from repro.models.moe import moe_ffn
from repro.models.options import RunOptions

PM = ParamMeta


# ===========================================================================
# Parameter metadata
# ===========================================================================
def attn_meta(cfg: ArchConfig) -> Dict[str, PM]:
    d, H, G, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    m = {
        "ln1": PM((d,), (None,), "ones"),
        "wq": PM((d, H * hd), ("fsdp", "tensor")),
        "wk": PM((d, G * hd), ("fsdp", "tensor")),
        "wv": PM((d, G * hd), ("fsdp", "tensor")),
        "wo": PM((H * hd, d), ("tensor", "fsdp")),
    }
    if cfg.qkv_bias:
        m["bq"] = PM((H * hd,), ("tensor",), "zeros")
        m["bk"] = PM((G * hd,), ("tensor",), "zeros")
        m["bv"] = PM((G * hd,), ("tensor",), "zeros")
    return m


def mlp_meta(cfg: ArchConfig) -> Dict[str, PM]:
    d, f = cfg.d_model, cfg.d_ff
    m = {"ln2": PM((d,), (None,), "ones")}
    if cfg.mlp == "swiglu":
        m["w_gate"] = PM((d, f), ("fsdp", "tensor"))
        m["w_up"] = PM((d, f), ("fsdp", "tensor"))
    else:
        m["w_up"] = PM((d, f), ("fsdp", "tensor"))
        if cfg.mlp == "gelu":
            m["b_up"] = PM((f,), ("tensor",), "zeros")
            m["b_down"] = PM((d,), (None,), "zeros")
    m["w_down"] = PM((f, d), ("tensor", "fsdp"))
    return m


def moe_meta(cfg: ArchConfig) -> Dict[str, PM]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    return {
        "ln2": PM((d,), (None,), "ones"),
        "router": PM((d, E), ("fsdp", None)),
        "w_gate": PM((E, d, f), ("expert", "fsdp", "expert_ff"), fan_in_dims=(1,)),
        "w_up": PM((E, d, f), ("expert", "fsdp", "expert_ff"), fan_in_dims=(1,)),
        "w_down": PM((E, f, d), ("expert", "expert_ff", "fsdp"), fan_in_dims=(1,)),
    }


def ssm_meta(cfg: ArchConfig, di: Optional[int] = None,
             own_norm: bool = True) -> Dict[str, PM]:
    """Projections kept UNFUSED (wx/wz/wb/wc separate, one causal conv
    per tensor): fused projections split post-matmul leave each half
    sharded on half the mesh and force GSPMD resharding permutes — see
    EXPERIMENTS.md §Perf cell 2."""
    s = cfg.ssm
    d = cfg.d_model
    di = di or cfg.d_inner
    H = di // s.head_dim
    GN = s.n_groups * s.d_state
    m = {
        "wx": PM((d, di), ("fsdp", "tensor")),
        "wz": PM((d, di), ("fsdp", "tensor")),
        "wb": PM((d, GN), ("fsdp", "tensor")),
        "wc": PM((d, GN), ("fsdp", "tensor")),
        "wdt": PM((d, H), ("fsdp", "tensor")),
        "dt_bias": PM((H,), (None,), "dt_bias"),
        "A_log": PM((H,), (None,), "ssm_a"),
        "Dskip": PM((H,), (None,), "ones"),
        "conv_wx": PM((s.conv_width, di), (None, "tensor")),
        "conv_bx": PM((di,), ("tensor",), "zeros"),
        "conv_wb": PM((s.conv_width, GN), (None, "tensor")),
        "conv_bb": PM((GN,), ("tensor",), "zeros"),
        "conv_wc": PM((s.conv_width, GN), (None, "tensor")),
        "conv_bc": PM((GN,), ("tensor",), "zeros"),
        "gln": PM((di,), ("tensor",), "ones"),
    }
    if own_norm:
        m["ln1"] = PM((d,), (None,), "ones")
        m["wout"] = PM((di, d), ("tensor", "fsdp"))
    return m


def layer_meta(cfg: ArchConfig) -> Dict[str, PM]:
    fam = cfg.family
    if fam == "ssm":
        return ssm_meta(cfg)
    if fam == "moe":
        return {**attn_meta(cfg), **moe_meta(cfg)}
    if fam == "hybrid":
        di = cfg.n_heads * cfg.hd
        m = {**attn_meta(cfg), **mlp_meta(cfg),
             **ssm_meta(cfg, di=di, own_norm=False)}
        m["norm_attn"] = PM((di,), ("tensor",), "ones")
        m["norm_ssm"] = PM((di,), ("tensor",), "ones")
        return m
    return {**attn_meta(cfg), **mlp_meta(cfg)}          # dense / vlm


def _stack(meta: Dict[str, PM], L: int) -> Dict[str, PM]:
    return {k: PM((L,) + m.shape, (None,) + tuple(m.axes), m.init, m.dtype,
                  tuple(d + 1 for d in m.fan_in_dims))
            for k, m in meta.items()}


def model_meta(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    Vp = padded_vocab(cfg.vocab)
    meta: Dict[str, Any] = {
        "embed": PM((Vp, d), ("vocab", "fsdp"), "embed"),
        "final_ln": PM((d,), (None,), "ones"),
        "layers": _stack(layer_meta(cfg), cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        meta["head"] = PM((d, Vp), ("fsdp", "vocab"))
    return meta


# ===========================================================================
# Blocks: forward (train/prefill) and decode
# ===========================================================================
def _maybe_head_shard(t, n_heads):
    if ctx().mesh is not None and n_heads % max(ctx().axis_size(("model",)), 1) == 0:
        return shard(t, "batch", "seq", "tensor", None)
    return shard(t, "batch", "seq", None, None)


def _qkv(p, xn, cfg: ArchConfig):
    B, S, _ = xn.shape
    H, G, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = xn @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0)
    k = xn @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0)
    v = xn @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0)
    q = _maybe_head_shard(q.reshape(B, S, H, hd), H)
    k = _maybe_head_shard(k.reshape(B, S, G, hd), G)
    v = _maybe_head_shard(v.reshape(B, S, G, hd), G)
    return q, k, v


def attn_apply(p, x, cfg: ArchConfig, opts: RunOptions, *,
               window: Optional[int], pos_offset: int = 0,
               return_kv: bool = False):
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, xn, cfg)
    B, S = x.shape[:2]
    positions = pos_offset + jnp.arange(S)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attend(q, k, v, causal=True, window=window,
               q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk)
    o = o.reshape(B, S, -1) @ p["wo"]
    out = x + shard(o, "batch", "seq", None)
    if return_kv:
        return out, (k, v)
    return out


def attn_decode(p, x, cfg: ArchConfig, *, window, kc, vc, slot_pos, cur_pos):
    """x (B,1,d); kc/vc (B,Sc,G,hd); slot_pos (Sc,); cur_pos () int32."""
    B = x.shape[0]
    H, G, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    Sc = kc.shape[1]
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, xn, cfg)
    q = apply_rope(q, jnp.full((1,), 1, jnp.int32) * cur_pos, cfg.rope_theta)
    k = apply_rope(k, jnp.full((1,), 1, jnp.int32) * cur_pos, cfg.rope_theta)
    slot = jnp.mod(cur_pos, Sc)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
    kc = shard(kc, "batch", "cache_seq", None, None)
    vc = shard(vc, "batch", "cache_seq", None, None)
    o = decode_attend(q, kc, vc, slot_pos[None, :],
                      jnp.broadcast_to(cur_pos, (B,)), window=window)
    o = o.reshape(B, 1, -1) @ p["wo"]
    return x + o, kc, vc


def _ssm_pre(p, xn, cfg: ArchConfig, di: int):
    """Unfused projections (see ssm_meta docstring). Returns x_in, z
    (…,di), b, c (…,GN), dt_raw (…,H)."""
    x_in = xn @ p["wx"]
    z = xn @ p["wz"]
    b = xn @ p["wb"]
    c = xn @ p["wc"]
    dtr = xn @ p["wdt"]
    return x_in, z, b, c, dtr


def ssm_apply(p, x, cfg: ArchConfig, opts: RunOptions, *, di: int,
              own_norm: bool = True, return_state: bool = False):
    """Mamba2 block over full sequence. x (B,S,d)."""
    s = cfg.ssm
    B, S, _ = x.shape
    H, P, G, N = di // s.head_dim, s.head_dim, s.n_groups, s.d_state
    xn = rms_norm(x, p["ln1"], cfg.norm_eps) if own_norm else x
    x_raw, z, b, c, dtr = _ssm_pre(p, xn, cfg, di)
    x_in = jax.nn.silu(ssd.causal_conv(x_raw, p["conv_wx"], p["conv_bx"]))
    b_c = jax.nn.silu(ssd.causal_conv(b, p["conv_wb"], p["conv_bb"]))
    c_c = jax.nn.silu(ssd.causal_conv(c, p["conv_wc"], p["conv_bc"]))
    Bm = b_c.reshape(B, S, G, N)
    Cm = c_c.reshape(B, S, G, N)
    dt = jax.nn.softplus(dtr + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x_in.reshape(B, S, H, P)
    y, state = ssd.ssd_scan(xh, dt, A, Bm, Cm, chunk=opts.ssd_chunk)
    y = y + p["Dskip"][None, None, :, None] * xh
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["gln"], cfg.norm_eps)
    new_cache = None
    if return_state:
        cw = s.conv_width
        new_cache = {"ssm": state,
                     "conv_x": x_raw[:, -(cw - 1):],
                     "conv_b": b[:, -(cw - 1):],
                     "conv_c": c[:, -(cw - 1):]}
    if own_norm:
        y = x + shard(y @ p["wout"], "batch", "seq", None)
    return (y, new_cache) if return_state else y


def ssm_decode(p, x, cfg: ArchConfig, *, di: int, ssm_state, cache_l,
               own_norm: bool = True):
    """One step. x (B,1,d); ssm_state (B,H,P,N) fp32; cache_l holds
    conv_x (B,cw-1,di), conv_b/conv_c (B,cw-1,GN)."""
    s = cfg.ssm
    B = x.shape[0]
    H, P, G, N = di // s.head_dim, s.head_dim, s.n_groups, s.d_state
    xn = rms_norm(x, p["ln1"], cfg.norm_eps) if own_norm else x
    x_raw, z, b, c, dtr = _ssm_pre(p, xn[:, 0], cfg, di)
    xo, conv_x = ssd.causal_conv_step(cache_l["conv_x"], x_raw,
                                      p["conv_wx"], p["conv_bx"])
    bo, conv_b = ssd.causal_conv_step(cache_l["conv_b"], b,
                                      p["conv_wb"], p["conv_bb"])
    co, conv_c = ssd.causal_conv_step(cache_l["conv_c"], c,
                                      p["conv_wc"], p["conv_bc"])
    x_in = jax.nn.silu(xo)
    Bm = jax.nn.silu(bo).reshape(B, G, N)
    Cm = jax.nn.silu(co).reshape(B, G, N)
    dt = jax.nn.softplus(dtr + p["dt_bias"])            # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x_in.reshape(B, H, P)
    y, ssm_state = ssd.ssd_decode_step(ssm_state, xh, dt, A, Bm, Cm)
    y = y + p["Dskip"][None, :, None] * xh
    y = y.reshape(B, 1, di)
    y = rms_norm(y * jax.nn.silu(z[:, None]), p["gln"], cfg.norm_eps)
    if own_norm:
        y = x + y @ p["wout"]
    new_conv = {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c}
    return y, ssm_state, new_conv


def _ffn(p, x, cfg: ArchConfig, opts: RunOptions):
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_ffn(p, xn, n_experts=cfg.moe.n_experts,
                         top_k=cfg.moe.top_k,
                         capacity_factor=opts.capacity_factor,
                         group_size=opts.moe_group)
    else:
        y, aux = mlp(p, xn, cfg.mlp), jnp.float32(0)
    return x + shard(y, "batch", "seq", None), aux


def hybrid_parallel(p, x, cfg: ArchConfig, opts: RunOptions, *,
                    window: Optional[int], pos_offset: int = 0,
                    return_cache: bool = False):
    """Hymba: parallel attention + mamba heads sharing the residual input."""
    di = cfg.n_heads * cfg.hd
    B, S, _ = x.shape
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    # attention branch
    q, k, v = _qkv(p, xn, cfg)
    positions = pos_offset + jnp.arange(S)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o_attn = attend(q, k, v, causal=True, window=window,
                    q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk)
    o_attn = o_attn.reshape(B, S, di)
    # ssm branch (no own norm / out-proj)
    y_ssm, ssm_cache = ssm_apply(p, xn, cfg, opts, di=di, own_norm=False,
                                 return_state=True)
    comb = 0.5 * (rms_norm(o_attn, p["norm_attn"], cfg.norm_eps)
                  + rms_norm(y_ssm, p["norm_ssm"], cfg.norm_eps))
    x = x + shard(comb @ p["wo"], "batch", "seq", None)
    x, aux = _ffn(p, x, cfg, opts)
    if return_cache:
        return x, {"k": k, "v": v, **ssm_cache}, aux
    return x, aux


def hybrid_decode(p, x, cfg: ArchConfig, opts: RunOptions, *, window,
                  cache_l, slot_pos, cur_pos):
    di = cfg.n_heads * cfg.hd
    B = x.shape[0]
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, xn, cfg)
    q = apply_rope(q, jnp.full((1,), 1, jnp.int32) * cur_pos, cfg.rope_theta)
    k = apply_rope(k, jnp.full((1,), 1, jnp.int32) * cur_pos, cfg.rope_theta)
    Sc = cache_l["k"].shape[1]
    slot = jnp.mod(cur_pos, Sc)
    kc = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k.astype(cache_l["k"].dtype), slot, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v.astype(cache_l["v"].dtype), slot, 1)
    o_attn = decode_attend(q, kc, vc, slot_pos[None, :],
                           jnp.broadcast_to(cur_pos, (B,)), window=window)
    o_attn = o_attn.reshape(B, 1, di)
    y_ssm, s_new, conv_new = ssm_decode(p, xn, cfg, di=di, own_norm=False,
                                        ssm_state=cache_l["ssm"],
                                        cache_l=cache_l)
    comb = 0.5 * (rms_norm(o_attn, p["norm_attn"], cfg.norm_eps)
                  + rms_norm(y_ssm, p["norm_ssm"], cfg.norm_eps))
    x = x + comb @ p["wo"]
    x, _ = _ffn(p, x, cfg, opts)
    return x, {"k": kc, "v": vc, "ssm": s_new, **conv_new}


# ===========================================================================
# Layer-stack runners
# ===========================================================================
def _layer_window(cfg: ArchConfig, li: int) -> Optional[int]:
    if cfg.window is None:
        return None
    if cfg.global_layers and li in cfg.global_layers:
        return None
    return cfg.window


def _block_fwd(lp, x, cfg, opts, *, window, return_cache):
    fam = cfg.family
    if fam == "ssm":
        if return_cache:
            y, c = ssm_apply(lp, x, cfg, opts, di=cfg.d_inner, return_state=True)
            return y, c, jnp.float32(0)
        return ssm_apply(lp, x, cfg, opts, di=cfg.d_inner), None, jnp.float32(0)
    if fam == "hybrid":
        if return_cache:
            return hybrid_parallel(lp, x, cfg, opts, window=window,
                                   return_cache=True)
        y, aux = hybrid_parallel(lp, x, cfg, opts, window=window)
        return y, None, aux
    # dense / moe / vlm
    if return_cache:
        y, (k, v) = attn_apply(lp, x, cfg, opts, window=window, return_kv=True)
        y, aux = _ffn(lp, y, cfg, opts)
        return y, {"k": k, "v": v}, aux
    y = attn_apply(lp, x, cfg, opts, window=window)
    y, aux = _ffn(lp, y, cfg, opts)
    return y, None, aux


def _wrap_remat(fn, opts: RunOptions):
    if opts.remat == "none":
        return fn
    if opts.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _layer_groups(cfg: ArchConfig):
    """Contiguous runs of layers sharing the same window (for hymba's
    interleaved global/SWA layers): [(start, length, window), ...]."""
    groups = []
    start = 0
    cur = _layer_window(cfg, 0)
    for li in range(1, cfg.n_layers):
        w = _layer_window(cfg, li)
        if w != cur:
            groups.append((start, li - start, cur))
            start, cur = li, w
    groups.append((start, cfg.n_layers - start, cur))
    return groups


def run_stack(params, x, cfg: ArchConfig, opts: RunOptions, *,
              return_cache: bool = False):
    """Forward through all layers; returns (x, cache|None, aux).

    Heterogeneous stacks (per-layer window differences) run as a GROUPED
    scan: one lax.scan per contiguous same-window run — O(#groups)
    compile cost instead of O(L) full unroll."""
    L = cfg.n_layers
    heterogeneous = bool(cfg.global_layers) and cfg.window is not None
    unroll = opts.layer_loop == "unroll"

    if unroll:
        caches, aux = [], jnp.float32(0)
        for li in range(L):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            win = _layer_window(cfg, li)
            fn = _wrap_remat(
                functools.partial(_block_fwd, cfg=cfg, opts=opts, window=win,
                                  return_cache=return_cache), opts)
            x, c, a = fn(lp, x)
            aux = aux + a
            caches.append(c)
        cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
                 if return_cache else None)
        return x, cache, aux

    groups = (_layer_groups(cfg) if heterogeneous
              else [(0, L, cfg.window)])
    aux = jnp.float32(0)
    cache_parts = []
    for start, length, win in groups:
        gp = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0),
            params["layers"])
        fn = _wrap_remat(
            functools.partial(_block_fwd, cfg=cfg, opts=opts, window=win,
                              return_cache=return_cache), opts)

        def body(carry, lp):
            x, aux = carry
            x, c, a = fn(lp, x)
            return (x, aux + a), c

        (x, aux), cache = jax.lax.scan(body, (x, aux), gp)
        cache_parts.append(cache)
    if not return_cache:
        return x, None, aux
    cache = (cache_parts[0] if len(cache_parts) == 1 else
             jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                          *cache_parts))
    return x, cache, aux


def run_stack_decode(params, cache, x, cfg: ArchConfig, opts: RunOptions, *,
                     slot_pos, cur_pos):
    """One decode step through all layers. cache['layers'] stacked on L."""
    L = cfg.n_layers
    heterogeneous = bool(cfg.global_layers) and cfg.window is not None
    unroll = opts.layer_loop == "unroll"

    def one(lp, cl, li_window, x):
        fam = cfg.family
        if fam == "ssm":
            y, s_new, conv_new = ssm_decode(lp, x, cfg, di=cfg.d_inner,
                                            ssm_state=cl["ssm"],
                                            cache_l=cl)
            return y, {"ssm": s_new, **conv_new}
        if fam == "hybrid":
            return hybrid_decode(lp, x, cfg, opts, window=li_window,
                                 cache_l=cl, slot_pos=slot_pos,
                                 cur_pos=cur_pos)
        y, kc, vc = attn_decode(lp, x, cfg, window=li_window, kc=cl["k"],
                                vc=cl["v"], slot_pos=slot_pos,
                                cur_pos=cur_pos)
        y, _ = _ffn(lp, y, cfg, opts)
        return y, {"k": kc, "v": vc}

    if unroll:
        new_layers = []
        for li in range(L):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            cl = jax.tree.map(lambda a: a[li], cache["layers"])
            x, cl_new = one(lp, cl, _layer_window(cfg, li), x)
            new_layers.append(cl_new)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
        return x, new_cache

    groups = (_layer_groups(cfg) if heterogeneous
              else [(0, L, cfg.window)])
    cache_parts = []
    for start, length, win in groups:
        gp = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0),
            params["layers"])
        gc = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0),
            cache["layers"])

        def body(x, inp):
            lp, cl = inp
            x, cl_new = one(lp, cl, win, x)
            return x, cl_new

        x, new_c = jax.lax.scan(body, x, (gp, gc))
        cache_parts.append(new_c)
    new_cache = (cache_parts[0] if len(cache_parts) == 1 else
                 jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *cache_parts))
    return x, new_cache


# ===========================================================================
# Top-level LM functions
# ===========================================================================
def _head(params, cfg: ArchConfig):
    return (params["embed"].T if cfg.tie_embeddings else params["head"])


def lm_forward(params, cfg: ArchConfig, opts: RunOptions, tokens,
               embeds=None, *, return_cache: bool = False):
    """tokens (B,S) int32; embeds (B,F,d) optional frontend stub output."""
    cdt = jnp.dtype(opts.compute_dtype)
    params = jax.tree.map(lambda a: a.astype(cdt)
                          if a.dtype == jnp.float32 and a.ndim > 1 else a,
                          params)
    x = embed_tokens(params["embed"], tokens).astype(cdt)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(cdt), x], axis=1)
    x = shard(x, "batch", "seq", None)
    x, cache, aux = run_stack(params, x, cfg, opts, return_cache=return_cache)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = lm_logits(x, _head(params, cfg), cfg.vocab)
    return logits, cache, aux


def lm_loss(params, cfg: ArchConfig, opts: RunOptions, batch):
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    logits, _, aux = lm_forward(params, cfg, opts, tokens, embeds)
    F = 0 if embeds is None else embeds.shape[1]
    S = tokens.shape[1]
    # logits position F+i predicts tokens[:, i+1]
    lg = logits[:, F:F + S - 1]
    labels = tokens[:, 1:]
    loss = softmax_xent(lg, labels, cfg.vocab)
    return loss + opts.aux_loss_weight * aux


def lm_prefill(params, cfg: ArchConfig, opts: RunOptions, tokens,
               embeds=None, cache_len: Optional[int] = None):
    """Returns (last-position logits argmax token, cache pytree).

    ``cache_len`` > prompt length reserves decode head-room; unset, the
    cache is exactly the prompt (ring-buffer eviction on further steps).
    """
    logits, layer_cache, _ = lm_forward(params, cfg, opts, tokens, embeds,
                                        return_cache=True)
    S_total = logits.shape[1]
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    if opts.kv_cache_dtype:
        kvdt = jnp.dtype(opts.kv_cache_dtype)
        layer_cache = {k: (v.astype(kvdt) if k in ("k", "v") else v)
                       for k, v in layer_cache.items()}
    cache = {"layers": layer_cache, "pos": jnp.int32(S_total)}
    if cfg.family != "ssm":
        Sc = _cache_len_from(layer_cache, cfg)
        if cache_len is not None and cache_len > Sc:
            pad = cache_len - Sc
            def pad_kv(a, name):
                if name in ("k", "v"):
                    return jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                return a
            cache["layers"] = {k: pad_kv(v, k)
                               for k, v in cache["layers"].items()}
            slot_pos = jnp.concatenate(
                [jnp.arange(Sc, dtype=jnp.int32),
                 jnp.full((pad,), -1, jnp.int32)])
        else:
            slot_pos = jnp.arange(Sc, dtype=jnp.int32)
        cache["slot_pos"] = slot_pos
    return next_tok, cache


def _cache_len_from(layer_cache, cfg):
    if cfg.family == "ssm":
        return 1
    return layer_cache["k"].shape[2]


def lm_decode_step(params, cfg: ArchConfig, opts: RunOptions, cache, token):
    """token (B,) int32 -> (next_token (B,), new cache)."""
    cdt = jnp.dtype(opts.compute_dtype)
    params = jax.tree.map(lambda a: a.astype(cdt)
                          if a.dtype == jnp.float32 and a.ndim > 1 else a,
                          params)
    cur = cache["pos"]
    x = embed_tokens(params["embed"], token[:, None]).astype(cdt)
    slot_pos = cache.get("slot_pos")
    if cfg.family != "ssm" and slot_pos is not None:
        Sc = slot_pos.shape[0]
        slot = jnp.mod(cur, Sc)
        slot_pos = jax.lax.dynamic_update_slice(slot_pos, cur[None], (slot,))
    x, new_layers = run_stack_decode(params, cache, x, cfg, opts,
                                     slot_pos=slot_pos, cur_pos=cur)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = lm_logits(x[:, 0], _head(params, cfg), cfg.vocab)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    new_cache = {"layers": new_layers, "pos": cur + 1}
    if slot_pos is not None:
        new_cache["slot_pos"] = slot_pos
    return next_tok, new_cache

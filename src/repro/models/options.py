"""Runtime options (orthogonal to ArchConfig): dtypes, remat, layer-loop
mode, sharding-rule variants. These are the §Perf hillclimbing knobs."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RunOptions:
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_cache_dtype: str = ""       # "" -> compute_dtype; e.g. float8_e4m3fn
    remat: str = "full"            # none | full | dots
    layer_loop: str = "scan"       # scan | unroll (unroll => exact cost_analysis)
    q_chunk: int = 512
    kv_chunk: int = 1024
    ssd_chunk: int = 256
    microbatches: int = 1
    # MoE sharding: 'tp' = expert d_ff over model (baseline);
    # 'cap' = capacity dim over model (shards the dispatch/combine
    # einsums too — §Perf); 'ep' = expert dim over model (all-to-all)
    moe_sharding: str = "tp"
    moe_group: int = 0             # GShard token-group size (0 = whole seq)
    fsdp: bool = True              # ZeRO-3 params over 'data' (off: pure TP)
    fsdp_pods: bool = False        # shard params over ('pod','data')
    compress_pod_grads: bool = False
    seq_shard_activations: bool = False   # sequence parallelism on activations
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    def rules(self) -> dict:
        r = {"expert": (), "expert_ff": (), "moe_cap": ()}
        if self.moe_sharding == "ep":
            r["expert"] = ("model",)
        elif self.moe_sharding == "cap":
            r["moe_cap"] = ("model",)
        else:
            r["expert_ff"] = ("model",)
        if not self.fsdp:
            r["fsdp"] = ()
        elif self.fsdp_pods:
            r["fsdp"] = ("pod", "data")
        if self.seq_shard_activations:
            r["seq"] = ("model",)
        return r

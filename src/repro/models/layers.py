"""Common layers: norms, MLP variants, embeddings, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def padded_vocab(vocab: int, multiple: int = 256) -> int:
    return -(-vocab // multiple) * multiple


def mlp(params, x, kind: str):
    """kind: swiglu (w_gate,w_up,w_down) | relu2/gelu (w_up,w_down)."""
    if kind == "swiglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = jax.nn.silu(g) * u
    elif kind == "relu2":
        h = jax.nn.relu(x @ params["w_up"]) ** 2
    elif kind == "gelu":
        h = jax.nn.gelu(x @ params["w_up"] + params.get("b_up", 0))
        h = shard(h, "batch", None, "tensor")
        return h @ params["w_down"] + params.get("b_down", 0)
    else:
        raise ValueError(kind)
    h = shard(h, "batch", None, "tensor")
    return h @ params["w_down"]


def embed_tokens(table, tokens):
    """table (Vp, d) vocab-sharded; tokens (B, S) int32."""
    return jnp.take(table, tokens, axis=0)


def lm_logits(x, head, vocab: int):
    """x (..., d) @ head (d,Vp) -> (..., Vp) with padded columns masked."""
    logits = x @ head
    if logits.ndim == 3:
        logits = shard(logits, "batch", None, "vocab")
    else:
        logits = shard(logits, "batch", "vocab")
    vp = head.shape[-1]
    if vp != vocab:
        mask = jnp.arange(vp) < vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def softmax_xent(logits, labels, vocab: int):
    """Mean next-token cross entropy; logits (B,S,Vp) fp32-safe, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2).astype(jnp.float32)
                  * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe

"""Top-k MoE FFN — GShard/Switch-style capacity dispatch via one-hot
einsums (the TPU-native formulation; dispatch overhead ~S/(3*d_ff) of
expert FLOPs).

Sharding modes (logical axes; see distribution/sharding.py):
- default "TP": expert d_ff dim on 'expert_ff' -> ('model',); experts
  replicated across the mesh — always divisible.
- "EP" (perf experiment): expert dim on 'expert' -> ('model',), d_ff
  unsharded — produces all-to-all dispatch in the lowered HLO.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard


def moe_ffn(p, x, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25,
            group_size: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d). p: router (d,E), w_gate/w_up (E,d,f), w_down (E,f,d).
    Returns (y (B,S,d), aux load-balance loss).

    ``group_size`` splits long sequences into token groups before
    dispatch (GShard's group dim): dispatch-tensor size and one-hot
    einsum FLOPs scale with S_group, not S — essential at 32k+ tokens.
    """
    B0, S0, d = x.shape
    regroup = group_size and S0 > group_size and S0 % group_size == 0
    if regroup:
        x = x.reshape(B0 * (S0 // group_size), group_size, d)
    B, S, _ = x.shape
    E, K = n_experts, top_k
    C = max(1, int(-(-K * S * capacity_factor // E)))

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (B,S,E) fp32
    gate, idx = jax.lax.top_k(probs, K)                  # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e fraction_e * prob_e
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(idx[..., 0], E).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)   # (B,S,K,E)
    # dispatch position: first-choice slots counted before second-choice
    oh_flat = onehot.transpose(0, 2, 1, 3).reshape(B, K * S, E)
    pos = jnp.cumsum(oh_flat, axis=1) - 1.0              # (B,K*S,E)
    pos = pos.reshape(B, K, S, E).transpose(0, 2, 1, 3)  # (B,S,K,E)
    keep = (pos < C) & (onehot > 0)
    slot = jax.nn.one_hot(pos, C, dtype=x.dtype)         # (B,S,K,E,C)
    disp_k = jnp.where(keep[..., None], slot, 0)
    dispatch = disp_k.sum(axis=2)                        # (B,S,E,C)
    combine = (disp_k * gate[..., None, None].astype(x.dtype)).sum(axis=2)

    dispatch = shard(dispatch, "batch", None, "expert", "moe_cap")
    combine = shard(combine, "batch", None, "expert", "moe_cap")
    xe = jnp.einsum("bsec,bsd->ebcd", dispatch, x)       # (E,B,C,d)
    xe = shard(xe, "expert", "batch", "moe_cap", None)
    g = jnp.einsum("ebcd,edf->ebcf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ebcd,edf->ebcf", xe, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = shard(h, "expert", "batch", "moe_cap", "expert_ff")
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["w_down"].astype(x.dtype))
    ye = shard(ye, "expert", "batch", "moe_cap", None)
    y = jnp.einsum("bsec,ebcd->bsd", combine, ye)
    if regroup:
        y = y.reshape(B0, S0, d)
    return y, aux.astype(jnp.float32)

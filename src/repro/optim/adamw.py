"""AdamW with decoupled weight decay + global-norm clipping + schedules.
Pure-JAX (no optax dependency)."""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


def adamw_init(params):
    return {"m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale, grads), g


def adamw_update(grads, opt_state, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    c = opt_state["count"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                     opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     opt_state["v"], grads)
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return (p - lr * (step + weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": c}


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)

"""Post-compile HLO analysis for the roofline.

``compiled.cost_analysis()`` does NOT multiply while-loop bodies by their
trip counts, so with scan-over-layers it undercounts by ~n_layers. This
parser walks the optimized HLO text, attributes ops to computations,
propagates ``known_trip_count`` multipliers through the while call graph,
and reports:

- per-kind collective bytes (per-device message sizes x trip counts),
- dot FLOPs (2 * result_elems * contracted_dim x trip counts),
- scatter/gather op counts and operand+result bytes (the query-latency
  floor the ROADMAP's Pallas item targets; scatters usually sit inside
  fusion computations, so fusion call edges propagate multipliers too),
- top-level operand+result bytes (memory-traffic proxy).

Validated against cost_analysis() on unrolled lowers in tests.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT )?%(\S+) = (.*?) (\S+?)\(")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-_]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-_]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-_]+)")
# one operand, with or without its inline type: older XLA prints
# ``dot(%a, %b)``; newer prints ``dot(f32[128,64]{1,0} %a, ...)`` and
# TPU lowers add tiled layouts ``f32[128,64]{1,0:T(8,128)}``
_OPND_RE = re.compile(
    r"(?:(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+)?%([\w\.\-_]+)")


def _call_operands(line: str, opcode: str):
    """[(inline_type_or_None, operand_name), ...] of an op's call args.
    Tiled layout annotations contain parens (``{1,0:T(8,128)}``), so the
    operand list ends at the ')' that closes '<opcode>(' at depth 0 —
    not at the first ')' in the line."""
    i = line.find(opcode + "(")
    if i < 0:
        return []
    start = i + len(opcode) + 1
    depth = 1
    end = start
    for end in range(start, len(line)):
        ch = line[end]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    return [(t or None, n)
            for t, n in _OPND_RE.findall(line[start:end])]


def cost_analysis_dict(compiled) -> Dict:
    """``compiled.cost_analysis()`` normalized across jax versions:
    jax<0.5 returns a per-device list of dicts, newer returns one dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, 0
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return dims, n


def analyze(hlo_text: str) -> Dict:
    comps: Dict[str, Dict] = {}
    cur = None
    result_types: Dict[str, str] = {}

    lines = hlo_text.splitlines()
    for line in lines:
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = mc.group(1)
            comps[cur] = {"colls": defaultdict(int), "coll_counts": defaultdict(int),
                          "dot_flops": 0, "bytes": 0, "dot_bytes": 0,
                          "whiles": [], "op_count": 0,
                          "sg": defaultdict(lambda: [0, 0])}
            continue
        if cur is None or not line.strip().startswith(("%", "ROOT")):
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, rtype, opcode = mo.groups()
        result_types[name] = rtype
        c = comps[cur]
        c["op_count"] += 1
        out_bytes = _shape_bytes(rtype)
        c["bytes"] += out_bytes
        base = opcode.split(".")[0]
        for kind in COLLECTIVES:
            if base == kind or base == kind + "-start":
                c["colls"][kind] += out_bytes
                c["coll_counts"][kind] += 1
        if base in ("scatter", "select-and-scatter", "gather"):
            # io bytes = result + every operand (operand array, indices,
            # updates) — the traffic a gather/scatter actually moves
            io = out_bytes
            for t, n in _call_operands(line, opcode):
                t = t if t is not None else result_types.get(n)
                if t:
                    io += _shape_bytes(t)
            c["sg"][base][0] += 1
            c["sg"][base][1] += io
        if base == "while":
            mt = _TRIP_RE.search(line)
            mb = _BODY_RE.search(line)
            if mb:
                trip = int(mt.group(1)) if mt else 1
                c["whiles"].append((mb.group(1), trip))
        elif base in ("dot", "convolution"):
            dims, out_elems = _shape_elems(rtype)
            # contracted size from lhs operand shape + contracting dims
            ops = _call_operands(line, opcode)
            md = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            contracted = 1
            dot_io = out_bytes
            op_types = [t if t is not None else result_types.get(n)
                        for t, n in ops]
            for t in op_types:
                if t:
                    dot_io += _shape_bytes(t)
            if md and op_types and op_types[0]:
                ldims, _ = _shape_elems(op_types[0])
                if ldims:
                    for ci in md.group(1).split(","):
                        if ci:
                            contracted *= ldims[int(ci)]
            c["dot_flops"] += 2 * out_elems * contracted
            c["dot_bytes"] += dot_io
        elif base == "fusion":
            mf = _CALLS_RE.search(line)
            if mf:
                c.setdefault("fusions", []).append(mf.group(1))

    # propagate multipliers from ENTRY through whiles (memoized DFS; each
    # while body has a unique name so the call graph is a DAG)
    entry = None
    for line in lines:
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY %?([\w\.\-_]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = next(iter(comps))
    callers: Dict[str, list] = defaultdict(list)
    for cname, c in comps.items():
        for body, trip in c["whiles"]:
            callers[body].append((cname, trip))
        for callee in c.get("fusions", ()):
            callers[callee].append((cname, 1))

    memo: Dict[str, float] = {}

    def mult_of(cname: str) -> float:
        if cname == entry:
            return 1.0
        if cname in memo:
            return memo[cname]
        memo[cname] = 0.0  # cycle guard
        m = sum(mult_of(p) * t for p, t in callers.get(cname, []))
        memo[cname] = m
        return m

    mult = {cname: mult_of(cname) for cname in comps}

    colls = defaultdict(int)
    coll_counts = defaultdict(int)
    dot_flops = 0.0
    raw_bytes = 0.0
    dot_bytes = 0.0
    census: Dict[str, Dict[str, float]] = {}
    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for k, v in c["colls"].items():
            colls[k] += v * m
            coll_counts[k] += c["coll_counts"][k] * m
        dot_flops += c["dot_flops"] * m
        raw_bytes += c["bytes"] * m
        dot_bytes += c["dot_bytes"] * m
        for op, (n, io) in c["sg"].items():
            e = census.setdefault(
                op, {"count": 0, "executed": 0.0, "bytes": 0.0})
            e["count"] += n
            e["executed"] += n * m
            e["bytes"] += io * m

    # entry argument bytes (params + inputs read once)
    arg_bytes = 0
    in_entry = False
    for line in lines:
        if line.startswith("ENTRY"):
            in_entry = True
        if in_entry and re.search(r"= .* parameter\(", line):
            m = re.match(r"^\s*(?:ROOT )?%\S+ = (.*?) parameter\(", line)
            if m:
                arg_bytes += _shape_bytes(m.group(1))

    coll_total = float(sum(colls.values()))
    scatter_ops = sum(e["executed"] for op, e in census.items()
                      if op != "gather")
    gather_ops = census.get("gather", {}).get("executed", 0.0)
    scatter_bytes = sum(e["bytes"] for op, e in census.items()
                        if op != "gather")
    gather_bytes = census.get("gather", {}).get("bytes", 0.0)
    return {
        "collective_bytes": dict(colls),
        "collective_bytes_total": coll_total,
        "collective_counts": {k: float(v) for k, v in coll_counts.items()},
        "dot_flops": float(dot_flops),
        "scatter_ops": float(scatter_ops),
        "gather_ops": float(gather_ops),
        "scatter_bytes": float(scatter_bytes),
        "gather_bytes": float(gather_bytes),
        # TPU-realistic HBM traffic: matmul operands/results (elementwise
        # chains fuse into them) + collective payloads + scatter/gather
        # traffic (the query floor) + one read of args
        "bytes_touched": float(dot_bytes + coll_total + scatter_bytes
                               + gather_bytes + arg_bytes),
        "bytes_touched_raw": float(raw_bytes),
        "argument_bytes": float(arg_bytes),
        "scatter_census": census,
    }


def scatter_census(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Trip-weighted scatter/gather census of one compiled module:
    ``opcode -> {count (static), executed (x trips), bytes (io x
    trips)}``. The per-plan-shape numbers any Pallas query kernel has
    to beat (ROADMAP "Break the scatter floor")."""
    return analyze(hlo_text)["scatter_census"]


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e targets; see DESIGN.md §7)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s per link
ICI_LINKS = 4              # usable links/chip on a 2D-torus axis pair


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = bytes_per_device / HBM_BW
    t_coll = coll_bytes_per_device / (ICI_LINKS * ICI_BW)
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {"compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "dominant": dom[1],
            "bound_s": dom[0]}

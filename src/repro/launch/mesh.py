"""Production meshes. Functions (not module constants) so importing never
touches jax device state."""
from __future__ import annotations

import jax
import numpy as np


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist from jax 0.5; on older
    releases every axis is implicitly Auto, so simply omit the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_shard_mesh(n_shards: int):
    """1-D ``('shard',)`` mesh over the first ``n_shards`` devices — the
    warehouse's row-partitioning axis (`warehouse.ShardedStore`). Returns
    ``None`` when the host has fewer devices, and callers fall back to a
    stacked single-device layout with identical semantics (so sharded
    code paths stay testable on a 1-device CPU; CI forces 8 host devices
    via ``--xla_force_host_platform_device_count`` for the real thing)."""
    devs = jax.devices()
    if n_shards > len(devs):
        return None
    if n_shards == len(devs):
        return make_mesh_compat((n_shards,), ("shard",))
    # a strict subset of the host's devices: build the Mesh directly
    # (jax.make_mesh insists on consuming every device)
    return jax.sharding.Mesh(np.asarray(devs[:n_shards]), ("shard",))


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    data_axis = n // model_axis
    return make_mesh_compat((data_axis, model_axis), ("data", "model"))

"""V-ETL serving launcher: batched requests through prefill + decode with
the Skyscraper knob switcher choosing the per-segment configuration.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 16 --prompt-len 32 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get
from repro.data.tokens import SyntheticCorpus
from repro.models.model import Model
from repro.models.options import RunOptions


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch).reduced()
    opts = RunOptions(remat="none", layer_loop="scan",
                      compute_dtype="float32", q_chunk=64, kv_chunk=64)
    model = Model(cfg, opts)
    params = model.init(jax.random.PRNGKey(args.seed))
    corpus = SyntheticCorpus(cfg.vocab, args.seed)

    prefill = jax.jit(lambda p, b: model.prefill(
        p, b, cache_len=args.prompt_len + args.gen))
    decode = jax.jit(model.decode_step)

    total_tokens = 0
    t0 = time.time()
    for r0 in range(0, args.requests, args.batch):
        b = min(args.batch, args.requests - r0)
        toks = jnp.asarray(corpus.batch(b, args.prompt_len, r0))
        nxt, cache = prefill(params, {"tokens": toks})
        outs = [nxt]
        for _ in range(args.gen - 1):
            nxt, cache = decode(params, cache, nxt)
            outs.append(nxt)
        total_tokens += b * args.gen
        print(f"batch {r0 // args.batch}: generated "
              f"{np.asarray(jnp.stack(outs, 1))[0][:8]}...")
    dt = time.time() - t0
    print(f"[serve] {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU reduced config)")


if __name__ == "__main__":
    main()

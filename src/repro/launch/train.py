"""Fault-tolerant training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 200 --ckpt-dir /tmp/ckpt

- auto-resumes from the latest checkpoint (restart-after-crash);
- periodic atomic checkpoints with retention;
- optional --simulate-failure N kills the process at step N (the
  restart-loop test uses this);
- elastic: on restart the state is resharded onto whatever mesh the
  surviving devices form (see runtime/elastic.py).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as CK
from repro.configs.base import get
from repro.data.tokens import make_batch_iter
from repro.distribution import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.models.options import RunOptions
from repro.runtime.steps import (init_train_state, make_train_step,
                                 train_state_shardings)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-failure", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opts = RunOptions(remat="none", layer_loop="scan",
                      compute_dtype="float32",
                      microbatches=args.microbatches,
                      q_chunk=min(128, args.seq), kv_chunk=min(128, args.seq))
    model = Model(cfg, opts)
    mesh = make_host_mesh(args.model_axis)
    rules = opts.rules()

    with shd.use_mesh(mesh, rules):
        state_sh = train_state_shardings(model, mesh)
        start = 0
        if args.ckpt_dir and (CK.latest_step(args.ckpt_dir) is not None):
            start = CK.latest_step(args.ckpt_dir)
            state = CK.restore(args.ckpt_dir, start, mesh=mesh,
                               shardings=state_sh)
            print(f"[train] resumed from step {start}")
        else:
            state = init_train_state(model, jax.random.PRNGKey(args.seed))
            state = jax.device_put(state, state_sh)
            print("[train] fresh init")

        step_fn = jax.jit(
            make_train_step(model, peak_lr=args.lr, warmup=20,
                            total_steps=args.steps),
            in_shardings=(state_sh, None), out_shardings=(state_sh, None),
            donate_argnums=(0,))
        it = make_batch_iter(cfg, global_batch=args.batch, seq_len=args.seq,
                             seed=args.seed)
        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch = next(it)
            if args.simulate_failure and step == args.simulate_failure:
                print(f"[train] SIMULATED FAILURE at step {step}",
                      flush=True)
                os._exit(42)
            state, metrics = step_fn(state, batch)
            if (step + 1) % args.log_every == 0 or step == start:
                l = float(metrics["loss"])
                losses.append(l)
                print(f"step {step + 1:5d} loss {l:8.4f} "
                      f"gnorm {float(metrics['gnorm']):7.3f} "
                      f"({(time.time() - t0):.1f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                CK.save(args.ckpt_dir, jax.device_get(state), step=step + 1)
        if args.ckpt_dir:
            CK.save(args.ckpt_dir, jax.device_get(state), step=args.steps)
        print(f"[train] done: final loss {losses[-1] if losses else 'n/a'}")
        return losses


if __name__ == "__main__":
    main()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any other import (jax locks the device count
on first init)."""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import get, registry          # noqa: E402
from repro.configs.shapes import SHAPES, applicable, skip_reason  # noqa: E402
from repro.distribution import sharding as shd        # noqa: E402
from repro.launch import hlo_analysis as HA           # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.models.model import Model                  # noqa: E402
from repro.models.options import RunOptions           # noqa: E402
from repro.runtime.steps import (abstract_train_state,  # noqa: E402
                                 make_decode_step, make_prefill_step,
                                 make_train_step, train_state_shardings)


def lower_cell(arch_name: str, shape_name: str, mesh, opts: RunOptions,
               *, want_text: bool = False):
    cfg = get(arch_name)
    shape = SHAPES[shape_name]
    model = Model(cfg, opts)
    n_dev = mesh.devices.size
    rules = opts.rules()
    out = {"arch": arch_name, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_devices": int(n_dev),
           "opts": {k: v for k, v in dataclasses.asdict(opts).items()
                    if k in ("remat", "layer_loop", "microbatches",
                             "moe_sharding", "fsdp", "param_dtype",
                             "fsdp_pods", "capacity_factor", "q_chunk")}}

    t0 = time.time()
    with shd.use_mesh(mesh, rules):
        if shape.kind == "train":
            step = make_train_step(model)
            state = abstract_train_state(model)
            state_sh = train_state_shardings(model, mesh)
            batch_sh = model.batch_shardings(shape, mesh)
            batch = model.input_specs(shape)["batch"]
            rep = shd.named(mesh, shd.spec_for((), (), mesh))
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh,
                               {"loss": rep, "gnorm": rep, "lr": rep}),
            ).lower(state, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            p_sh = model.param_shardings(mesh)
            batch_sh = model.batch_shardings(shape, mesh)
            batch = model.input_specs(shape)["batch"]
            lowered = jax.jit(step, in_shardings=(p_sh, batch_sh)).lower(
                model.abstract_params(), batch)
        else:  # decode
            step = make_decode_step(model)
            p_sh = model.param_shardings(mesh)
            spec = model.input_specs(shape)
            bsh = model.batch_shardings(shape, mesh)
            lowered = jax.jit(
                step, in_shardings=(p_sh, bsh["cache"], bsh["token"]),
            ).lower(model.abstract_params(), spec["cache"], spec["token"])
        out["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        out["compile_s"] = round(time.time() - t0, 2)

    ca = HA.cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    out["cost_analysis"] = {"flops": ca.get("flops", 0.0),
                            "bytes": ca.get("bytes accessed", 0.0)}
    out["memory"] = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "code_bytes": getattr(ma, "generated_code_size_in_bytes", 0),
    }
    txt = compiled.as_text()
    out["hlo"] = HA.analyze(txt)
    if want_text:
        out["hlo_text"] = txt

    # roofline (per device)
    flops_dev = out["hlo"]["dot_flops"]
    bytes_dev = out["hlo"]["bytes_touched"]
    coll_dev = out["hlo"]["collective_bytes_total"]
    out["roofline"] = HA.roofline_terms(flops_dev, bytes_dev, coll_dev)

    # analytic model flops (global, fp-counted the 6ND/2ND way)
    N = cfg.param_count()
    Na = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * Na * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * Na * tokens
    else:
        mf = 2.0 * Na * shape.global_batch
    out["model_flops_global"] = mf
    out["model_flops_per_device"] = mf / n_dev
    out["useful_ratio"] = (mf / n_dev) / max(flops_dev, 1.0)
    out["params_b"] = round(N / 1e9, 3)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--layer-loop", default="scan",
                    choices=["scan", "unroll"])
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moe-shard", default="tp", choices=["tp", "cap", "ep"])
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--param-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--kv-dtype", default="",
                    choices=["", "bfloat16", "float8_e4m3fn"])
    ap.add_argument("--fsdp-pods", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    opts = RunOptions(remat=args.remat, layer_loop=args.layer_loop,
                      microbatches=args.microbatches,
                      moe_sharding=args.moe_shard,
                      moe_group=args.moe_group,
                      fsdp=not args.no_fsdp,
                      param_dtype=args.param_dtype,
                      kv_cache_dtype=args.kv_dtype,
                      fsdp_pods=args.fsdp_pods,
                      seq_shard_activations=args.seq_shard,
                      q_chunk=args.q_chunk,
                      capacity_factor=args.capacity_factor)

    archs = sorted(registry()) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r.get("arch"), r.get("shape"), r.get("mesh"), r.get("tag"))
            for r in results}

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
        for a in archs:
            cfg = get(a)
            for s in shapes:
                key = (a, s, mesh_name, args.tag)
                if key in done:
                    continue
                if not applicable(cfg, SHAPES[s]):
                    rec = {"arch": a, "shape": s, "mesh": mesh_name,
                           "tag": args.tag, "skipped": skip_reason(cfg, SHAPES[s])}
                    print(f"[skip] {a} x {s} x {mesh_name}: {rec['skipped']}")
                else:
                    print(f"[lower] {a} x {s} x {mesh_name} ...", flush=True)
                    try:
                        rec = lower_cell(a, s, mesh, opts)
                        rec["tag"] = args.tag
                        rl = rec["roofline"]
                        print(f"  ok compile={rec['compile_s']}s "
                              f"dom={rl['dominant']} "
                              f"comp={rl['compute_s']:.4f}s "
                              f"mem={rl['memory_s']:.4f}s "
                              f"coll={rl['collective_s']:.4f}s "
                              f"useful={rec['useful_ratio']:.2f}", flush=True)
                    except Exception as e:   # noqa: BLE001
                        rec = {"arch": a, "shape": s, "mesh": mesh_name,
                               "tag": args.tag, "error": str(e)[:500],
                               "trace": traceback.format_exc()[-2000:]}
                        print(f"  ERROR: {str(e)[:200]}", flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_err = sum(1 for r in results if "error" in r)
    print(f"done: {len(results)} records, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()

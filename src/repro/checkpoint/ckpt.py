"""Checkpointing + fault tolerance.

- Atomic saves (write to tmp, fsync, rename) so a crash mid-save never
  corrupts the latest checkpoint.
- Mesh-agnostic format: arrays are gathered to host numpy and stored
  flat (msgpack + compression), so restore() can reshard onto ANY mesh —
  the elastic-scaling path after node loss.
- Compression: zstd when the optional ``zstandard`` package is
  installed, stdlib zlib otherwise. Files carry a format-tagged header
  (``RSK1`` + codec byte) so either writer's checkpoints restore under
  either environment; legacy untagged zstd frames are still read.
- Retention: keep the last N checkpoints; ``latest_step`` enables
  auto-resume in launch/train.py.
"""
from __future__ import annotations

import io
import os
import re
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:          # optional dep: fall back to stdlib zlib
    zstd = None

_MAGIC = b"RSK1"
_CODEC_ZSTD = b"z"
_CODEC_ZLIB = b"d"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"   # legacy untagged files
# reserved payload key holding plain-python (msgpack-able) metadata —
# host-side structure like row counts or chunk sizes that must survive
# a restart alongside the arrays (the warehouse store uses this). Tree
# keys may not collide with it.
_META_KEY = "__meta__"


def _compress(raw: bytes) -> bytes:
    if zstd is not None:
        return _MAGIC + _CODEC_ZSTD + zstd.ZstdCompressor(level=3).compress(raw)
    return _MAGIC + _CODEC_ZLIB + zlib.compress(raw, level=6)


def _decompress(buf: bytes) -> bytes:
    if buf[:4] == _MAGIC:
        codec, body = buf[4:5], buf[5:]
        if codec == _CODEC_ZLIB:
            return zlib.decompress(body)
        if codec == _CODEC_ZSTD:
            if zstd is None:
                raise ImportError(
                    "checkpoint was written with zstd but the 'zstandard' "
                    "package is not installed (see requirements-dev.txt)")
            return zstd.ZstdDecompressor().decompress(body)
        raise ValueError(f"unknown checkpoint codec tag {codec!r}")
    if buf[:4] == _ZSTD_FRAME_MAGIC:       # pre-header checkpoints
        if zstd is None:
            raise ImportError(
                "legacy zstd checkpoint needs the 'zstandard' package")
        return zstd.ZstdDecompressor().decompress(buf)
    return zlib.decompress(buf)


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict) and node and all(
                k.startswith("#") for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def save(path: str, tree, step: Optional[int] = None, keep: int = 3,
         meta: Optional[Dict[str, Any]] = None):
    """Atomic checkpoint save; if ``step`` given, path is a directory and
    the file is ``<path>/ckpt_<step>.rsk`` with retention. ``meta`` is an
    optional dict of plain msgpack-able python values stored alongside
    the arrays (read back via ``restore(..., return_meta=True)``)."""
    if step is not None:
        os.makedirs(path, exist_ok=True)
        final = os.path.join(path, f"ckpt_{step:08d}.rsk")
    else:
        final = path
        os.makedirs(os.path.dirname(final) or ".", exist_ok=True)
    flat = _flatten(tree)
    assert _META_KEY not in flat, f"{_META_KEY!r} is a reserved tree key"
    payload = {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        payload[k] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    if meta is not None:
        payload[_META_KEY] = meta
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(raw)
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    if step is not None and keep:
        ckpts = sorted(f for f in os.listdir(path)
                       if re.fullmatch(r"ckpt_\d+\.rsk", f))
        for old in ckpts[:-keep]:
            os.remove(os.path.join(path, old))
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.fullmatch(r"ckpt_(\d+)\.rsk", f))]
    return max(steps) if steps else None


def restore(path: str, step: Optional[int] = None, *, mesh=None,
            shardings=None, return_meta: bool = False):
    """Load a checkpoint; with (mesh, shardings) the arrays are placed
    sharded (elastic reshard onto whatever mesh exists now). With
    ``return_meta=True`` returns ``(tree, meta)`` where meta is the dict
    passed to ``save`` (None for checkpoints written without one)."""
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.rsk")
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    meta = payload.pop(_META_KEY, None)
    flat = {}
    for k, v in payload.items():
        arr = np.frombuffer(v["data"], dtype=np.dtype(v["dtype"]))
        flat[k] = arr.reshape(v["shape"])
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(jnp.asarray(a), s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return (tree, meta) if return_meta else tree

"""Checkpointing + fault tolerance.

- Atomic saves (write to tmp, fsync, rename) so a crash mid-save never
  corrupts the latest checkpoint.
- Mesh-agnostic format: arrays are gathered to host numpy and stored
  flat (msgpack + zstd), so restore() can reshard onto ANY mesh — the
  elastic-scaling path after node loss.
- Retention: keep the last N checkpoints; ``latest_step`` enables
  auto-resume in launch/train.py.
"""
from __future__ import annotations

import io
import os
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard as zstd


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict) and node and all(
                k.startswith("#") for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def save(path: str, tree, step: Optional[int] = None, keep: int = 3):
    """Atomic checkpoint save; if ``step`` given, path is a directory and
    the file is ``<path>/ckpt_<step>.rsk`` with retention."""
    if step is not None:
        os.makedirs(path, exist_ok=True)
        final = os.path.join(path, f"ckpt_{step:08d}.rsk")
    else:
        final = path
        os.makedirs(os.path.dirname(final) or ".", exist_ok=True)
    flat = _flatten(tree)
    payload = {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        payload[k] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = zstd.ZstdCompressor(level=3).compress(raw)
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    if step is not None and keep:
        ckpts = sorted(f for f in os.listdir(path)
                       if re.fullmatch(r"ckpt_\d+\.rsk", f))
        for old in ckpts[:-keep]:
            os.remove(os.path.join(path, old))
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.fullmatch(r"ckpt_(\d+)\.rsk", f))]
    return max(steps) if steps else None


def restore(path: str, step: Optional[int] = None, *, mesh=None,
            shardings=None):
    """Load a checkpoint; with (mesh, shardings) the arrays are placed
    sharded (elastic reshard onto whatever mesh exists now)."""
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.rsk")
    with open(path, "rb") as f:
        raw = zstd.ZstdDecompressor().decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    flat = {}
    for k, v in payload.items():
        arr = np.frombuffer(v["data"], dtype=np.dtype(v["dtype"]))
        flat[k] = arr.reshape(v["shape"])
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(jnp.asarray(a), s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree

"""Standing queries: registered plans kept fresh AT INGEST RATE.

``store.query(plan)`` rescans every stored row — an O(rows) floor that
grows without bound while ingestion runs. But the partial/merge split
(``warehouse.query``) already reduces any aggregating plan to
fixed-shape ``{"acc", "cnt"}`` accumulators, and those are exactly
incrementally-maintainable state: fold the NEW rows' contributions into
the stored accumulators at ingest time and the plan's answer is a pure
O(result) finalize — no rescan, ever.

``StandingQueries`` is that registry:

- ``register(plan)`` splits the plan at its aggregating reducer
  (GroupBy / WindowAgg / MultiGroupBy — pure row plans and row-level
  TopK have no fixed-size incremental state and are rejected), takes a
  one-time O(rows) *backfill* partial over whatever the store already
  holds, and from then on every ingest folds the new rows in.
- The fold runs INSIDE the store's ingest kernels — the same single
  dispatch as ``ShardedStore.ingest_fused[_multi]`` / ``ingest_tick`` /
  ``append_rows`` (and the trivial 1-shard ``SegmentStore`` paths): the
  ingest kernel takes the stacked standing state as extra operands and
  returns the updated state next to the new columns. No second
  dispatch, no extra executable per query.
- Queries of the SAME plan shape batch into one vmapped fold: their
  thresholds are stacked dynamic operands ``(Q, F)`` and their state
  carries a leading query axis, padded to power-of-two buckets — so
  registering thousands of queries costs O(log Q) recompiles total and
  ZERO warm recompiles per tick (changing thresholds never recompiles,
  matching the query engine's operand-hoisting contract).
- ``subscribe(plan, predicate)`` layers change-data alerts on top: each
  poll evaluates the predicate over the plan's fixed-shape answer table
  and returns a fired-alert mask per result row, surfaced through the
  store's flight-recorder counters (``standing_refreshes``,
  ``alerts_checked``, ``alerts_fired`` — see ``obs.telemetry``).

Exactness contract (pinned by tests/test_standing_properties.py): the
fold is ``query._seg_fold`` — the segment scatter SEEDED with the
stored accumulator — so each group's fp32 addition sequence continues
exactly where the previous fold stopped. A backfill plus any
interleaving of ingest folds is therefore bit-exact with one
``_seg_partial`` over all rows in ingest order: on the single-store
path standing answers equal ``execute_ref`` bit-exactly (including
float sums); per-shard accumulators equal the rescan's per-shard
partials bit-exactly, with only the final cross-shard float-sum merge
regrouping (counts / max / min / integer-valued sums stay exact), the
same contract ``execute_sharded`` itself has. Spills never change a
standing answer: every row's exact fp32 contribution was folded when
it was INGESTED, so demoting the row to the int8 cold tier later
cannot touch the accumulators (rescans, by contrast, drift by the
quantization error).

The Pallas fused filter+group+aggregate kernel can compute the
delta-partials (``use_pallas=True`` at registration, single-store path
only — the sharded fold needs the ownership mask, which the fused
kernel cannot express): zero-scatter folds with the same ``{"acc",
"cnt"}`` convention. Its float sums accumulate tile-wise, so that path
trades the bit-exact-sum contract for tolerance (max/min/count stay
exact) — same trade the ``use_pallas`` query path documents.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.analysis.registry import example_builder, register_engine
from repro.core.switcher import register_cache_probe
from repro.kernels.warehouse_agg import CMP as _CMP
from repro.kernels.warehouse_agg import fused_segment_agg
from repro.warehouse.query import (Filter, GroupBy, MultiGroupBy, TopK,
                                   WindowAgg, _apply_nodes, _FilterRef,
                                   _pallas_spec, _resolve_use_pallas,
                                   _seg_finalize, _seg_fold, _seg_table,
                                   normalize, split_plan, to_host)


def _num_groups(node) -> int:
    if isinstance(node, GroupBy):
        return node.num_groups
    if isinstance(node, WindowAgg):
        return node.num_windows
    return math.prod(node.nums)                      # MultiGroupBy


def _bucket(n: int) -> int:
    """Power-of-two query-slot buckets (1, 2, 4, ...): the stacked
    threshold operands and state rows only change shape at bucket
    crossings, so reaching Q registered queries costs O(log Q)
    recompiles of the ingest program — then zero, warm."""
    return 1 << (n - 1).bit_length()


# ---------------------------------------------------------------------------
# the fold: new rows -> stored partials, traced inside the ingest kernels
# ---------------------------------------------------------------------------

def _fold_group(state, fvals, table, mask, n_new, *, spec, use_pallas):
    """Fold one plan-shape group's batch of new rows into its stacked
    per-query state, vmapped over the leading query axis of ``(state,
    fvals)``. ``table`` is the replicated new-rows column block,
    ``mask`` the rows this shard owns (all rows on the single-store
    path), ``n_new`` the valid prefix length (the Pallas delta path's
    row bound — prefix-valid wherever that path is allowed)."""
    pre, node, _post = split_plan(spec)

    def one(st, fv):
        if not use_pallas:
            tbl, m = _apply_nodes(table, mask, fv, pre)
            return _seg_fold(st, tbl, m, node)
        # zero-scatter delta partial via the fused kernel, then an
        # elementwise combiner fold (sum/max/min are the merge
        # algebra of _merge_partials)
        aspec = _pallas_spec(pre, node, table)
        delta = fused_segment_agg(table, n_new, fv, spec=aspec)
        if node.agg == "max":
            acc = jnp.maximum(st["acc"], delta["acc"])
        elif node.agg == "min":
            acc = jnp.minimum(st["acc"], delta["acc"])
        else:
            acc = st["acc"] + delta["acc"]
        return {"acc": acc, "cnt": st["cnt"] + delta["cnt"]}

    return jax.vmap(one)(state, fvals)


def _fold_all(sstates, sfvals, table, mask, n_new, sspecs):
    """Every registered group's fold, in registration order — called
    INSIDE the store ingest kernels (see ``warehouse.store``), so the
    refresh shares their single dispatch. ``sspecs`` is the static
    tuple of ``(plan spec, use_pallas)`` pairs aligned with the
    ``sstates`` / ``sfvals`` operand tuples."""
    return tuple(
        _fold_group(st, fv, table, mask, n_new, spec=sp, use_pallas=up)
        for st, fv, (sp, up) in zip(sstates, sfvals, sspecs))


@functools.partial(jax.jit, static_argnames=("sspec",))
def _backfill(cols, n_rows, fvals, state, *, sspec):
    """One-time O(rows) registration scan on the single-store path:
    the same fold, seeded with the fresh init state, over the store's
    live prefix — after this, ingest folds keep the state current."""
    spec, use_pallas = sspec
    cap = next(iter(cols.values())).shape[0]
    mask = jnp.arange(cap) < n_rows
    return _fold_group(state, fvals, cols, mask, n_rows, spec=spec,
                       use_pallas=use_pallas)


# (mesh, n_shards) -> jitted sharded backfill kernel; plain dict so the
# cache probe can sum executable counts (same pattern as query.py)
_SHARDED_FOLD: Dict = {}


def _sharded_fold_kernel(mesh, n_shards: int):
    kern = _SHARDED_FOLD.get((mesh, n_shards))
    if kern is not None:
        return kern

    @functools.partial(jax.jit, static_argnames=("sspec",))
    def run(cols, n_valid, fvals, state, *, sspec):
        spec, _up = sspec        # Pallas deltas are single-store only
        if mesh is None:
            def one(c, n, st):
                cap = next(iter(c.values())).shape[0]
                return _fold_group(st, fvals, c, jnp.arange(cap) < n, n,
                                   spec=spec, use_pallas=False)
            return jax.vmap(one)(cols, n_valid, state)

        def body(c, n, fv, st):
            c0 = {k: v[0] for k, v in c.items()}
            cap = next(iter(c0.values())).shape[0]
            st2 = _fold_group(jax.tree.map(lambda x: x[0], st), fv, c0,
                              jnp.arange(cap) < n[0], n[0], spec=spec,
                              use_pallas=False)
            return jax.tree.map(lambda x: x[None], st2)

        return shard_map(body, mesh=mesh,
                         in_specs=(P("shard"), P("shard"), P(),
                                   P("shard")),
                         out_specs=P("shard"), check_rep=False)(
                             cols, n_valid, fvals, state)

    _SHARDED_FOLD[(mesh, n_shards)] = run
    return run


@functools.partial(jax.jit, static_argnames=("spec", "sharded"))
def _answer_kernel(state, fvals, *, spec, sharded):
    """O(result) snapshot of a whole group: merge the per-shard
    accumulators (sum / max / min over the stacked shard axis — the
    ``_merge_partials`` algebra), finalize, and run the post-reduction
    nodes, vmapped over the query axis. Input sizes are
    ``(S, Q, groups)`` — never the stored rows — and changing
    thresholds reuses the executable."""
    _pre, node, post = split_plan(spec)

    def one(st, fv):
        acc, cnt = st["acc"], st["cnt"]
        if sharded:
            if node.agg == "max":
                acc = acc.max(axis=0)
            elif node.agg == "min":
                acc = acc.min(axis=0)
            else:
                acc = acc.sum(axis=0)
            cnt = cnt.sum(axis=0)
        out, cnt = _seg_finalize(acc, cnt, node.agg)
        table, mask = _seg_table(node, out, cnt)
        return _apply_nodes(table, mask, fv, post)

    return jax.vmap(one, in_axes=(1, 0) if sharded else (0, 0))(
        state, fvals)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclass
class Alert:
    """One subscription's poll result: ``fired`` is the fixed-shape
    per-result-row alert mask (predicate AND the row's validity), the
    same shape every tick; ``table`` the answer snapshot it was
    evaluated on (host numpy)."""
    sub: int
    name: str
    handle: int
    fired: np.ndarray
    table: Dict[str, np.ndarray]

    @property
    def n_fired(self) -> int:
        return int(self.fired.sum())


@dataclass
class _Sub:
    sid: int
    name: str
    handle: int
    predicate: Filter


@dataclass
class _Query:
    handle: int
    name: str
    plan: tuple
    spec: tuple                        # normalized plan shape (group key)
    fvals: Tuple[np.ndarray, ...]      # this query's (F,) operands
    slot: int                          # row in the group's stacked state


class _Group:
    """All registered queries of one plan SHAPE: one spec, stacked
    ``(Qb, F)`` threshold operands, stacked ``([S,] Qb, groups[, D])``
    accumulator state, one vmapped fold per ingest."""

    def __init__(self, reg: "StandingQueries", spec, use_pallas: bool):
        self.reg = reg
        self.spec = spec
        self.use_pallas = bool(use_pallas)
        _pre, self.node, _post = split_plan(spec)
        self.queries: List[_Query] = []
        self.qb = 0
        self.fvals_dev = None
        self.state = None

    @property
    def q(self) -> int:
        return len(self.queries)

    @property
    def sspec(self):
        return (self.spec, self.use_pallas)

    def _init_state(self, qb: Optional[int] = None):
        qb = self.qb if qb is None else qb
        reg, node = self.reg, self.node
        num = _num_groups(node)
        vcol = reg.host.columns[node.value]
        width = vcol.shape[(2 if reg.sharded else 1):]   # () or (D,)
        lead = (reg.host.n_shards, qb) if reg.sharded else (qb,)
        fill = {"max": -jnp.inf, "min": jnp.inf}.get(node.agg, 0.0)
        return reg._place({
            "acc": jnp.full(lead + (num,) + width, fill, jnp.float32),
            "cnt": jnp.zeros(lead + (num,), jnp.float32)})

    def _restack_fvals(self) -> None:
        """(Qb, F) stacked dynamic threshold operands; padding slots
        replicate query 0 (their state rows are never read)."""
        rows = [q.fvals for q in self.queries]
        rows += [rows[0]] * (self.qb - len(rows))
        self.fvals_dev = tuple(
            jnp.asarray(np.stack([r[i] for r in rows]))
            for i in range(4))

    def add(self, query: _Query) -> None:
        self.queries.append(query)
        if self.q > self.qb:                 # bucket crossing: grow
            old, old_qb = self.state, self.qb
            self.qb = _bucket(self.q)
            grown = self._init_state()
            if old is not None:
                # folded history is irreplaceable state (a re-backfill
                # after a spill would see dequantized rows) — copy it
                if self.reg.sharded:
                    grown = jax.tree.map(
                        lambda g, o: g.at[:, :old_qb].set(o), grown, old)
                else:
                    grown = jax.tree.map(
                        lambda g, o: g.at[:old_qb].set(o), grown, old)
            self.state = self.reg._place(grown)
        self._restack_fvals()
        self._backfill_slot(query)

    def _backfill_slot(self, query: _Query) -> None:
        """Fold the store's EXISTING rows into the new query's slot —
        a single-slot (Q=1) kernel call, so every registration reuses
        one executable regardless of the group's bucket size."""
        reg = self.reg
        src = reg._source()
        if src is None:                      # empty store: init seed
            return
        cols, n_valid = src
        fv1 = tuple(jnp.asarray(a[None]) for a in query.fvals)
        st1 = self._init_state(qb=1)
        if reg.sharded:
            kern = _sharded_fold_kernel(reg.host.mesh, reg.host.n_shards)
            bf = kern(cols, n_valid, fv1, st1, sspec=self.sspec)
            self.state = reg._place(jax.tree.map(
                lambda st, b: st.at[:, query.slot].set(b[:, 0]),
                self.state, bf))
        else:
            bf = _backfill(cols, jnp.int32(n_valid), fv1, st1,
                           sspec=self.sspec)
            self.state = jax.tree.map(
                lambda st, b: st.at[query.slot].set(b[0]),
                self.state, bf)


class StandingQueries:
    """The store-attached registry. Attach once per store::

        reg = StandingQueries(store)          # any store/tiered variant
        h = reg.register((Filter(...), GroupBy(...)))
        store.append_rows(rows)               # fold happens IN the ingest
        table, mask = reg.answer(h)           # O(result), no rescan

    Works over ``SegmentStore`` / ``ShardedStore`` and their tiered
    wrappers (``TieredStore`` / ``ShardedTieredStore`` — registration
    attaches to the hot store, whose ingest kernels do the folding;
    backfill scans the two-tier view, so registering AFTER a spill
    snapshots the cold rows at their dequantized values)."""

    def __init__(self, store):
        self.store = store
        self.host = getattr(store, "hot", store)
        assert getattr(self.host, "standing", None) is None, \
            "store already has a StandingQueries registry attached"
        self.host.standing = self
        self.sharded = hasattr(self.host, "n_shards")
        self._groups: Dict[tuple, _Group] = {}
        self._queries: Dict[int, _Query] = {}
        self._subs: Dict[int, _Sub] = {}
        self._active: List[_Group] = []
        self._next = 0

    def __len__(self) -> int:
        return len(self._queries)

    @property
    def has_subscriptions(self) -> bool:
        return bool(self._subs)

    # -- registration --------------------------------------------------
    def _validate(self, spec) -> None:
        pre, node, _post = split_plan(spec)
        if node is None or isinstance(node, TopK):
            raise ValueError(
                "standing queries need an aggregating reducer (GroupBy/"
                "WindowAgg/MultiGroupBy): pure row plans and row-level "
                "TopK have no fixed-size incremental state")
        avail = set(self.host.columns)
        for nd in pre:
            if isinstance(nd, _FilterRef):
                if nd.column not in avail:
                    raise ValueError(f"unknown column {nd.column!r}")
            else:                                        # Project
                if not set(nd.columns) <= avail:
                    raise ValueError(
                        f"unknown columns {set(nd.columns) - avail}")
                avail = set(nd.columns)
        if isinstance(node, GroupBy):
            keys = {node.key}
        elif isinstance(node, WindowAgg):
            keys = {"t"}
        else:
            keys = set(node.keys)
        missing = (keys | {node.value}) - avail
        if missing:
            raise ValueError(f"plan references unknown columns {missing}")

    def _resolve_pallas(self, flag, spec) -> bool:
        if self.sharded:
            # the sharded fold masks rows by ownership, which the fused
            # kernel's prefix-validity bound cannot express
            return False
        pre, node, _post = split_plan(spec)
        cols = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in self.host.columns.items()}
        return _resolve_use_pallas(flag, pre, node, cols)

    def register(self, plan, *, name: Optional[str] = None,
                 use_pallas=None) -> int:
        """Register ``plan`` as a standing query; returns its handle.
        One-time cost: an O(rows) backfill partial over the current
        store. Thereafter the plan's partial is maintained inside every
        ingest dispatch and ``answer(handle)`` is O(result)."""
        spec, fv_dev = normalize(plan)
        self._validate(spec)
        g = self._groups.get(spec)
        if g is None:
            g = _Group(self, spec, self._resolve_pallas(use_pallas, spec))
            self._groups[spec] = g
        handle = self._next
        self._next += 1
        q = _Query(handle, name or f"q{handle}", tuple(plan), spec,
                   tuple(np.asarray(a) for a in fv_dev), g.q)
        g.add(q)
        self._queries[handle] = q
        self.host.obs["standing_queries"] = len(self._queries)
        return handle

    def subscribe(self, plan, predicate: Filter, *,
                  name: Optional[str] = None, use_pallas=None) -> int:
        """Register ``plan`` AND a threshold alert over its answer
        table: ``predicate`` is a ``Filter`` on a result column (the
        agg value, ``count``, or a group-key column). Every ``poll()``
        evaluates it over the fixed-shape answer and returns the fired
        mask — change-data capture at O(result) per tick."""
        assert isinstance(predicate, Filter), \
            "predicate must be a Filter(...) over the answer table"
        handle = self.register(plan, name=name, use_pallas=use_pallas)
        sid = self._next
        self._next += 1
        self._subs[sid] = _Sub(sid, name or f"alert{sid}", handle,
                               predicate)
        return sid

    # -- ingest-side hooks (called by the stores) ----------------------
    def kernel_args(self):
        """(sstates, sfvals, sspecs) operand/static tuples the ingest
        kernels thread through their single dispatch."""
        self._active = [g for g in self._groups.values() if g.q]
        return (tuple(g.state for g in self._active),
                tuple(g.fvals_dev for g in self._active),
                tuple(g.sspec for g in self._active))

    def absorb(self, new_states) -> None:
        """Store the folded state an ingest kernel returned."""
        for g, st in zip(self._active, new_states):
            g.state = st
        self.host.obs["standing_refreshes"] += 1

    def _place(self, tree):
        put = getattr(self.host, "_put", None)
        return put(tree) if put is not None else tree

    def _source(self):
        """(columns, valid counts) for backfill — the store's combined
        two-tier view — or None when there is nothing to scan."""
        if self.store.n_rows == 0:
            return None
        if self.sharded:
            return self.store.shard_source()
        from repro.warehouse.query import _source as q_source
        return q_source(self.store)

    # -- answers -------------------------------------------------------
    def group_answers(self, group: _Group):
        """Stacked (Q, ...) answer tables of one whole group — ONE
        O(result) dispatch shared by every query of the shape."""
        return _answer_kernel(group.state, group.fvals_dev,
                              spec=group.spec, sharded=self.sharded)

    def answer(self, handle: int):
        """(table, mask) of one standing query — device arrays, no
        rescan (accumulator finalize + post nodes only)."""
        q = self._queries[handle]
        table, mask = self.group_answers(self._group_of(q))
        return ({k: v[q.slot] for k, v in table.items()}, mask[q.slot])

    def _group_of(self, q: _Query) -> _Group:
        return self._groups[q.spec]

    def answer_host(self, handle: int) -> Dict[str, np.ndarray]:
        """``answer`` compacted to host numpy (masked rows dropped)."""
        table, mask = self.answer(handle)
        return to_host(table, mask)

    # -- alerts --------------------------------------------------------
    def poll(self) -> List[Alert]:
        """Evaluate every subscription against its plan's CURRENT
        standing answer: one answer dispatch per plan shape, then the
        predicates host-side over the fixed-shape tables. Updates the
        flight-recorder counters (``alerts_checked``/``alerts_fired``)."""
        alerts: List[Alert] = []
        cache: Dict[int, tuple] = {}
        for sub in self._subs.values():
            q = self._queries[sub.handle]
            g = self._group_of(q)
            if id(g) not in cache:
                cache[id(g)] = self.group_answers(g)
            table, mask = cache[id(g)]
            row = {k: np.asarray(v[q.slot]) for k, v in table.items()}
            valid = np.asarray(mask[q.slot])
            col = row[sub.predicate.column]
            dt = np.float64 if np.issubdtype(col.dtype, np.integer) \
                else np.float32
            pred = np.asarray(_CMP[sub.predicate.op](
                col.astype(dt), dt(sub.predicate.value)))
            fired = valid & pred
            self.host.obs["alerts_checked"] += 1
            self.host.obs["alerts_fired"] += int(fired.sum())
            alerts.append(Alert(sub.sid, sub.name, sub.handle, fired,
                                row))
        return alerts


# ---- cache probes + static-analysis registry -------------------------------

register_cache_probe(
    "warehouse_standing",
    lambda: (_backfill._cache_size() + _answer_kernel._cache_size()
             + sum(k._cache_size() for k in _SHARDED_FOLD.values())))

register_engine("standing_backfill",
                example_builder("standing_backfill", "filter_groupby"),
                probe=lambda: _backfill._cache_size(),
                covers=("repro.warehouse.standing:_backfill",),
                probe_name="warehouse_standing")
# "_pallas" in the name keys this engine into the aggregated
# scatter_ops.query_pallas=0 bench ceiling: the fused delta path must
# stay scatter-free
register_engine("standing_backfill_pallas",
                example_builder("standing_backfill", "group_max", True),
                probe=lambda: _backfill._cache_size(),
                probe_name="warehouse_standing")
register_engine("standing_fold_sharded",
                example_builder("standing_fold_sharded"),
                probe=lambda: sum(k._cache_size()
                                  for k in _SHARDED_FOLD.values()),
                probe_name="warehouse_standing")
register_engine("standing_answer",
                example_builder("standing_answer", False),
                probe=lambda: _answer_kernel._cache_size(),
                covers=("repro.warehouse.standing:_answer_kernel",),
                probe_name="warehouse_standing")
register_engine("standing_answer_sharded",
                example_builder("standing_answer", True),
                probe=lambda: _answer_kernel._cache_size(),
                probe_name="warehouse_standing")

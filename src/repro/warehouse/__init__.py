"""V-ETL Load subsystem: device-resident columnar warehouse + compiled
query engine + hot/cold tiering (see store.py / query.py / tiers.py)."""
from repro.warehouse.query import (Filter, GroupBy, Project, TopK,
                                   WindowAgg, execute, execute_ref,
                                   to_host, windows_for)
from repro.warehouse.store import SegmentStore
from repro.warehouse.tiers import (TieredStore, load_warehouse,
                                   save_warehouse)

__all__ = [
    "SegmentStore", "TieredStore", "Filter", "Project", "GroupBy",
    "WindowAgg", "TopK", "execute", "execute_ref", "to_host",
    "windows_for", "save_warehouse", "load_warehouse",
]

"""V-ETL Load subsystem: device-resident columnar warehouse + compiled
partial/merge query engine + hot/cold tiering, single-device or
stream-hash sharded across a device mesh (see store.py / query.py /
tiers.py)."""
from repro.warehouse.query import (Filter, GroupBy, MultiGroupBy, Project,
                                   TopK, WindowAgg, execute, execute_ref,
                                   execute_sharded, to_host, windows_for)
from repro.warehouse.standing import Alert, StandingQueries
from repro.warehouse.store import SegmentStore, ShardedStore
from repro.warehouse.tiers import (ShardedTieredStore, TieredStore,
                                   load_warehouse, save_warehouse)

__all__ = [
    "SegmentStore", "ShardedStore", "TieredStore", "ShardedTieredStore",
    "StandingQueries", "Alert",
    "Filter", "Project", "GroupBy", "WindowAgg", "MultiGroupBy", "TopK",
    "execute", "execute_sharded", "execute_ref", "to_host",
    "windows_for", "save_warehouse", "load_warehouse",
]

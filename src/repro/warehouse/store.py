"""V-ETL *Load*: a device-resident columnar segment store.

The paper frames video analytics as data warehousing: Extract decodes,
Transform runs the content-adaptive UDFs, and **Load** lands every
segment's results "in an application-specific format that is easy to
query". Before this module the fused engines reduced a run to a
``RunResult`` summary and threw the per-segment outputs away.

``SegmentStore`` is append-only, chunked, and columnar: one device
array per column, grown in ``chunk_rows`` multiples so the set of array
shapes (and therefore jit executables) stays small. Columns:

    stream_id     int32   which camera/stream produced the segment
    t             int32   segment index on that stream's timeline
    category      int32   content category the switcher classified
    k             int32   knob configuration the switcher chose
    quality       f32     measured quality of the chosen config
    on_core_s     f32     on-prem work spent (core-seconds)
    cloud_core_s  f32     cloud work spent (core-seconds)
    buffer_s      f32     buffer fill after the segment (seconds)
    out           f32     fixed-width application output / embedding (D,)

Ingestion is batched and device-side: ``ingest_fused`` takes the fused
whole-run engine's *stacked* traces (``(n_w, W)`` leaves, still on
device) and writes all columns in ONE jitted dispatch — flattening,
tail-slicing, column synthesis (stream_id/t) and the scatter all live
in the same program, so nothing round-trips through the host per
segment. ``ingest_fused_multi`` does the same for the (n_w, V, W)
multi-stream traces and ``ingest_tick`` lands one row per live stream
from a serving-pool tick.

The store is a registered JAX pytree (columns are leaves; row count and
chunking are static aux), so it passes through jit/vmap and flattens
for checkpointing (see ``warehouse.tiers``).

``ShardedStore`` is the horizontal scale-out of the same layout: rows
partition by ``stream_id % n_shards`` onto a 1-D ``('shard',)`` device
mesh, columns are stacked ``(n_shards, cap, ...)`` arrays whose leading
axis is split across devices, and every ingest runs as ONE ``shard_map``
dispatch — each shard scatters exactly the rows it owns (a masked
cumulative-rank scatter; non-owned rows land out of bounds and are
dropped), so routing never gathers through the host. Queries execute
through the partial/merge engine (``warehouse.query.execute_sharded``).
With fewer devices than shards the same kernels run vmapped over the
stacked axis on one device, so all sharding semantics stay testable
anywhere.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.analysis.registry import example_builder, register_engine
from repro.core.switcher import register_cache_probe
from repro.distribution.sharding import put_row_sharded
from repro.launch.mesh import make_shard_mesh
from repro.obs.telemetry import (StoreTelemetry, store_obs_batch,
                                 store_obs_init, store_obs_tick)

# repro.warehouse.standing's fold — imported lazily (inside the ingest
# kernels, only on the sspecs != () trace path) because standing.py
# imports query.py, which transitively imports this module: by the time
# a StandingQueries registry can hand a store non-empty sspecs, the
# standing module is fully initialized.


def _fold_all(*args):
    from repro.warehouse.standing import _fold_all as fold
    return fold(*args)

SCALAR_COLUMNS = (
    ("stream_id", jnp.int32),
    ("t", jnp.int32),
    ("category", jnp.int32),
    ("k", jnp.int32),
    ("quality", jnp.float32),
    ("on_core_s", jnp.float32),
    ("cloud_core_s", jnp.float32),
    ("buffer_s", jnp.float32),
)
OUT_COLUMN = "out"

# fused-run trace key -> store column
_RUN_KEYS = (("c", "category"), ("k", "k"), ("qual", "quality"),
             ("on_s", "on_core_s"), ("cl_s", "cloud_core_s"),
             ("buffer_s", "buffer_s"))


def _empty_columns(cap: int, out_dim: int) -> Dict[str, jnp.ndarray]:
    cols = {n: jnp.zeros((cap,), dt) for n, dt in SCALAR_COLUMNS}
    cols[OUT_COLUMN] = jnp.zeros((cap, out_dim), jnp.float32)
    return cols


def _bucket_cap(need: int, chunk: int) -> int:
    """Smallest capacity from the fixed ladder ``{chunk * 2**j}`` that
    fits ``need`` rows. Growing to ladder rungs (instead of the exact
    chunk-aligned need) means EVERY store with the same chunk size
    draws its capacities from one small global set, so the kernels
    specialized on capacity (append / ingest / query) compile O(log
    rows) times over a store's whole lifetime and a warm capacity is
    never re-traced — the recompile-per-growth fix pinned by
    tests/test_standing.py."""
    units = max(1, -(-need // chunk))
    return chunk * (1 << (units - 1).bit_length())


def _standing_args(store):
    """The attached ``StandingQueries`` registry's ingest operands
    ``(sstates, sfvals, sspecs)`` — empty tuples (the kernels' no-op
    defaults, tracing the exact pre-standing programs) when no registry
    or no registered queries."""
    reg = store.standing
    if reg is None or not len(reg):
        return (), (), ()
    return reg.kernel_args()


def _put_all(cols, upd, offset):
    """Write every column's update block at row ``offset`` (dynamic)."""
    def put(dst, src):
        idx = (offset,) + (0,) * (src.ndim - 1)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)
    return {k: put(cols[k], upd[k]) for k in cols}


_scatter = jax.jit(_put_all)


def _write_and_fold(cols, upd, offset, sstates, sfvals, sspecs):
    """Scatter the update block AND fold it into the standing-query
    accumulators — the shared tail of every single-store ingest kernel,
    so registered answers refresh inside the SAME dispatch that lands
    the rows (see ``warehouse.standing``). With no registered queries
    (``sspecs=()``, the static default) this traces the exact
    pre-standing program and keeps the old single-value return."""
    new = _put_all(cols, upd, offset)
    if not sspecs:
        return new
    # fold what a rescan would READ: the update block cast to the
    # stored column dtypes (the standing exactness contract)
    cast = {k: v.astype(cols[k].dtype) for k, v in upd.items()}
    n = upd["t"].shape[0]
    states = _fold_all(sstates, sfvals, cast, jnp.ones((n,), bool),
                       jnp.int32(n), sspecs)
    return new, states


@functools.partial(jax.jit, static_argnames=("sspecs",))
def _scatter_fold(cols, upd, offset, sstates, sfvals, *, sspecs):
    """``append_rows`` + standing refresh in one dispatch (the plain
    ``_scatter`` stays the no-registry fast path)."""
    return _write_and_fold(cols, upd, offset, sstates, sfvals, sspecs)


@functools.partial(jax.jit, static_argnames=("T", "sspecs"))
def _ingest_fused(cols, traces, out_vecs, stream_id, t0, offset,
                  sstates=(), sfvals=(), *, T, sspecs=()):
    """One device op: flatten the fused engine's stacked (n_w, W) traces,
    drop the tail padding, synthesize stream_id/t, scatter all columns
    (folding standing-query partials in the same program)."""
    upd = {dst: traces[src].reshape(-1)[:T] for src, dst in _RUN_KEYS}
    upd["stream_id"] = jnp.full((T,), stream_id, jnp.int32)
    upd["t"] = t0 + jnp.arange(T, dtype=jnp.int32)
    upd[OUT_COLUMN] = out_vecs
    return _write_and_fold(cols, upd, offset, sstates, sfvals, sspecs)


@functools.partial(jax.jit, static_argnames=("T", "sspecs"))
def _ingest_fused_multi(cols, traces, out_vecs, stream_base, t0, offset,
                        sstates=(), sfvals=(), *, T, sspecs=()):
    """Multi-stream ingest: traces have (n_w, V, W) leaves; rows land
    stream-major ((stream 0 t=0..T-1), (stream 1 ...), ...)."""
    V = out_vecs.shape[0]

    def flat(x):                                  # (n_w, V, W) -> (V*T,)
        return jnp.swapaxes(x, 0, 1).reshape(V, -1)[:, :T].reshape(-1)

    upd = {dst: flat(traces[src]) for src, dst in _RUN_KEYS}
    upd["stream_id"] = (stream_base
                        + jnp.repeat(jnp.arange(V, dtype=jnp.int32), T))
    upd["t"] = t0 + jnp.tile(jnp.arange(T, dtype=jnp.int32), V)
    upd[OUT_COLUMN] = out_vecs.reshape(V * T, -1)
    return _write_and_fold(cols, upd, offset, sstates, sfvals, sspecs)


@functools.partial(jax.jit, static_argnames=("sspecs",))
def _ingest_tick(cols, traces, quality, out_vecs, t, offset,
                 sstates=(), sfvals=(), *, sspecs=()):
    """One serving-pool tick: V rows (one per live stream)."""
    V = quality.shape[0]
    upd = {dst: traces[src] for src, dst in _RUN_KEYS}
    upd["quality"] = quality          # measured by the user's Transform
    upd["stream_id"] = jnp.arange(V, dtype=jnp.int32)
    upd["t"] = jnp.full((V,), t, jnp.int32)
    upd[OUT_COLUMN] = out_vecs
    return _write_and_fold(cols, upd, offset, sstates, sfvals, sspecs)


@functools.partial(jax.jit, static_argnames=("sspecs",))
def _ingest_tick_masked(cols, traces, quality, out_vecs, t, offset,
                        stream_ids, valid, sstates=(), sfvals=(), *,
                        sspecs=()):
    """Elastic-pool tick: the slot axis carries REAL stream ids and an
    ``active`` mask (retired/empty slots). Active rows compact to
    consecutive positions at ``offset`` via the same masked-rank
    scatter the sharded router uses (inactive rows index past the
    capacity and drop), and only active rows fold into the standing
    accumulators — all fixed-shape, one executable per capacity."""
    V = quality.shape[0]
    upd = {dst: traces[src] for src, dst in _RUN_KEYS}
    upd["quality"] = quality
    upd["stream_id"] = stream_ids.astype(jnp.int32)
    upd["t"] = jnp.full((V,), t, jnp.int32)
    upd[OUT_COLUMN] = out_vecs
    keep = jnp.asarray(valid, bool)
    cap = next(iter(cols.values())).shape[0]
    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
    idx = jnp.where(keep, offset + rank, cap)
    new = {k: cols[k].at[idx].set(upd[k].astype(cols[k].dtype),
                                  mode="drop") for k in cols}
    if not sspecs:
        return new
    cast = {k: v.astype(cols[k].dtype) for k, v in upd.items()}
    states = _fold_all(sstates, sfvals, cast, keep, jnp.int32(V), sspecs)
    return new, states


class SegmentStore:
    """Append-only columnar store for per-segment V-ETL results."""

    def __init__(self, out_dim: int, chunk_rows: int = 8192):
        assert out_dim >= 1 and chunk_rows >= 1
        self.out_dim = int(out_dim)
        self.chunk_rows = int(chunk_rows)
        self.n_rows = 0
        self.t_max = -1
        self.columns = _empty_columns(0, out_dim)
        # host-side observability counters (see ``telemetry()``) —
        # deliberately NOT pytree aux: they vary per instance, and
        # hashable aux must stay stable or every jit call recompiles
        self.obs = store_obs_init()
        # StandingQueries registry (attached by its constructor)
        self.standing = None

    # -- capacity ------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.columns["t"].shape[0]

    def _reserve(self, n_new: int) -> None:
        need = self.n_rows + n_new
        if need <= self.capacity:
            return
        cap = _bucket_cap(need, self.chunk_rows)
        grown = _empty_columns(cap, self.out_dim)
        if self.n_rows:
            grown = {k: jax.lax.dynamic_update_slice(
                grown[k], self.columns[k], (0,) * grown[k].ndim)
                for k in grown}
        self.columns = grown

    # -- ingestion -----------------------------------------------------
    def ingest_fused(self, traces, out_vecs, *, stream_id: int = 0,
                     t0: int = 0) -> int:
        """Land a full ``run_skyscraper_fused`` run: ``traces`` is the
        engine's stacked outs dict ((n_w, W) device leaves), ``out_vecs``
        the (T, D) per-segment output/embedding block (e.g. the measured
        quality vectors). Returns the number of rows appended."""
        T = int(out_vecs.shape[0])
        assert out_vecs.ndim == 2 and out_vecs.shape[1] == self.out_dim, \
            f"out_vecs must be (T, {self.out_dim})"
        self._reserve(T)
        sub = {src: traces[src] for src, _ in _RUN_KEYS}
        sstates, sfvals, sspecs = _standing_args(self)
        res = _ingest_fused(
            self.columns, sub, jnp.asarray(out_vecs, jnp.float32),
            jnp.int32(stream_id), jnp.int32(t0), jnp.int32(self.n_rows),
            sstates, sfvals, T=T, sspecs=sspecs)
        if sspecs:
            self.columns, states = res
            self.standing.absorb(states)
        else:
            self.columns = res
        self.n_rows += T
        self.t_max = max(self.t_max, t0 + T - 1)
        store_obs_batch(self.obs, 1, T)
        return T

    def ingest_fused_multi(self, traces, out_vecs, *, stream_base: int = 0,
                           t0: int = 0) -> int:
        """Land a full ``run_skyscraper_multi`` run: traces have
        (n_w, V, W) device leaves, ``out_vecs`` is (V, T, D)."""
        V, T = int(out_vecs.shape[0]), int(out_vecs.shape[1])
        assert out_vecs.ndim == 3 and out_vecs.shape[2] == self.out_dim
        self._reserve(V * T)
        sub = {src: traces[src] for src, _ in _RUN_KEYS}
        sstates, sfvals, sspecs = _standing_args(self)
        res = _ingest_fused_multi(
            self.columns, sub, jnp.asarray(out_vecs, jnp.float32),
            jnp.int32(stream_base), jnp.int32(t0), jnp.int32(self.n_rows),
            sstates, sfvals, T=T, sspecs=sspecs)
        if sspecs:
            self.columns, states = res
            self.standing.absorb(states)
        else:
            self.columns = res
        self.n_rows += V * T
        self.t_max = max(self.t_max, t0 + T - 1)
        store_obs_batch(self.obs, V, T)
        return V * T

    def ingest_tick(self, traces, *, quality, out_vecs, t: int,
                    stream_ids=None, valid=None) -> int:
        """Land one serving-pool tick: traces have (V,) device leaves
        (a ``switch_step_multi`` outs dict); ``quality`` (V,) is the
        measured quality reported by the user's Transform.

        The elastic pool passes ``stream_ids`` (V,) — the REAL stream
        id behind each slot — and ``valid`` (V,) host bool: inactive
        slots land no row (the masked kernel compacts active rows to
        consecutive positions). Defaults keep the fixed-pool contract:
        slot v IS stream v, every slot lands."""
        V = int(out_vecs.shape[0])
        assert out_vecs.ndim == 2 and out_vecs.shape[1] == self.out_dim
        keep = None if valid is None else np.asarray(valid, bool)
        n_new = V if keep is None else int(keep.sum())
        self._reserve(n_new)
        sub = {src: traces[src] for src, _ in _RUN_KEYS}
        sstates, sfvals, sspecs = _standing_args(self)
        if stream_ids is None and keep is None:
            res = _ingest_tick(
                self.columns, sub, jnp.asarray(quality, jnp.float32),
                jnp.asarray(out_vecs, jnp.float32), jnp.int32(t),
                jnp.int32(self.n_rows), sstates, sfvals, sspecs=sspecs)
        else:
            ids = (np.arange(V) if stream_ids is None
                   else np.asarray(stream_ids))
            res = _ingest_tick_masked(
                self.columns, sub, jnp.asarray(quality, jnp.float32),
                jnp.asarray(out_vecs, jnp.float32), jnp.int32(t),
                jnp.int32(self.n_rows), jnp.asarray(ids, jnp.int32),
                jnp.asarray(np.ones(V, bool) if keep is None else keep),
                sstates, sfvals, sspecs=sspecs)
        if sspecs:
            self.columns, states = res
            self.standing.absorb(states)
        else:
            self.columns = res
        self.n_rows += n_new
        if n_new:
            self.t_max = max(self.t_max, t)
        store_obs_tick(self.obs, n_new)
        return n_new

    def append_rows(self, rows: Dict[str, jnp.ndarray]) -> int:
        """Generic batched append: ``rows`` maps every column name to an
        (n,) array (``out`` to (n, D)). Host-facing convenience for
        tests and manual loads."""
        n = int(np.shape(rows["t"])[0])
        assert set(rows) == set(self.columns), \
            f"need exactly columns {sorted(self.columns)}"
        self._reserve(n)
        upd = {k: jnp.asarray(v) for k, v in rows.items()}
        sstates, sfvals, sspecs = _standing_args(self)
        if sspecs:
            self.columns, states = _scatter_fold(
                self.columns, upd, jnp.int32(self.n_rows), sstates,
                sfvals, sspecs=sspecs)
            self.standing.absorb(states)
        else:
            self.columns = _scatter(self.columns, upd,
                                    jnp.int32(self.n_rows))
        self.n_rows += n
        self.t_max = max(self.t_max, int(np.max(np.asarray(rows["t"]))))
        store_obs_tick(self.obs, n)
        return n

    # -- reading -------------------------------------------------------
    def query(self, plan, **kw):
        """Run a compiled query plan over the live rows (see
        ``warehouse.query``; ``use_pallas=`` selects the aggregation
        kernel)."""
        from repro.warehouse import query as Q
        self.obs["query_dispatches"] += 1
        return Q.execute(self, plan, **kw)

    def telemetry(self) -> StoreTelemetry:
        """Warehouse flight recorder: row counts, ingest/query dispatch
        counts, and ingest-to-queryable lag — all from host metadata,
        zero device reads. Counters are per live instance (a store
        rebuilt through pytree unflatten starts fresh)."""
        return StoreTelemetry(rows_by_shard=np.asarray([self.n_rows]),
                              **self.obs)

    def host_rows(self) -> Dict[str, np.ndarray]:
        """All live rows as host numpy (an explicit full transfer — for
        tests, references, and exports; the query path never needs it).
        """
        return {k: np.asarray(v)[: self.n_rows]
                for k, v in self.columns.items()}

    def __len__(self) -> int:
        return self.n_rows

    def __repr__(self) -> str:
        return (f"SegmentStore(rows={self.n_rows}, cap={self.capacity}, "
                f"out_dim={self.out_dim}, chunk={self.chunk_rows})")


def _store_flatten(s: SegmentStore):
    keys = tuple(sorted(s.columns))
    return (tuple(s.columns[k] for k in keys),
            (keys, s.out_dim, s.chunk_rows, s.n_rows, s.t_max))


def _store_unflatten(aux, children) -> SegmentStore:
    keys, out_dim, chunk_rows, n_rows, t_max = aux
    s = SegmentStore.__new__(SegmentStore)
    s.out_dim, s.chunk_rows = out_dim, chunk_rows
    s.n_rows, s.t_max = n_rows, t_max
    s.columns = dict(zip(keys, children))
    # fresh counters: mutable host state can't ride through aux (it
    # must stay hashable and stable), so telemetry isn't checkpointed;
    # same for standing registries (re-register after a reload)
    s.obs = store_obs_init()
    s.standing = None
    return s


jax.tree_util.register_pytree_node(SegmentStore, _store_flatten,
                                   _store_unflatten)

register_cache_probe(
    "warehouse_append",
    lambda: (_scatter._cache_size() + _scatter_fold._cache_size()
             + _ingest_fused._cache_size()
             + _ingest_fused_multi._cache_size()
             + _ingest_tick._cache_size()))
register_engine("warehouse_scatter", example_builder("store_scatter"),
                probe=lambda: _scatter._cache_size(),
                covers=("repro.warehouse.store:_scatter",),
                probe_name="warehouse_append")
# ingest + standing-query refresh fused into ONE executable: the same
# append/tick kernels with the stacked standing state threaded through
register_engine("warehouse_scatter_standing",
                example_builder("store_scatter_standing"),
                probe=lambda: _scatter_fold._cache_size(),
                covers=("repro.warehouse.store:_scatter_fold",),
                probe_name="warehouse_append")
register_engine("warehouse_ingest_tick_standing",
                example_builder("store_ingest_tick_standing"),
                probe=lambda: _ingest_tick._cache_size(),
                probe_name="warehouse_append")
register_engine("warehouse_ingest_fused",
                example_builder("store_ingest_fused"),
                probe=lambda: _ingest_fused._cache_size(),
                covers=("repro.warehouse.store:_ingest_fused",),
                probe_name="warehouse_append")
register_engine("warehouse_ingest_fused_multi",
                example_builder("store_ingest_fused_multi"),
                probe=lambda: _ingest_fused_multi._cache_size(),
                covers=("repro.warehouse.store:_ingest_fused_multi",),
                probe_name="warehouse_append")
register_engine("warehouse_ingest_tick",
                example_builder("store_ingest_tick"),
                probe=lambda: _ingest_tick._cache_size(),
                covers=("repro.warehouse.store:_ingest_tick",),
                probe_name="warehouse_append")
register_cache_probe("warehouse_tick_masked",
                     lambda: _ingest_tick_masked._cache_size())
register_engine("warehouse_ingest_tick_masked",
                example_builder("store_ingest_tick_masked"),
                probe=lambda: _ingest_tick_masked._cache_size(),
                covers=("repro.warehouse.store:_ingest_tick_masked",),
                probe_name="warehouse_tick_masked")


# ---------------------------------------------------------------------------
# sharded store: stream-hash partitioned rows across a device mesh
# ---------------------------------------------------------------------------

def _route_write(cols, n_rows, upd, owner, shard_id):
    """ONE shard's slice of a routed append. Rows whose ``owner`` equals
    ``shard_id`` scatter at consecutive positions starting at this
    shard's ``n_rows`` offset (rank = exclusive cumsum of the ownership
    mask); every other row's index points past the capacity and the
    scatter drops it — so all shards run the identical fixed-shape
    program on the identical replicated update block, and each keeps
    exactly its own rows. No host gathers, no data-dependent shapes."""
    cap = next(iter(cols.values())).shape[0]
    own = owner == shard_id
    rank = jnp.cumsum(own.astype(jnp.int32)) - 1
    idx = jnp.where(own, n_rows + rank, cap)
    new = {k: cols[k].at[idx].set(upd[k].astype(cols[k].dtype),
                                  mode="drop") for k in cols}
    return new, n_rows + own.sum(dtype=jnp.int32)


def _append_traced(cols, n_rows, upd, mesh, n_shards, sstates=(),
                   sfvals=(), sspecs=(), valid=None):
    """Routed append over all shards: shard_map on the mesh (one
    collective-free dispatch, each device writes its own block) or the
    vmapped stacked fallback. ``upd`` maps every column to an (n, ...)
    replicated update block; ownership is ``stream_id % n_shards``.

    With standing queries registered (``sspecs`` non-empty) each shard
    ALSO folds the rows it owns into its slice of the stacked standing
    state — the ownership mask doubles as the fold mask, so a row's
    contribution lands exactly once, on the shard that stores the row,
    inside this same dispatch. The return grows a third element (the
    folded state tuple); the empty-``sspecs`` trace is unchanged.

    ``valid`` (n,) bool, when given, marks rows that must NOT land
    anywhere (the elastic pool's retired/empty slots): their owner is
    forced past the last shard id, so the routed scatter drops them and
    the standing folds never see them — the default ``None`` traces the
    exact pre-elastic program."""
    owner = upd["stream_id"].astype(jnp.int32) % n_shards
    if valid is not None:
        owner = jnp.where(jnp.asarray(valid, bool), owner,
                          jnp.int32(n_shards))
    n = owner.shape[0]
    if mesh is None:
        sids = jnp.arange(n_shards, dtype=jnp.int32)
        if not sspecs:
            return jax.vmap(lambda c, nr, s: _route_write(
                c, nr, upd, owner, s))(cols, n_rows, sids)

        def one(c, nr, s, sts):
            new, nn = _route_write(c, nr, upd, owner, s)
            cast = {k: upd[k].astype(c[k].dtype) for k in upd}
            states = _fold_all(sts, sfvals, cast, owner == s,
                               jnp.int32(n), sspecs)
            return new, nn, states

        return jax.vmap(one)(cols, n_rows, sids, sstates)

    def body(c, nr, u, ow, sts, fvs):
        c0 = {k: v[0] for k, v in c.items()}
        sid = jax.lax.axis_index("shard")
        new, n2 = _route_write(c0, nr[0], u, ow, sid)
        stacked = {k: v[None] for k, v in new.items()}
        if not sspecs:
            return stacked, n2[None]
        cast = {k: u[k].astype(c0[k].dtype) for k in u}
        states = _fold_all(jax.tree.map(lambda x: x[0], sts), fvs,
                           cast, ow == sid, jnp.int32(n), sspecs)
        return stacked, n2[None], jax.tree.map(lambda x: x[None], states)

    out_specs = (P("shard"), P("shard")) if not sspecs \
        else (P("shard"), P("shard"), P("shard"))
    return shard_map(body, mesh=mesh,
                     in_specs=(P("shard"), P("shard"), P(), P(),
                               P("shard"), P()),
                     out_specs=out_specs,
                     check_rep=False)(cols, n_rows, upd, owner, sstates,
                                      sfvals)


# (kind, mesh, n_shards) -> jitted kernel; plain dict so the cache probe
# can sum executable counts
_SHARD_KERNELS: Dict = {}


def _shard_kernel(kind: str, mesh, n_shards: int):
    key = (kind, mesh, n_shards)
    kern = _SHARD_KERNELS.get(key)
    if kern is not None:
        return kern
    if kind == "append":
        @functools.partial(jax.jit, static_argnames=("sspecs",))
        def kern(cols, n_rows, upd, sstates=(), sfvals=(), *, sspecs=()):
            return _append_traced(cols, n_rows, upd, mesh, n_shards,
                                  sstates, sfvals, sspecs)
    elif kind == "fused_multi":
        @functools.partial(jax.jit, static_argnames=("T", "sspecs"))
        def kern(cols, n_rows, traces, out_vecs, stream_base, t0,
                 sstates=(), sfvals=(), *, T, sspecs=()):
            V = out_vecs.shape[0]

            def flat(x):                      # (n_w, V, W) -> (V*T,)
                return jnp.swapaxes(x, 0, 1).reshape(V, -1)[:, :T] \
                    .reshape(-1)

            upd = {dst: flat(traces[src]) for src, dst in _RUN_KEYS}
            upd["stream_id"] = (stream_base
                                + jnp.repeat(jnp.arange(V, dtype=jnp.int32),
                                             T))
            upd["t"] = t0 + jnp.tile(jnp.arange(T, dtype=jnp.int32), V)
            upd[OUT_COLUMN] = out_vecs.reshape(V * T, -1)
            return _append_traced(cols, n_rows, upd, mesh, n_shards,
                                  sstates, sfvals, sspecs)
    elif kind == "tick":
        @functools.partial(jax.jit, static_argnames=("sspecs",))
        def kern(cols, n_rows, traces, quality, out_vecs, t,
                 sstates=(), sfvals=(), *, sspecs=()):
            V = quality.shape[0]
            upd = {dst: traces[src] for src, dst in _RUN_KEYS}
            upd["quality"] = quality
            upd["stream_id"] = jnp.arange(V, dtype=jnp.int32)
            upd["t"] = jnp.full((V,), t, jnp.int32)
            upd[OUT_COLUMN] = out_vecs
            return _append_traced(cols, n_rows, upd, mesh, n_shards,
                                  sstates, sfvals, sspecs)
    elif kind == "tick_ids":
        @functools.partial(jax.jit, static_argnames=("sspecs",))
        def kern(cols, n_rows, traces, quality, out_vecs, t, stream_ids,
                 valid, sstates=(), sfvals=(), *, sspecs=()):
            V = quality.shape[0]
            upd = {dst: traces[src] for src, dst in _RUN_KEYS}
            upd["quality"] = quality
            upd["stream_id"] = stream_ids.astype(jnp.int32)
            upd["t"] = jnp.full((V,), t, jnp.int32)
            upd[OUT_COLUMN] = out_vecs
            return _append_traced(cols, n_rows, upd, mesh, n_shards,
                                  sstates, sfvals, sspecs, valid=valid)
    else:
        raise ValueError(kind)
    _SHARD_KERNELS[key] = kern
    return kern


def _sharded_append_cache_size():
    return sum(k._cache_size() for k in _SHARD_KERNELS.values())


register_cache_probe("warehouse_append_sharded", _sharded_append_cache_size)
register_engine("warehouse_append_sharded",
                example_builder("store_sharded", "append"),
                probe=_sharded_append_cache_size,
                probe_name="warehouse_append_sharded")
register_engine("warehouse_ingest_sharded_fused",
                example_builder("store_sharded", "fused_multi"),
                probe=_sharded_append_cache_size,
                probe_name="warehouse_append_sharded")
register_engine("warehouse_ingest_sharded_tick",
                example_builder("store_sharded", "tick"),
                probe=_sharded_append_cache_size,
                probe_name="warehouse_append_sharded")
register_engine("warehouse_ingest_sharded_standing",
                example_builder("store_sharded_standing"),
                probe=_sharded_append_cache_size,
                probe_name="warehouse_append_sharded")
register_engine("warehouse_ingest_sharded_tick_ids",
                example_builder("store_sharded", "tick_ids"),
                probe=_sharded_append_cache_size,
                probe_name="warehouse_append_sharded")


class ShardedStore:
    """Stream-hash partitioned ``SegmentStore`` across a device mesh.

    Columns are stacked ``(n_shards, cap, ...)`` device arrays with the
    leading axis split over a 1-D ``'shard'`` mesh (one shard per
    device, see ``launch.mesh.make_shard_mesh``); row ``r`` of stream
    ``s`` lives on shard ``s % n_shards``. Every ingest path
    (``ingest_fused`` / ``ingest_fused_multi`` / ``ingest_tick`` /
    ``append_rows``) is ONE jitted shard_map dispatch that routes each
    row to its owning shard device-side, and ``query`` executes plans
    through the partial/merge engine as ONE shard_map dispatch of the
    per-shard partial kernel plus a collective merge. On hosts with
    fewer devices than shards the identical kernels run vmapped over
    the stacked axis (``mesh is None``) — same semantics, one device.

    Host-side bookkeeping (per-shard row counts, ``t_max``) is computed
    from ingest METADATA (stream ids and row counts the caller already
    knows) — the data itself never round-trips."""

    def __init__(self, out_dim: int, n_shards: int,
                 chunk_rows: int = 8192, mesh="auto"):
        assert out_dim >= 1 and n_shards >= 1 and chunk_rows >= 1
        self.out_dim = int(out_dim)
        self.n_shards = int(n_shards)
        self.chunk_rows = int(chunk_rows)
        self.mesh = make_shard_mesh(n_shards) if mesh == "auto" else mesh
        self.t_max = -1
        self.n_rows_by_shard = np.zeros(self.n_shards, np.int64)
        self.columns = self._put(self._empty(0))
        self.n_rows_dev = self._put(jnp.zeros((self.n_shards,), jnp.int32))
        self.obs = store_obs_init()
        self.standing = None

    def _put(self, tree):
        return put_row_sharded(tree, self.mesh) if self.mesh is not None \
            else tree

    @classmethod
    def _from_parts(cls, *, out_dim, n_shards, chunk_rows, mesh, columns,
                    n_rows_dev, n_rows_by_shard, t_max):
        """Adopt already-partitioned device columns without an ingest
        pass — the constructor ``runtime.elastic.rebalance`` uses to
        wrap its one-dispatch repartition output. Host bookkeeping
        (per-shard counts) comes from the caller; obs counters and the
        standing registry start fresh (rebalance re-registers)."""
        self = cls.__new__(cls)
        self.out_dim = int(out_dim)
        self.n_shards = int(n_shards)
        self.chunk_rows = int(chunk_rows)
        self.mesh = mesh
        self.t_max = int(t_max)
        self.n_rows_by_shard = np.asarray(n_rows_by_shard,
                                          np.int64).copy()
        self.columns = columns
        self.n_rows_dev = n_rows_dev
        self.obs = store_obs_init()
        self.standing = None
        return self

    def _empty(self, cap: int) -> Dict[str, jnp.ndarray]:
        cols = {n: jnp.zeros((self.n_shards, cap), dt)
                for n, dt in SCALAR_COLUMNS}
        cols[OUT_COLUMN] = jnp.zeros((self.n_shards, cap, self.out_dim),
                                     jnp.float32)
        return cols

    # -- capacity ------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Per-shard row capacity."""
        return self.columns["t"].shape[1]

    @property
    def n_rows(self) -> int:
        return int(self.n_rows_by_shard.sum())

    def _reserve(self, incoming_by_shard: np.ndarray) -> None:
        """Grow every shard's capacity (uniformly, chunk-aligned,
        geometric) to fit the incoming per-shard row counts."""
        need = int((self.n_rows_by_shard + incoming_by_shard).max())
        if need <= self.capacity:
            return
        cap = _bucket_cap(need, self.chunk_rows)
        pad = cap - self.capacity
        grown = {k: jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
                 for k, v in self.columns.items()}
        self.columns = self._put(grown)

    # -- ingestion -----------------------------------------------------
    def _owner_counts(self, stream_ids) -> np.ndarray:
        return np.bincount(np.asarray(stream_ids, np.int64)
                           % self.n_shards, minlength=self.n_shards)

    def ingest_fused(self, traces, out_vecs, *, stream_id: int = 0,
                     t0: int = 0) -> int:
        """Land a full single-stream fused run (``(n_w, W)`` trace
        leaves): all T rows route to shard ``stream_id % n_shards``."""
        T = int(out_vecs.shape[0])
        assert out_vecs.ndim == 2 and out_vecs.shape[1] == self.out_dim
        # (n_w, W) -> (n_w, 1, W): the multi kernel with V=1
        sub = {src: traces[src][:, None] for src, _ in _RUN_KEYS}
        return self._ingest_multi(sub, jnp.asarray(out_vecs,
                                                   jnp.float32)[None],
                                  stream_base=stream_id, t0=t0)

    def ingest_fused_multi(self, traces, out_vecs, *,
                           stream_base: int = 0, t0: int = 0) -> int:
        """Land a full multi-stream fused run (``(n_w, V, W)`` leaves):
        stream ``v``'s trace routes to shard
        ``(stream_base + v) % n_shards`` — ONE shard_map dispatch, no
        host gathers."""
        assert out_vecs.ndim == 3 and out_vecs.shape[2] == self.out_dim
        sub = {src: traces[src] for src, _ in _RUN_KEYS}
        return self._ingest_multi(sub, jnp.asarray(out_vecs, jnp.float32),
                                  stream_base=stream_base, t0=t0)

    def _ingest_multi(self, sub, out_vecs, *, stream_base, t0) -> int:
        V, T = int(out_vecs.shape[0]), int(out_vecs.shape[1])
        counts = self._owner_counts(stream_base + np.arange(V)) * T
        self._reserve(counts)
        kern = _shard_kernel("fused_multi", self.mesh, self.n_shards)
        sstates, sfvals, sspecs = _standing_args(self)
        res = kern(self.columns, self.n_rows_dev, sub, out_vecs,
                   jnp.int32(stream_base), jnp.int32(t0), sstates,
                   sfvals, T=T, sspecs=sspecs)
        if sspecs:
            self.columns, self.n_rows_dev, states = res
            self.standing.absorb(states)
        else:
            self.columns, self.n_rows_dev = res
        self.n_rows_by_shard += counts
        self.t_max = max(self.t_max, t0 + T - 1)
        store_obs_batch(self.obs, V, T)
        return V * T

    def ingest_tick(self, traces, *, quality, out_vecs, t: int,
                    stream_ids=None, valid=None) -> int:
        """Land one serving-pool tick (V rows, stream v -> shard
        ``v % n_shards``). ``stream_ids`` / ``valid`` route the elastic
        pool's slot axis: each active slot's row goes to the shard
        owning its REAL stream id, inactive slots land nothing — same
        single routed dispatch (see ``SegmentStore.ingest_tick``)."""
        V = int(out_vecs.shape[0])
        assert out_vecs.ndim == 2 and out_vecs.shape[1] == self.out_dim
        sub = {src: traces[src] for src, _ in _RUN_KEYS}
        sstates, sfvals, sspecs = _standing_args(self)
        if stream_ids is None and valid is None:
            counts = self._owner_counts(np.arange(V))
            self._reserve(counts)
            kern = _shard_kernel("tick", self.mesh, self.n_shards)
            res = kern(self.columns, self.n_rows_dev, sub,
                       jnp.asarray(quality, jnp.float32),
                       jnp.asarray(out_vecs, jnp.float32), jnp.int32(t),
                       sstates, sfvals, sspecs=sspecs)
        else:
            ids = (np.arange(V) if stream_ids is None
                   else np.asarray(stream_ids))
            keep = (np.ones(V, bool) if valid is None
                    else np.asarray(valid, bool))
            counts = np.bincount(ids[keep].astype(np.int64)
                                 % self.n_shards,
                                 minlength=self.n_shards)
            self._reserve(counts)
            kern = _shard_kernel("tick_ids", self.mesh, self.n_shards)
            res = kern(self.columns, self.n_rows_dev, sub,
                       jnp.asarray(quality, jnp.float32),
                       jnp.asarray(out_vecs, jnp.float32), jnp.int32(t),
                       jnp.asarray(ids, jnp.int32), jnp.asarray(keep),
                       sstates, sfvals, sspecs=sspecs)
        if sspecs:
            self.columns, self.n_rows_dev, states = res
            self.standing.absorb(states)
        else:
            self.columns, self.n_rows_dev = res
        self.n_rows_by_shard += counts
        n_new = int(counts.sum())
        if n_new:
            self.t_max = max(self.t_max, t)
        store_obs_tick(self.obs, n_new)
        return n_new

    def append_rows(self, rows: Dict[str, jnp.ndarray]) -> int:
        """Generic batched append, routed by the rows' own stream ids."""
        n = int(np.shape(rows["t"])[0])
        assert set(rows) == {c for c, _ in SCALAR_COLUMNS} | {OUT_COLUMN}, \
            "need exactly the store's columns"
        counts = self._owner_counts(rows["stream_id"])
        self._reserve(counts)
        upd = {k: jnp.asarray(v) for k, v in rows.items()}
        kern = _shard_kernel("append", self.mesh, self.n_shards)
        sstates, sfvals, sspecs = _standing_args(self)
        res = kern(self.columns, self.n_rows_dev, upd, sstates, sfvals,
                   sspecs=sspecs)
        if sspecs:
            self.columns, self.n_rows_dev, states = res
            self.standing.absorb(states)
        else:
            self.columns, self.n_rows_dev = res
        self.n_rows_by_shard += counts
        if n:
            self.t_max = max(self.t_max,
                             int(np.max(np.asarray(rows["t"]))))
        store_obs_tick(self.obs, n)
        return n

    # -- reading -------------------------------------------------------
    def shard_source(self):
        """(stacked columns, per-shard valid row counts) — what the
        sharded query kernel consumes."""
        return self.columns, self.n_rows_dev

    def query(self, plan, **kw):
        """ONE shard_map dispatch: per-shard partial kernel + merge
        combiner (see ``warehouse.query.execute_sharded``)."""
        from repro.warehouse import query as Q
        self.obs["query_dispatches"] += 1
        return Q.execute_sharded(self, plan, **kw)

    def telemetry(self) -> StoreTelemetry:
        """Warehouse flight recorder incl. per-shard balance: the
        imbalance factor (max/mean shard rows) comes straight off the
        ``n_rows_by_shard`` host metadata — zero device reads."""
        return StoreTelemetry(
            rows_by_shard=self.n_rows_by_shard.copy(), **self.obs)

    def host_rows(self) -> Dict[str, np.ndarray]:
        """All live rows as host numpy, shard-major (an explicit full
        transfer — tests/exports only; the query path never needs it)."""
        out = {}
        for k, v in self.columns.items():
            h = np.asarray(v)
            out[k] = np.concatenate(
                [h[s, : self.n_rows_by_shard[s]]
                 for s in range(self.n_shards)])
        return out

    def __len__(self) -> int:
        return self.n_rows

    def __repr__(self) -> str:
        dev = "mesh" if self.mesh is not None else "stacked"
        return (f"ShardedStore(shards={self.n_shards}[{dev}], "
                f"rows={self.n_rows_by_shard.tolist()}, "
                f"cap={self.capacity}, out_dim={self.out_dim}, "
                f"chunk={self.chunk_rows})")

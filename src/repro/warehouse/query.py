"""Compiled queries over the warehouse (the paper's "easy to query").

A query is a tuple of plan nodes applied left to right:

    Filter(column, op, value)   row predicate; ANDed into the row mask
    Project(columns)            keep only the named columns
    GroupBy(key, value, agg)    segment_sum/-max aggregation per key id
    WindowAgg(window, value)    same, keyed by time window t // window
    TopK(k, by)                 lax.top_k over a (possibly aggregated)
                                column; gathers every surviving column

The whole plan compiles to ONE jitted kernel per *plan shape*: filter
predicates are vmapped masks whose threshold VALUES are dynamic
operands (re-querying with a new threshold, or after more rows arrive
within the same chunk capacity, reuses the executable — assert it via
``compile_cache_size()`` / the registered ``warehouse_query`` probe).
Aggregations use ``jax.ops.segment_sum`` with static group counts, so
no data-dependent shapes ever materialize; filtered-out and padding
rows participate as exact no-ops (weight 0 / -inf).

``execute`` returns ``(table, mask)``: a dict of device columns plus a
validity mask over its rows (top-k slots beyond the number of matching
groups are masked off). ``execute_ref`` is the plain-numpy reference
implementation used by tests and the benchmark baseline; it replicates
the kernel's row-order summation so fp32 results match exactly.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.switcher import register_cache_probe


@dataclass(frozen=True)
class Filter:
    column: str
    op: str              # eq | ne | lt | le | gt | ge
    value: float         # dynamic operand: changing it never recompiles


@dataclass(frozen=True)
class Project:
    columns: Tuple[str, ...]


@dataclass(frozen=True)
class GroupBy:
    key: str             # integer column holding the group id
    value: str           # column to aggregate
    agg: str = "sum"     # sum | mean | count | max | min
    num_groups: int = 8  # static: group ids clip into [0, num_groups)


@dataclass(frozen=True)
class WindowAgg:
    window: int          # segments per time window (ids = t // window)
    value: str
    agg: str = "sum"
    num_windows: int = 64


@dataclass(frozen=True)
class TopK:
    k: int
    by: str
    largest: bool = True


PlanNode = Union[Filter, Project, GroupBy, WindowAgg, TopK]


@dataclass(frozen=True)
class _FilterRef:
    """Filter with its value hoisted into the dynamic operand vector, so
    the jitted plan is value-independent."""
    column: str
    op: str
    idx: int


_CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
}


def _int_pred(x, op, i, is_int):
    """Exact real-number comparison of an INTEGER column x against a
    threshold given as (floor, integral?) — computed host-side in
    float64, so neither side ever rounds through f32 (which collapses
    ints past 2^24; the append-only ``t`` column crosses that after
    ~388 days of 2 s segments). All branches are dynamic operands:
    changing the threshold, integral or not, never recompiles."""
    i = i.astype(x.dtype)             # floor(v), the largest int <= v
    if op == "ge":                    # x >= v
        return jnp.where(is_int, x >= i, x >= i + 1)
    if op == "gt":                    # x > v  <=>  x >= floor(v)+1
        return x >= i + 1
    if op == "le":                    # x <= v  <=>  x <= floor(v)
        return x <= i
    if op == "lt":                    # x < v
        return jnp.where(is_int, x <= i - 1, x <= i)
    if op == "eq":
        return is_int & (x == i)
    return ~is_int | (x != i)         # ne


def normalize(plan):
    """Split a plan into its static shape (hashable spec) and the
    dynamic filter-value operands: the f32 thresholds (float columns)
    plus each threshold's float64-computed floor and integrality
    (integer columns — f32 can't hold ints past 2^24, so those are
    hoisted host-side at full precision)."""
    spec, vals, floors, isint = [], [], [], []
    for node in plan:
        if isinstance(node, Filter):
            assert node.op in _CMP, f"unknown filter op {node.op!r}"
            spec.append(_FilterRef(node.column, node.op, len(vals)))
            v = float(node.value)
            assert not math.isnan(v), "NaN filter threshold"
            vals.append(np.float32(v))
            # symmetric clamp: _int_pred computes i±1, so the floor must
            # stay one step inside int32 on BOTH ends (an unclamped
            # -2^31 would wrap `lt`'s i-1 to +2^31-1 and match rows a
            # float64 comparison rejects). +/-inf clamps to the end
            # matching its sign. Thresholds beyond the clamp are only
            # approximate at the extreme +/-2^31 edge of int32 data.
            if math.isinf(v):
                fl = (2 ** 31 - 2) if v > 0 else (-2 ** 31 + 1)
            else:
                fl = min(max(math.floor(v), -2 ** 31 + 1), 2 ** 31 - 2)
            floors.append(np.int32(fl))
            isint.append(math.isfinite(v) and v == fl)
        else:
            spec.append(node)
    return tuple(spec), (jnp.asarray(np.asarray(vals, np.float32)),
                         jnp.asarray(np.asarray(floors, np.int32)),
                         jnp.asarray(np.asarray(isint, bool)))


def _aggregate(table, mask, ids, num, value, agg):
    """Masked segment aggregation with a static group count."""
    v = table[value].astype(jnp.float32)
    ids = jnp.clip(ids.astype(jnp.int32), 0, num - 1)
    if agg in ("sum", "mean", "count"):
        # value and count share ONE scatter pass (the scatter is the
        # whole cost of the kernel on CPU); per-column addition order
        # is unchanged, so results still match the numpy reference
        # bit-exact
        both = jax.ops.segment_sum(
            jnp.stack([jnp.where(mask, v, 0.0),
                       mask.astype(jnp.float32)], axis=1),
            ids, num_segments=num)
        out, cnt = both[:, 0], both[:, 1]
        if agg == "mean":
            out = out / jnp.maximum(cnt, 1.0)
        elif agg == "count":
            out = cnt
        return out, cnt
    cnt = jax.ops.segment_sum(mask.astype(jnp.float32), ids,
                              num_segments=num)
    if agg == "max":
        out = jax.ops.segment_max(jnp.where(mask, v, -jnp.inf), ids,
                                  num_segments=num)
        out = jnp.where(cnt > 0, out, 0.0)
    elif agg == "min":
        out = jax.ops.segment_min(jnp.where(mask, v, jnp.inf), ids,
                                  num_segments=num)
        out = jnp.where(cnt > 0, out, 0.0)
    else:
        raise ValueError(f"unknown agg {agg!r}")
    return out, cnt


@functools.partial(jax.jit, static_argnames=("spec",))
def _run_plan(cols, n_rows, fvals, *, spec):
    cap = cols["t"].shape[0] if "t" in cols else \
        next(iter(cols.values())).shape[0]
    mask = jnp.arange(cap) < n_rows
    table = cols
    for node in spec:
        if isinstance(node, _FilterRef):
            vals, floors, isint = fvals
            col = table[node.column]
            if jnp.issubdtype(col.dtype, jnp.integer):
                i, ii = floors[node.idx], isint[node.idx]
                pred = jax.vmap(
                    lambda x: _int_pred(x, node.op, i, ii))(col)
            else:
                v = vals[node.idx]
                pred = jax.vmap(
                    lambda x: _CMP[node.op](x.astype(jnp.float32), v))(col)
            mask = mask & pred
        elif isinstance(node, Project):
            table = {c: table[c] for c in node.columns}
        elif isinstance(node, GroupBy):
            out, cnt = _aggregate(table, mask, table[node.key],
                                  node.num_groups, node.value, node.agg)
            table = {node.key: jnp.arange(node.num_groups, dtype=jnp.int32),
                     node.value: out, "count": cnt}
            mask = cnt > 0
        elif isinstance(node, WindowAgg):
            out, cnt = _aggregate(table, mask, table["t"] // node.window,
                                  node.num_windows, node.value, node.agg)
            table = {"window": jnp.arange(node.num_windows,
                                          dtype=jnp.int32),
                     node.value: out, "count": cnt}
            mask = cnt > 0
        elif isinstance(node, TopK):
            score = jnp.where(mask, table[node.by].astype(jnp.float32),
                              -jnp.inf)
            score = score if node.largest else jnp.where(
                jnp.isfinite(score), -score, score)
            kk = min(node.k, int(score.shape[0]))
            top, idx = jax.lax.top_k(score, kk)
            table = {c: jnp.take(table[c], idx, axis=0) for c in table}
            table["index"] = idx
            mask = jnp.isfinite(top)
        else:
            raise TypeError(f"unknown plan node {node!r}")
    return table, mask


register_cache_probe("warehouse_query", lambda: _run_plan._cache_size())


def compile_cache_size() -> int:
    """jit cache entries of the query kernel: one per distinct plan
    shape x store capacity — stable across repeated queries (changed
    filter values, appended rows within the same chunk capacity)."""
    return _run_plan._cache_size()


def _source(store):
    """(columns, n_rows) from a SegmentStore, a TieredStore (which
    materializes its cold tier on device), or a raw (columns, n) pair."""
    if hasattr(store, "materialize"):
        return store.materialize()
    if hasattr(store, "columns") and hasattr(store, "n_rows"):
        return store.columns, store.n_rows
    cols, n = store
    return cols, n


def execute(store, plan):
    """Run ``plan`` over ``store`` as one compiled dispatch; returns
    ``(table, mask)`` of device arrays."""
    cols, n_rows = _source(store)
    spec, fvals = normalize(plan)
    return _run_plan(cols, jnp.int32(n_rows), fvals, spec=spec)


def windows_for(store, window: int) -> int:
    """Static window count covering every stored timestamp."""
    t_max = store.t_max if hasattr(store, "t_max") else store.hot.t_max
    return max(1, int(t_max) // int(window) + 1)


def to_host(table, mask) -> Dict[str, np.ndarray]:
    """Compact a query result to host numpy, dropping masked-off rows."""
    m = np.asarray(mask)
    return {k: np.asarray(v)[m] for k, v in table.items()}


# ---------------------------------------------------------------------------
# numpy reference (tests + benchmark correctness baseline)
# ---------------------------------------------------------------------------

def _np_aggregate(table, mask, ids, num, value, agg):
    v = np.asarray(table[value], np.float32)
    ids = np.clip(np.asarray(ids, np.int64), 0, num - 1)
    cnt = np.zeros(num, np.float32)
    np.add.at(cnt, ids[mask], np.float32(1.0))
    if agg == "count":
        out = cnt
    elif agg in ("sum", "mean"):
        out = np.zeros(num, np.float32)
        # np.add.at accumulates in row order — the same fp32 addition
        # sequence as the kernel's segment_sum, so sums match bit-exact
        np.add.at(out, ids[mask], v[mask])
        if agg == "mean":
            out = out / np.maximum(cnt, 1.0)
    elif agg == "max":
        out = np.full(num, -np.inf, np.float32)
        np.maximum.at(out, ids[mask], v[mask])
        out = np.where(cnt > 0, out, 0.0).astype(np.float32)
    elif agg == "min":
        out = np.full(num, np.inf, np.float32)
        np.minimum.at(out, ids[mask], v[mask])
        out = np.where(cnt > 0, out, 0.0).astype(np.float32)
    else:
        raise ValueError(agg)
    return out, cnt


def execute_ref(cols: Dict[str, np.ndarray], n_rows: int, plan):
    """Plain-numpy mirror of ``execute`` (same clipping, masking, and
    summation-order semantics). Returns ``(table, mask)`` in numpy."""
    cap = len(next(iter(cols.values())))
    mask = np.arange(cap) < n_rows
    table = {k: np.asarray(v) for k, v in cols.items()}
    for node in plan:
        if isinstance(node, Filter):
            x = table[node.column]
            if np.issubdtype(x.dtype, np.integer):
                # exact: int32 values and the host-side threshold both
                # embed in float64 (mirrors the kernel's _int_pred)
                mask = mask & _CMP[node.op](x.astype(np.float64),
                                            np.float64(node.value))
            else:
                mask = mask & _CMP[node.op](x.astype(np.float32),
                                            np.float32(node.value))
        elif isinstance(node, Project):
            table = {c: table[c] for c in node.columns}
        elif isinstance(node, GroupBy):
            out, cnt = _np_aggregate(table, mask, table[node.key],
                                     node.num_groups, node.value, node.agg)
            table = {node.key: np.arange(node.num_groups, dtype=np.int32),
                     node.value: out, "count": cnt}
            mask = cnt > 0
        elif isinstance(node, WindowAgg):
            out, cnt = _np_aggregate(table, mask, table["t"] // node.window,
                                     node.num_windows, node.value, node.agg)
            table = {"window": np.arange(node.num_windows, dtype=np.int32),
                     node.value: out, "count": cnt}
            mask = cnt > 0
        elif isinstance(node, TopK):
            score = np.where(mask, table[node.by].astype(np.float32),
                             -np.inf)
            if not node.largest:
                score = np.where(np.isfinite(score), -score, score)
            kk = min(node.k, len(score))
            idx = np.argsort(-score, kind="stable")[:kk].astype(np.int32)
            top = score[idx]
            table = {c: np.take(table[c], idx, axis=0) for c in table}
            table["index"] = idx
            mask = np.isfinite(top)
        else:
            raise TypeError(f"unknown plan node {node!r}")
    return table, mask

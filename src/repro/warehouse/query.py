"""Compiled queries over the warehouse (the paper's "easy to query").

A query is a tuple of plan nodes applied left to right:

    Filter(column, op, value)   row predicate; ANDed into the row mask
    Project(columns)            keep only the named columns
    GroupBy(key, value, agg)    segment_sum/-max aggregation per key id
    WindowAgg(window, value)    same, keyed by time window t // window
    MultiGroupBy(keys, value)   multi-key aggregation (e.g. window x
                                category) via fused key encoding into
                                ONE segment_sum pass
    TopK(k, by)                 lax.top_k over a (possibly aggregated)
                                column; gathers every surviving column

Execution model: every plan is a two-phase **partial / merge** program.
The *partial* phase runs row-local work (filter masks, projections) and
reduces its rows to a fixed-shape, mergeable partial — masked
segment_sum accumulators for aggregations, a local top-k candidate
block for TopK, the masked rows themselves for pure row plans. The
*merge* phase combines partials (sum / max / concat), finalizes
(mean division, empty-group replacement), and runs any post-reduction
nodes. The single-device engine is the trivial 1-shard case of this
model — partial + identity merge, bit-exact with the pre-refactor
kernel — and the SAME partial/merge functions execute sharded:
``execute_sharded`` runs ONE ``shard_map`` dispatch over a
``ShardedStore``'s device mesh (psum/pmax/all_gather merge; optionally
int8-compressed partial sums for wide embedding columns, reusing
``distribution.compression``), or, below the device count, the same
kernels vmapped over a stacked shard axis on one device.

The whole plan compiles to ONE jitted kernel per *plan shape*: filter
predicates are vmapped masks whose threshold VALUES are dynamic
operands (re-querying with a new threshold, or after more rows arrive
within the same chunk capacity, reuses the executable — assert it via
``compile_cache_size()`` / the registered ``warehouse_query`` probes).
Aggregations use ``jax.ops.segment_sum`` with static group counts, so
no data-dependent shapes ever materialize; filtered-out and padding
rows participate as exact no-ops (weight 0 / -inf).

Aggregation partials have TWO interchangeable kernels behind
``use_pallas`` (see ``execute``): the XLA ``segment_sum`` path above,
and the fused Pallas filter+group+aggregate kernel
(``repro.kernels.warehouse_agg``) that evaluates the predicate mask
in-register and accumulates into an on-chip ``(n_groups[, lanes])``
accumulator with ZERO scatters — the auditor's scatter census is 0 on
that path (the XLA path pins one executed scatter per groupby-style
plan). Both produce the identical ``{"acc", "cnt"}`` partial, share
``_seg_finalize`` and the merge combiners, and ``execute_sharded``
runs the fused kernel per shard inside its single shard_map dispatch.

``execute`` returns ``(table, mask)``: a dict of device columns plus a
validity mask over its rows (top-k slots beyond the number of matching
groups are masked off). ``execute_ref`` is the plain-numpy reference
implementation used by tests and the benchmark baseline; it replicates
the kernel's row-order summation so fp32 results match exactly on a
single shard (multi-shard float sums regroup the addition and match to
tolerance; counts and integer-valued sums stay exact).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.analysis.registry import example_builder, register_engine
from repro.core.switcher import register_cache_probe
from repro.distribution.compression import compressed_psum, quantize_int8
from repro.kernels.warehouse_agg import (CMP as _CMP, FusedAggSpec,
                                         fused_segment_agg, int_pred,
                                         pallas_auto)


@dataclass(frozen=True)
class Filter:
    """Row predicate plan node: keep rows where ``column <op> value``
    (also reused as the standing-alert predicate over answer tables)."""
    column: str
    op: str              # eq | ne | lt | le | gt | ge
    value: float         # dynamic operand: changing it never recompiles


@dataclass(frozen=True)
class Project:
    """Column-selection plan node: restrict downstream nodes to
    ``columns`` (trace-time slicing; no device work of its own)."""
    columns: Tuple[str, ...]


@dataclass(frozen=True)
class GroupBy:
    """Grouped aggregation plan node: one ``segment_sum``-style pass
    over an integer key column, fixed ``num_groups`` output shape."""
    key: str             # integer column holding the group id
    value: str           # column to aggregate
    agg: str = "sum"     # sum | mean | count | max | min
    num_groups: int = 8  # static: group ids clip into [0, num_groups)


@dataclass(frozen=True)
class WindowAgg:
    """Time-window aggregation plan node: group rows by
    ``t // window`` into ``num_windows`` fixed slots."""
    window: int          # segments per time window (ids = t // window)
    value: str
    agg: str = "sum"
    num_windows: int = 64


@dataclass(frozen=True)
class MultiGroupBy:
    """Aggregate by SEVERAL integer keys at once (e.g. time window x
    content category) with the key tuple fused into one flat id, so the
    whole multi-key aggregation is still ONE segment_sum pass.

    ``nums[i]`` is the static id count of ``keys[i]`` (ids clip into
    [0, nums[i]) after windowing); ``windows[i] > 1`` divides that key's
    column first (``keys[i] == "t", windows[i] == W`` reproduces
    WindowAgg's time windows). The result table has one decoded id
    column per key plus the aggregated value and ``count``."""
    keys: Tuple[str, ...]
    value: str
    agg: str = "sum"
    nums: Tuple[int, ...] = ()
    windows: Tuple[int, ...] = ()    # optional, same length as keys


@dataclass(frozen=True)
class TopK:
    """Row-level top-k plan node: the ``k`` rows extremal in ``by``
    (a post node — no fixed-size mergeable partial, so not standing)."""
    k: int
    by: str
    largest: bool = True


PlanNode = Union[Filter, Project, GroupBy, WindowAgg, MultiGroupBy, TopK]

# nodes that reduce rows to a fixed-shape mergeable partial — a sharded
# plan splits at the FIRST of these
_REDUCERS = (GroupBy, WindowAgg, MultiGroupBy, TopK)


@dataclass(frozen=True)
class _FilterRef:
    """Filter with its value hoisted into the dynamic operand vector, so
    the jitted plan is value-independent."""
    column: str
    op: str
    idx: int


def normalize(plan):
    """Split a plan into its static shape (hashable spec) and the
    dynamic filter-value operands: the f32 thresholds (float columns)
    plus each threshold's float64-computed floor, integrality, and
    out-of-int32-range flag (integer columns — f32 can't hold ints
    past 2^24, so those are hoisted host-side at full precision).
    ``int_pred``'s rewrites are closed-form in the floor (no ±1
    arithmetic), so every threshold with a representable int32 floor —
    including the ±2^31 edges — compares exactly; ``oob`` (-1/0/+1)
    marks thresholds outside int32 entirely (incl. ∓inf), where the
    comparison is a constant for every possible column value."""
    spec, vals, floors, isint, oob = [], [], [], [], []
    for node in plan:
        if isinstance(node, Filter):
            assert node.op in _CMP, f"unknown filter op {node.op!r}"
            spec.append(_FilterRef(node.column, node.op, len(vals)))
            v = float(node.value)
            assert not math.isnan(v), "NaN filter threshold"
            vals.append(np.float32(v))
            if v >= 2.0 ** 31:                 # incl. +inf
                ob, fl, ii = 1, 0, False
            elif v < -2.0 ** 31:               # incl. -inf
                ob, fl, ii = -1, 0, False
            else:
                ob, fl = 0, math.floor(v)      # in [-2^31, 2^31 - 1]
                ii = v == fl
            floors.append(np.int32(fl))
            isint.append(ii)
            oob.append(np.int32(ob))
        else:
            if isinstance(node, MultiGroupBy):
                assert len(node.keys) >= 1 and \
                    len(node.nums) == len(node.keys), \
                    "MultiGroupBy needs one static id count per key"
                assert not node.windows or \
                    len(node.windows) == len(node.keys), \
                    "MultiGroupBy windows must match keys"
            spec.append(node)
    return tuple(spec), (jnp.asarray(np.asarray(vals, np.float32)),
                         jnp.asarray(np.asarray(floors, np.int32)),
                         jnp.asarray(np.asarray(isint, bool)),
                         jnp.asarray(np.asarray(oob, np.int32)))


# ---------------------------------------------------------------------------
# segment aggregation as partial -> finalize (the mergeable core)
# ---------------------------------------------------------------------------

def _seg_ids(table, node):
    """Clipped int32 group ids + static group count for an agg node."""
    if isinstance(node, GroupBy):
        ids, num = table[node.key], node.num_groups
    elif isinstance(node, WindowAgg):
        ids, num = table["t"] // node.window, node.num_windows
    else:                                            # MultiGroupBy
        wins = node.windows or (0,) * len(node.keys)
        fused = None
        for key, n, w in zip(node.keys, node.nums, wins):
            ids = table[key].astype(jnp.int32)
            if w and w > 1:
                ids = ids // w
            ids = jnp.clip(ids, 0, n - 1)
            # fused encoding: ONE scatter pass covers the key tuple
            fused = ids if fused is None else fused * n + ids
        return fused, math.prod(node.nums)
    return jnp.clip(ids.astype(jnp.int32), 0, num - 1), num


def _seg_partial(table, mask, node):
    """Masked segment accumulators — the per-shard PARTIAL of an agg
    node: {"acc", "cnt"}, fixed (num_groups,[D]) shapes, mergeable by
    sum (sum/mean/count) or max/min. Filtered rows are exact no-ops."""
    ids, num = _seg_ids(table, node)
    v = table[node.value].astype(jnp.float32)
    if node.agg in ("sum", "mean", "count"):
        if v.ndim == 1:
            # value and count share ONE scatter pass (the scatter is the
            # whole cost of the kernel on CPU); per-column addition
            # order is unchanged, so single-shard results still match
            # the numpy reference bit-exact
            both = jax.ops.segment_sum(
                jnp.stack([jnp.where(mask, v, 0.0),
                           mask.astype(jnp.float32)], axis=1),
                ids, num_segments=num)
            return {"acc": both[:, 0], "cnt": both[:, 1]}
        # wide (row, D) value columns (the `out` embedding): plain
        # masked segment_sum per lane
        acc = jax.ops.segment_sum(jnp.where(mask[:, None], v, 0.0), ids,
                                  num_segments=num)
        cnt = jax.ops.segment_sum(mask.astype(jnp.float32), ids,
                                  num_segments=num)
        return {"acc": acc, "cnt": cnt}
    assert v.ndim == 1, f"agg {node.agg!r} needs a scalar column"
    cnt = jax.ops.segment_sum(mask.astype(jnp.float32), ids,
                              num_segments=num)
    if node.agg == "max":
        acc = jax.ops.segment_max(jnp.where(mask, v, -jnp.inf), ids,
                                  num_segments=num)
    elif node.agg == "min":
        acc = jax.ops.segment_min(jnp.where(mask, v, jnp.inf), ids,
                                  num_segments=num)
    else:
        raise ValueError(f"unknown agg {node.agg!r}")
    return {"acc": acc, "cnt": cnt}


def _seg_fold(part, table, mask, node):
    """Fold a batch of NEW rows into a stored partial IN PLACE of the
    zero/∓inf seed: the scatter that ``_seg_partial`` runs over a zeroed
    accumulator runs here over the STORED accumulator instead. For
    sum/mean/count this continues each group's fp32 addition sequence
    exactly where the stored partial left off (the same row-order
    scatter-accumulation contract ``_seg_partial``'s ``segment_sum``
    already relies on for single-shard bit-exactness), so a backfill
    followed by any number of ingest-time folds produces the BIT-EXACT
    accumulator one ``_seg_partial`` over the concatenated rows would —
    the standing-query engine's exactness contract (see
    ``warehouse.standing``). max/min/count folds are order-independent
    and exact regardless."""
    ids, num = _seg_ids(table, node)
    v = table[node.value].astype(jnp.float32)
    if node.agg in ("sum", "mean", "count"):
        if v.ndim == 1:
            # same stacked value+count single-scatter layout as
            # _seg_partial, seeded with the stored accumulators
            both = jnp.stack([part["acc"], part["cnt"]], axis=1)
            upd = jnp.stack([jnp.where(mask, v, 0.0),
                             mask.astype(jnp.float32)], axis=1)
            both = both.at[ids].add(upd, mode="drop")
            return {"acc": both[:, 0], "cnt": both[:, 1]}
        acc = part["acc"].at[ids].add(jnp.where(mask[:, None], v, 0.0),
                                      mode="drop")
        cnt = part["cnt"].at[ids].add(mask.astype(jnp.float32),
                                      mode="drop")
        return {"acc": acc, "cnt": cnt}
    assert v.ndim == 1, f"agg {node.agg!r} needs a scalar column"
    cnt = part["cnt"].at[ids].add(mask.astype(jnp.float32), mode="drop")
    if node.agg == "max":
        acc = part["acc"].at[ids].max(jnp.where(mask, v, -jnp.inf),
                                      mode="drop")
    elif node.agg == "min":
        acc = part["acc"].at[ids].min(jnp.where(mask, v, jnp.inf),
                                      mode="drop")
    else:
        raise ValueError(f"unknown agg {node.agg!r}")
    return {"acc": acc, "cnt": cnt}


def _seg_finalize(acc, cnt, agg):
    """Merged accumulators -> the agg's answer (pure; shared verbatim
    by the 1-shard, sharded, and Pallas paths, so they cannot drift).

    Empty-group contract: a group with NO surviving rows (filtered out
    or never present) answers 0.0 with ``count == 0`` and a masked-off
    result row, for EVERY agg — the ``∓inf`` sentinels that seed
    ``max``/``min`` accumulators (and survive pmax/pmin merges of
    all-empty shards) must never leak into a result table.
    ``execute_ref`` defines the same contract and the regression tests
    in tests/test_warehouse_agg_pallas.py pin it on all three paths."""
    if agg == "mean":
        c = jnp.maximum(cnt, 1.0)
        out = acc / (c if acc.ndim == cnt.ndim else c[:, None])
    elif agg == "count":
        out = cnt
    elif agg in ("max", "min"):
        out = jnp.where(cnt > 0, acc, 0.0)
    else:
        out = acc
    return out, cnt


def _seg_table(node, out, cnt):
    """Result table + mask for a finalized aggregation."""
    if isinstance(node, GroupBy):
        table = {node.key: jnp.arange(node.num_groups, dtype=jnp.int32)}
    elif isinstance(node, WindowAgg):
        table = {"window": jnp.arange(node.num_windows, dtype=jnp.int32)}
    else:                                            # MultiGroupBy
        num = math.prod(node.nums)
        rem = jnp.arange(num, dtype=jnp.int32)
        decoded = {}
        for key, n in zip(reversed(node.keys), reversed(node.nums)):
            decoded[key] = rem % n
            rem = rem // n
        table = {k: decoded[k] for k in node.keys}
    table[node.value] = out
    table["count"] = cnt
    return table, cnt > 0


def _apply_nodes(table, mask, fvals, spec):
    """Run plan nodes left-to-right on a (replicated) table — row-local
    nodes plus full (partial + trivially-merged) reductions. This IS the
    single-device engine, and the sharded engine reuses it for the
    pre-reduction and post-merge phases."""
    for node in spec:
        if isinstance(node, _FilterRef):
            vals, floors, isint, oob = fvals
            col = table[node.column]
            if jnp.issubdtype(col.dtype, jnp.integer):
                i, ii, ob = floors[node.idx], isint[node.idx], \
                    oob[node.idx]
                pred = jax.vmap(
                    lambda x: int_pred(x, node.op, i, ii, ob))(col)
            else:
                v = vals[node.idx]
                pred = jax.vmap(
                    lambda x: _CMP[node.op](x.astype(jnp.float32), v))(col)
            mask = mask & pred
        elif isinstance(node, Project):
            table = {c: table[c] for c in node.columns}
        elif isinstance(node, (GroupBy, WindowAgg, MultiGroupBy)):
            part = _seg_partial(table, mask, node)
            out, cnt = _seg_finalize(part["acc"], part["cnt"], node.agg)
            table, mask = _seg_table(node, out, cnt)
        elif isinstance(node, TopK):
            score = jnp.where(mask, table[node.by].astype(jnp.float32),
                              -jnp.inf)
            score = score if node.largest else jnp.where(
                jnp.isfinite(score), -score, score)
            kk = min(node.k, int(score.shape[0]))
            top, idx = jax.lax.top_k(score, kk)
            table = {c: jnp.take(table[c], idx, axis=0) for c in table}
            table["index"] = idx
            mask = jnp.isfinite(top)
        else:
            raise TypeError(f"unknown plan node {node!r}")
    return table, mask


def _pallas_spec(pre, node, cols):
    """``FusedAggSpec`` for a plan's partial phase, or None when the
    fused Pallas kernel cannot run it: no reducer / TopK reducer /
    wide-column max-min, or a pre-node referencing columns the XLA
    path would reject (Project order is honored, so forced-Pallas
    never silently answers a plan the fallback path errors on).
    ``cols`` may be real arrays or per-shard ShapeDtypeStructs."""
    if node is None or isinstance(node, TopK):
        return None
    avail = set(cols)
    filters = []
    for nd in pre:
        if isinstance(nd, _FilterRef):
            if nd.column not in avail:
                return None
            filters.append((nd.column, nd.op, nd.idx))
        elif isinstance(nd, Project):
            if not set(nd.columns) <= avail:
                return None
            avail = set(nd.columns)
        else:
            return None
    if isinstance(node, GroupBy):
        keys = ((node.key, node.num_groups, 0),)
    elif isinstance(node, WindowAgg):
        keys = (("t", node.num_windows, node.window),)
    else:                                            # MultiGroupBy
        wins = node.windows or (0,) * len(node.keys)
        keys = tuple(zip(node.keys, node.nums, wins))
    if not {k for k, _, _ in keys} | {node.value} <= avail:
        return None
    if len(cols[node.value].shape) == 2 and node.agg in ("max", "min"):
        return None                  # the XLA path asserts scalar too
    return FusedAggSpec(filters=tuple(filters), keys=keys,
                        value=node.value, agg=node.agg)


def _resolve_use_pallas(flag, pre, node, cols) -> bool:
    """Host-side dispatch: ``False`` forces XLA; ``True`` requests the
    fused kernel (falling back to XLA when the plan shape doesn't fit
    it — e.g. TopK reducers); ``None`` is the cost-based auto policy
    (``pallas_auto``): Pallas on TPU for on-chip-sized accumulators,
    XLA elsewhere (CPU interpret mode is a correctness path only)."""
    if flag is not None and not flag:
        return False
    aspec = _pallas_spec(pre, node, cols)
    if aspec is None:
        return False
    if flag:
        return True
    width = cols[aspec.value].shape[1] \
        if len(cols[aspec.value].shape) == 2 else 1
    return pallas_auto(aspec, width)


@functools.partial(jax.jit, static_argnames=("spec", "use_pallas"))
def _run_plan(cols, n_rows, fvals, *, spec, use_pallas=False):
    if use_pallas:
        # fused Pallas partial (no scatter, mask in-register) + the
        # SAME finalize/post nodes as the XLA path
        pre, node, post = split_plan(spec)
        aspec = _pallas_spec(pre, node, cols)
        assert aspec is not None, "unsupported plan for the fused kernel"
        part = fused_segment_agg(cols, n_rows, fvals, spec=aspec)
        out, cnt = _seg_finalize(part["acc"], part["cnt"], node.agg)
        table, mask = _seg_table(node, out, cnt)
        return _apply_nodes(table, mask, fvals, post)
    cap = cols["t"].shape[0] if "t" in cols else \
        next(iter(cols.values())).shape[0]
    mask = jnp.arange(cap) < n_rows
    return _apply_nodes(cols, mask, fvals, spec)


register_cache_probe("warehouse_query", lambda: _run_plan._cache_size())
register_engine("warehouse_query_filter_groupby",
                example_builder("query", "filter_groupby"),
                probe=lambda: _run_plan._cache_size(),
                covers=("repro.warehouse.query:_run_plan",),
                probe_name="warehouse_query")
register_engine("warehouse_query_window",
                example_builder("query", "window_sum"),
                probe=lambda: _run_plan._cache_size(),
                probe_name="warehouse_query")
register_engine("warehouse_query_multi_topk",
                example_builder("query", "multi_topk"),
                probe=lambda: _run_plan._cache_size(),
                probe_name="warehouse_query")
# the fused Pallas path (use_pallas=True) — the "_pallas" suffix keys
# the per-engine scatter_ops.* ceilings AND the aggregated
# scatter_ops.query_pallas=0 metric in benchmarks/run.py: the audit
# fails the bench --compare if a scatter ever creeps back in
register_engine("warehouse_query_pallas_groupby",
                example_builder("query_pallas", "filter_groupby"),
                probe=lambda: _run_plan._cache_size(),
                probe_name="warehouse_query")
register_engine("warehouse_query_pallas_window",
                example_builder("query_pallas", "window_sum"),
                probe=lambda: _run_plan._cache_size(),
                probe_name="warehouse_query")
register_engine("warehouse_query_pallas_groupmax",
                example_builder("query_pallas", "group_max"),
                probe=lambda: _run_plan._cache_size(),
                probe_name="warehouse_query")
register_engine("warehouse_query_pallas_multi",
                example_builder("query_pallas", "multi_topk"),
                probe=lambda: _run_plan._cache_size(),
                probe_name="warehouse_query")


def compile_cache_size() -> int:
    """jit cache entries of the single-device query kernel: one per
    distinct plan shape x store capacity — stable across repeated
    queries (changed filter values, appended rows within the same chunk
    capacity)."""
    return _run_plan._cache_size()


# ---------------------------------------------------------------------------
# sharded execution: per-shard partial kernel + merge combiner
# ---------------------------------------------------------------------------

def split_plan(spec):
    """(pre, reduce_node, post): the partial phase runs ``pre`` (row-
    local Filter/Project) plus the first reducing node's accumulators;
    the merge phase combines partials and runs ``post`` on the merged,
    replicated table."""
    for i, node in enumerate(spec):
        if isinstance(node, _REDUCERS):
            return spec[:i], node, spec[i + 1:]
    return spec, None, ()


class _CollectiveCombine:
    """Merge primitives inside shard_map: collectives over the mesh's
    'shard' axis."""
    collective = True

    def __init__(self, axis: str, n: int):
        self.axis, self.n = axis, n

    def sum(self, x):
        return jax.lax.psum(x, self.axis)

    def max(self, x):
        return jax.lax.pmax(x, self.axis)

    def min(self, x):
        return jax.lax.pmin(x, self.axis)

    def concat(self, x):
        return jax.lax.all_gather(x, self.axis, axis=0, tiled=True)


class _StackedCombine:
    """Merge primitives for the single-device fallback: partial leaves
    carry a leading (n_shards,) axis (vmapped partial kernel) and merge
    by axis-0 reduction — the same algebra, no collectives."""
    collective = False

    def __init__(self, n: int):
        self.n = n

    def sum(self, x):
        return x.sum(axis=0)

    def max(self, x):
        return x.max(axis=0)

    def min(self, x):
        return x.min(axis=0)

    def concat(self, x):
        return x.reshape((-1,) + x.shape[2:])


def _compressed_sum(acc, combine, key):
    """Merge float partial sums through int8 quantization (per-shard
    scale + stochastic rounding) — 4x fewer bytes on the cross-shard
    hop, for wide embedding-column accumulators. The collective path
    reuses ``distribution.compression.compressed_psum`` (x n to undo its
    mean); the stacked path mirrors its math (sum of int8 codes times
    the mean scale) so both modes share semantics."""
    if combine.collective:
        k = jax.random.fold_in(key, jax.lax.axis_index(combine.axis))
        mean, _ = compressed_psum(acc, combine.axis, k,
                                  jnp.zeros_like(acc))
        return mean * combine.n
    keys = jax.random.split(key, acc.shape[0])
    q, scale = jax.vmap(quantize_int8)(acc, keys)
    total = q.astype(jnp.int32).sum(axis=0).astype(jnp.float32)
    return total * (scale.sum() / combine.n)


def _shard_partial_pallas(cols, n_valid, fvals, shard_id, *, pre, node):
    """``_shard_partial`` with the whole filter+group+aggregate partial
    as ONE fused Pallas kernel pass — the identical ``{"acc", "cnt"}``
    convention, so the merge combiners and finalize are untouched
    (selected per-plan by ``execute_sharded``'s ``use_pallas``)."""
    aspec = _pallas_spec(pre, node, cols)
    assert aspec is not None, "unsupported plan for the fused kernel"
    return fused_segment_agg(cols, n_valid, fvals, spec=aspec)


def _shard_partial(cols, n_valid, fvals, shard_id, *, pre, node):
    """ONE shard's partial: row-local pre nodes, then the reduce node's
    fixed-shape mergeable accumulators (or the masked rows themselves
    for pure row plans)."""
    cap = next(iter(cols.values())).shape[0]
    mask = jnp.arange(cap) < n_valid
    table, mask = _apply_nodes(cols, mask, fvals, pre)
    if node is None:
        return {"table": table, "mask": mask}
    if isinstance(node, TopK):
        # local candidates: the global top-k is a subset of the union of
        # per-shard top-k blocks, so k survivors per shard suffice
        score = jnp.where(mask, table[node.by].astype(jnp.float32),
                          -jnp.inf)
        if not node.largest:
            score = jnp.where(jnp.isfinite(score), -score, score)
        kk = min(node.k, int(score.shape[0]))
        top, idx = jax.lax.top_k(score, kk)
        cand = {c: jnp.take(table[c], idx, axis=0) for c in table}
        cand["index"] = idx + shard_id * cap       # global row id
        return {"table": cand, "score": top}
    return _seg_partial(table, mask, node)


def _merge_partials(part, node, post, fvals, combine, key, compressed):
    """Pure merge combiner: cross-shard reduction of the partial, agg
    finalization, then the post-reduction plan nodes on the (now
    replicated) merged table."""
    if node is None:                                  # pure row plan
        table = {k: combine.concat(v) for k, v in part["table"].items()}
        return table, combine.concat(part["mask"])
    if isinstance(node, TopK):
        score = combine.concat(part["score"])
        cand = {c: combine.concat(v) for c, v in part["table"].items()}
        kk = min(node.k, int(score.shape[0]))
        top, idx = jax.lax.top_k(score, kk)
        table = {c: jnp.take(v, idx, axis=0) for c, v in cand.items()}
        mask = jnp.isfinite(top)
    else:
        acc, cnt = part["acc"], part["cnt"]
        if node.agg == "max":
            acc = combine.max(acc)
        elif node.agg == "min":
            acc = combine.min(acc)
        elif compressed and acc.dtype == jnp.float32:
            acc = _compressed_sum(acc, combine, key)
        else:
            acc = combine.sum(acc)
        cnt = combine.sum(cnt)                        # counts stay exact
        out, cnt = _seg_finalize(acc, cnt, node.agg)
        table, mask = _seg_table(node, out, cnt)
    return _apply_nodes(table, mask, fvals, post)


# (mesh, n_shards) -> jitted sharded kernel; a plain dict (not
# lru_cache) so the cache probe can sum executable counts across them
_SHARDED_KERNELS: Dict = {}


def _sharded_kernel(mesh, n_shards: int):
    kern = _SHARDED_KERNELS.get((mesh, n_shards))
    if kern is not None:
        return kern

    @functools.partial(jax.jit,
                       static_argnames=("spec", "compressed",
                                        "use_pallas"))
    def run(cols, n_valid, fvals, key, *, spec, compressed,
            use_pallas=False):
        pre, node, post = split_plan(spec)
        part_fn = _shard_partial_pallas if use_pallas else _shard_partial
        if mesh is None:
            # single-device fallback: vmap the SAME partial kernel over
            # the stacked shard axis, merge by axis-0 reduction
            sids = jnp.arange(n_shards, dtype=jnp.int32)
            part = jax.vmap(lambda c, n, s: part_fn(
                c, n, fvals, s, pre=pre, node=node))(cols, n_valid, sids)
            return _merge_partials(part, node, post, fvals,
                                   _StackedCombine(n_shards), key,
                                   compressed)

        def body(c, n, fv, k):
            sid = jax.lax.axis_index("shard")
            part = part_fn({name: v[0] for name, v in c.items()},
                           n[0], fv, sid, pre=pre, node=node)
            return _merge_partials(part, node, post, fv,
                                   _CollectiveCombine("shard", n_shards),
                                   k, compressed)

        return shard_map(body, mesh=mesh,
                         in_specs=(P("shard"), P("shard"), P(), P()),
                         out_specs=P(), check_rep=False)(
                             cols, n_valid, fvals, key)

    _SHARDED_KERNELS[(mesh, n_shards)] = run
    return run


def sharded_compile_cache_size() -> int:
    """jit cache entries across every sharded query kernel: one per
    (plan shape x shard capacity) per (mesh, shard count) — stable
    across repeated queries at a fixed shard count."""
    return sum(k._cache_size() for k in _SHARDED_KERNELS.values())


register_cache_probe("warehouse_query_sharded", sharded_compile_cache_size)
register_engine("warehouse_query_sharded_groupby",
                example_builder("query_sharded", "filter_groupby"),
                probe=sharded_compile_cache_size,
                probe_name="warehouse_query_sharded")
register_engine("warehouse_query_sharded_topk",
                example_builder("query_sharded", "topk"),
                probe=sharded_compile_cache_size,
                probe_name="warehouse_query_sharded")
register_engine("warehouse_query_pallas_sharded",
                example_builder("query_sharded", "filter_groupby", True),
                probe=sharded_compile_cache_size,
                probe_name="warehouse_query_sharded")


def execute_sharded(store, plan, *, compressed: bool = False, key=None,
                    use_pallas=None):
    """Run ``plan`` over a sharded store as ONE dispatch: the per-shard
    partial kernel through ``shard_map`` on the store's device mesh
    followed by the pure merge combiner (psum / pmax / all-gather), or
    the vmapped stacked equivalent when the host lacks the devices.
    ``compressed=True`` merges float partial sums through int8
    quantization (see ``_compressed_sum``) — exact counts, lossy sums.
    ``use_pallas`` picks the per-shard partial kernel exactly like
    ``execute`` (None = cost-based auto; True = fused Pallas partials
    inside the same shard_map dispatch, when the plan shape fits).
    Returns ``(table, mask)`` of replicated device arrays."""
    cols, n_valid = store.shard_source()
    spec, fvals = normalize(plan)
    if key is None:
        key = jax.random.PRNGKey(0)
    pre, node, _post = split_plan(spec)
    shard_cols = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                  for k, v in cols.items()}
    up = _resolve_use_pallas(use_pallas, pre, node, shard_cols)
    kern = _sharded_kernel(store.mesh, store.n_shards)
    return kern(cols, n_valid, fvals, key, spec=spec,
                compressed=bool(compressed), use_pallas=up)


def _source(store):
    """(columns, n_rows) from a SegmentStore, a TieredStore (which
    materializes its cold tier on device), or a raw (columns, n) pair."""
    if hasattr(store, "materialize"):
        return store.materialize()
    if hasattr(store, "columns") and hasattr(store, "n_rows"):
        return store.columns, store.n_rows
    cols, n = store
    return cols, n


def execute(store, plan, *, use_pallas=None):
    """Run ``plan`` over ``store`` as one compiled dispatch; returns
    ``(table, mask)`` of device arrays. Sharded stores route to
    ``execute_sharded``. ``use_pallas=None`` picks the backend-aware
    cost-based dispatch (fused Pallas kernel on TPU for on-chip-sized
    accumulators, XLA ``segment_sum`` elsewhere); ``True`` forces the
    fused kernel for plan shapes it supports — on CPU it runs in
    interpret mode, a correctness path, not a fast one — and ``False``
    forces the XLA path."""
    if hasattr(store, "shard_source"):
        return execute_sharded(store, plan, use_pallas=use_pallas)
    cols, n_rows = _source(store)
    spec, fvals = normalize(plan)
    pre, node, _post = split_plan(spec)
    up = _resolve_use_pallas(use_pallas, pre, node, cols)
    return _run_plan(cols, jnp.int32(n_rows), fvals, spec=spec,
                     use_pallas=up)


def windows_for(store, window: int) -> int:
    """Static window count covering every stored timestamp."""
    t_max = store.t_max if hasattr(store, "t_max") else store.hot.t_max
    return max(1, int(t_max) // int(window) + 1)


def to_host(table, mask) -> Dict[str, np.ndarray]:
    """Compact a query result to host numpy, dropping masked-off rows."""
    m = np.asarray(mask)
    return {k: np.asarray(v)[m] for k, v in table.items()}


# ---------------------------------------------------------------------------
# numpy reference (tests + benchmark correctness baseline)
# ---------------------------------------------------------------------------

def _np_seg_ids(table, node):
    if isinstance(node, GroupBy):
        ids, num = table[node.key], node.num_groups
    elif isinstance(node, WindowAgg):
        ids, num = table["t"] // node.window, node.num_windows
    else:                                            # MultiGroupBy
        wins = node.windows or (0,) * len(node.keys)
        fused = None
        for key, n, w in zip(node.keys, node.nums, wins):
            ids = np.asarray(table[key], np.int64)
            if w and w > 1:
                ids = ids // w
            ids = np.clip(ids, 0, n - 1)
            fused = ids if fused is None else fused * n + ids
        return fused, math.prod(node.nums)
    return np.clip(np.asarray(ids, np.int64), 0, num - 1), num


def _np_aggregate(table, mask, node):
    ids, num = _np_seg_ids(table, node)
    v = np.asarray(table[node.value], np.float32)
    agg = node.agg
    cnt = np.zeros(num, np.float32)
    np.add.at(cnt, ids[mask], np.float32(1.0))
    if agg == "count":
        out = cnt
    elif agg in ("sum", "mean"):
        out = np.zeros((num,) + v.shape[1:], np.float32)
        # np.add.at accumulates in row order — the same fp32 addition
        # sequence as the kernel's segment_sum, so single-shard sums
        # match bit-exact
        np.add.at(out, ids[mask], v[mask])
        if agg == "mean":
            c = np.maximum(cnt, 1.0)
            out = out / (c if out.ndim == 1 else c[:, None])
    elif agg == "max":
        assert v.ndim == 1, "max needs a scalar column"
        out = np.full(num, -np.inf, np.float32)
        np.maximum.at(out, ids[mask], v[mask])
        out = np.where(cnt > 0, out, 0.0).astype(np.float32)
    elif agg == "min":
        assert v.ndim == 1, "min needs a scalar column"
        out = np.full(num, np.inf, np.float32)
        np.minimum.at(out, ids[mask], v[mask])
        out = np.where(cnt > 0, out, 0.0).astype(np.float32)
    else:
        raise ValueError(agg)
    return out, cnt


def _np_seg_table(node, out, cnt):
    if isinstance(node, GroupBy):
        table = {node.key: np.arange(node.num_groups, dtype=np.int32)}
    elif isinstance(node, WindowAgg):
        table = {"window": np.arange(node.num_windows, dtype=np.int32)}
    else:
        num = math.prod(node.nums)
        rem = np.arange(num, dtype=np.int64)
        decoded = {}
        for key, n in zip(reversed(node.keys), reversed(node.nums)):
            decoded[key] = (rem % n).astype(np.int32)
            rem = rem // n
        table = {k: decoded[k] for k in node.keys}
    table[node.value] = out
    table["count"] = cnt
    return table, cnt > 0


def _np_topk_idx(score, kk: int) -> np.ndarray:
    """Mirror ``lax.top_k``'s ordering exactly: descending IEEE-754
    TOTAL order — so ``+0.0`` outranks ``-0.0``, which a plain
    ``np.argsort(-score)`` treats as equal and orders by index —
    with ties at identical bit patterns broken by ascending row index
    (both are stable). The total order comes from the classic
    sign-magnitude bit flip: non-negative floats set the sign bit,
    negative floats invert all bits, and the uint32 keys then sort in
    float total order."""
    bits = np.ascontiguousarray(np.asarray(score, np.float32)) \
        .view(np.uint32)
    key = np.where(bits & np.uint32(0x80000000), ~bits,
                   bits | np.uint32(0x80000000))
    return np.argsort(~key, kind="stable")[:kk].astype(np.int32)


def execute_ref(cols: Dict[str, np.ndarray], n_rows: int, plan):
    """Plain-numpy mirror of ``execute`` (same clipping, masking, and
    summation-order semantics — including ``_seg_finalize``'s
    empty-group contract: 0.0 / count 0 / masked row for every agg,
    and ``lax.top_k``'s total-order tie-break). Returns ``(table,
    mask)`` in numpy."""
    cap = len(next(iter(cols.values())))
    mask = np.arange(cap) < n_rows
    table = {k: np.asarray(v) for k, v in cols.items()}
    for node in plan:
        if isinstance(node, Filter):
            x = table[node.column]
            if np.issubdtype(x.dtype, np.integer):
                # exact: int32 values and the host-side threshold both
                # embed in float64 (mirrors the kernel's _int_pred)
                mask = mask & _CMP[node.op](x.astype(np.float64),
                                            np.float64(node.value))
            else:
                mask = mask & _CMP[node.op](x.astype(np.float32),
                                            np.float32(node.value))
        elif isinstance(node, Project):
            table = {c: table[c] for c in node.columns}
        elif isinstance(node, (GroupBy, WindowAgg, MultiGroupBy)):
            out, cnt = _np_aggregate(table, mask, node)
            table, mask = _np_seg_table(node, out, cnt)
        elif isinstance(node, TopK):
            score = np.where(mask, table[node.by].astype(np.float32),
                             -np.inf)
            if not node.largest:
                score = np.where(np.isfinite(score), -score, score)
            kk = min(node.k, len(score))
            idx = _np_topk_idx(score, kk)
            top = score[idx]
            table = {c: np.take(table[c], idx, axis=0) for c in table}
            table["index"] = idx
            mask = np.isfinite(top)
        else:
            raise TypeError(f"unknown plan node {node!r}")
    return table, mask

"""Warehouse tiering + persistence.

Hot tier: the fp32 ``SegmentStore`` chunks that queries touch most.
Cold tier: older chunks spilled to int8 with one quantization scale per
chunk (reusing ``distribution.compression.quantize_int8``, so the cold
tier inherits its stochastic-rounding error bound: per-element error is
at most the chunk's scale = max|x|/127). Integer columns spill
losslessly. ``spill`` moves whole chunks so every tier keeps
chunk-aligned shapes and the jit executables stay shared.

Queries run over BOTH tiers: ``materialize`` dequantizes the cold
chunks and concatenates them in front of the hot columns in one jitted
device op, and the compiled query kernel scans the combined table —
fp32-exact on the hot rows, within quantization tolerance on cold ones.

``save_warehouse``/``load_warehouse`` persist the whole thing through
``checkpoint/ckpt.py`` (atomic, mesh-agnostic, host-count independent),
so a warehouse survives process restart onto any topology: the hot tier
round-trips bit-exact (raw fp32 bytes), the cold tier's int8 codes and
scales likewise.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.distribution.compression import dequantize, quantize_int8
from repro.warehouse.store import SegmentStore


@functools.partial(jax.jit, static_argnames=("n", "chunk"))
def _quantize_chunks(cols, key, *, n: int, chunk: int):
    """Quantize the first ``n`` rows (a whole number of chunks) of every
    float column to int8 with a per-chunk scale; integer columns pass
    through. Output/embedding rows quantize with their chunk flattened
    so the (chunk, D) block shares one scale."""
    n_chunks = n // chunk
    keys = jax.random.split(key, n_chunks)
    q, scales, ints = {}, {}, {}
    for name, col in cols.items():
        block = col[:n]
        if col.dtype == jnp.float32:
            flat = block.reshape(n_chunks, -1)
            qq, ss = jax.vmap(quantize_int8)(flat, keys)
            q[name] = qq.reshape(block.shape)
            scales[name] = ss
        else:
            ints[name] = block
    return q, scales, ints


@functools.partial(jax.jit, static_argnames=("n_spill",))
def _compact(cols, *, n_spill: int):
    """Drop the spilled prefix from the hot tier: shift the survivors to
    row 0 and zero the tail (capacity unchanged)."""
    return {k: jnp.concatenate(
        [v[n_spill:], jnp.zeros((n_spill,) + v.shape[1:], v.dtype)])
        for k, v in cols.items()}


@functools.partial(jax.jit, static_argnames=("chunk",))
def _materialize(cold_q, cold_scales, cold_int, hot_cols, *, chunk: int):
    """Combined view for the query kernel: dequantized cold rows
    followed by the hot columns, one device op."""
    out = {}
    for name, hot in hot_cols.items():
        if name in cold_q:
            qq = cold_q[name]
            n_chunks = qq.shape[0] // chunk
            deq = jax.vmap(dequantize)(qq.reshape(n_chunks, -1),
                                       cold_scales[name])
            cold = deq.reshape(qq.shape).astype(hot.dtype)
        else:
            cold = cold_int[name]
        out[name] = jnp.concatenate([cold, hot])
    return out


class TieredStore:
    """A ``SegmentStore`` hot tier plus an int8 cold tier it spills to."""

    def __init__(self, hot: SegmentStore, seed: int = 0):
        self.hot = hot
        self.seed = int(seed)
        self.n_cold = 0
        self.cold_q: Dict[str, jnp.ndarray] = {}
        self.cold_scales: Dict[str, jnp.ndarray] = {}
        self.cold_int: Dict[str, jnp.ndarray] = {}
        # memoized combined view; keyed on the hot columns object (every
        # append/spill replaces that dict) + the cold row count
        self._mat_cache = None

    @property
    def n_rows(self) -> int:
        return self.n_cold + self.hot.n_rows

    @property
    def t_max(self) -> int:
        return self.hot.t_max

    def spill(self, keep_hot: int) -> int:
        """Move the oldest whole chunks to the cold tier until at most
        ``keep_hot`` rows (rounded up to a chunk) stay hot. Returns the
        number of rows spilled."""
        # keep_hot >= 0 keeps n_spill <= n_rows: capacity padding can
        # never enter the cold tier as phantom data
        assert keep_hot >= 0, keep_hot
        chunk = self.hot.chunk_rows
        n_spill = ((self.hot.n_rows - keep_hot) // chunk) * chunk
        if n_spill <= 0:
            return 0
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self.n_cold)
        q, scales, ints = _quantize_chunks(self.hot.columns, key,
                                           n=n_spill, chunk=chunk)
        if self.n_cold:
            q = {k: jnp.concatenate([self.cold_q[k], v])
                 for k, v in q.items()}
            scales = {k: jnp.concatenate([self.cold_scales[k], v])
                      for k, v in scales.items()}
            ints = {k: jnp.concatenate([self.cold_int[k], v])
                    for k, v in ints.items()}
        self.cold_q, self.cold_scales, self.cold_int = q, scales, ints
        self.n_cold += n_spill
        self.hot.columns = _compact(self.hot.columns, n_spill=n_spill)
        self.hot.n_rows -= n_spill
        return n_spill

    def materialize(self) -> Tuple[Dict[str, jnp.ndarray], int]:
        """(columns, n_rows) spanning both tiers — what the compiled
        query kernel scans. Valid rows stay a prefix: cold rows are
        oldest-first, hot live rows are a prefix of the hot arrays.
        Memoized: repeat queries between appends/spills reuse the
        combined view instead of re-dequantizing the cold tier."""
        if self.n_cold == 0:
            return self.hot.columns, self.hot.n_rows
        c = self._mat_cache
        if c is not None and c[0] is self.hot.columns \
                and c[1] == self.n_cold:
            return c[2], self.n_rows
        cols = _materialize(self.cold_q, self.cold_scales, self.cold_int,
                            self.hot.columns, chunk=self.hot.chunk_rows)
        self._mat_cache = (self.hot.columns, self.n_cold, cols)
        return cols, self.n_rows

    def query(self, plan):
        from repro.warehouse import query as Q
        return Q.execute(self, plan)

    def max_cold_scale(self) -> float:
        """Largest per-chunk quantization scale across the cold tier —
        the per-element error bound of cold-row values."""
        if not self.cold_scales:
            return 0.0
        return max(float(jnp.max(s)) for s in self.cold_scales.values())

    def __repr__(self) -> str:
        return (f"TieredStore(hot={self.hot.n_rows}, cold={self.n_cold}, "
                f"chunk={self.hot.chunk_rows})")


# ---------------------------------------------------------------------------
# persistence (through checkpoint/ckpt.py)
# ---------------------------------------------------------------------------

def save_warehouse(path: str, ts: TieredStore) -> str:
    """Atomic save of both tiers; restores onto any host/topology."""
    tree = {"hot": ts.hot.columns}
    if ts.n_cold:
        tree["cold"] = {"q": ts.cold_q, "scales": ts.cold_scales,
                        "ints": ts.cold_int}
    meta = {"n_rows": ts.hot.n_rows, "t_max": ts.hot.t_max,
            "out_dim": ts.hot.out_dim, "chunk_rows": ts.hot.chunk_rows,
            "n_cold": ts.n_cold, "seed": ts.seed}
    return ckpt.save(path, tree, meta=meta)


def load_warehouse(path: str) -> TieredStore:
    tree, meta = ckpt.restore(path, return_meta=True)
    assert meta is not None, f"{path} is not a warehouse checkpoint"
    hot = SegmentStore(meta["out_dim"], chunk_rows=meta["chunk_rows"])
    hot.columns = tree["hot"]
    hot.n_rows = meta["n_rows"]
    hot.t_max = meta["t_max"]
    ts = TieredStore(hot, seed=meta["seed"])
    ts.n_cold = meta["n_cold"]
    if ts.n_cold:
        ts.cold_q = tree["cold"]["q"]
        ts.cold_scales = tree["cold"]["scales"]
        ts.cold_int = tree["cold"]["ints"]
    return ts

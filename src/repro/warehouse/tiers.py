"""Warehouse tiering + persistence.

Hot tier: the fp32 ``SegmentStore`` chunks that queries touch most.
Cold tier: older chunks spilled to int8 with one quantization scale per
chunk (reusing ``distribution.compression.quantize_int8``, so the cold
tier inherits its stochastic-rounding error bound: per-element error is
at most the chunk's scale = max|x|/127). Integer columns spill
losslessly. ``spill`` moves whole chunks so every tier keeps
chunk-aligned shapes and the jit executables stay shared.

Queries run over BOTH tiers: ``materialize`` dequantizes the cold
chunks and concatenates them in front of the hot columns in one jitted
device op, and the compiled query kernel scans the combined table —
fp32-exact on the hot rows, within quantization tolerance on cold ones.

``save_warehouse``/``load_warehouse`` persist the whole thing through
``checkpoint/ckpt.py`` (atomic, mesh-agnostic, host-count independent),
so a warehouse survives process restart onto any topology: the hot tier
round-trips bit-exact (raw fp32 bytes), the cold tier's int8 codes and
scales likewise.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.distribution.compression import dequantize, quantize_int8
from repro.obs.telemetry import StoreTelemetry
from repro.warehouse.store import SegmentStore, ShardedStore, _bucket_cap


def _tier_obs_init():
    """Host-side tier counters (see ``telemetry()``): chunk spills and
    cold-tier dequantize (materialize cache-miss) events."""
    return {"spill_events": 0, "spilled_rows": 0, "dequantize_events": 0}


@functools.partial(jax.jit, static_argnames=("n", "chunk"))
def _quantize_chunks(cols, key, *, n: int, chunk: int):
    """Quantize the first ``n`` rows (a whole number of chunks) of every
    float column to int8 with a per-chunk scale; integer columns pass
    through. Output/embedding rows quantize with their chunk flattened
    so the (chunk, D) block shares one scale."""
    n_chunks = n // chunk
    keys = jax.random.split(key, n_chunks)
    q, scales, ints = {}, {}, {}
    for name, col in cols.items():
        block = col[:n]
        if col.dtype == jnp.float32:
            flat = block.reshape(n_chunks, -1)
            qq, ss = jax.vmap(quantize_int8)(flat, keys)
            q[name] = qq.reshape(block.shape)
            scales[name] = ss
        else:
            ints[name] = block
    return q, scales, ints


@functools.partial(jax.jit, static_argnames=("n_spill",))
def _compact(cols, *, n_spill: int):
    """Drop the spilled prefix from the hot tier: shift the survivors to
    row 0 and zero the tail (capacity unchanged)."""
    return {k: jnp.concatenate(
        [v[n_spill:], jnp.zeros((n_spill,) + v.shape[1:], v.dtype)])
        for k, v in cols.items()}


@functools.partial(jax.jit, static_argnames=("chunk",))
def _materialize(cold_q, cold_scales, cold_int, hot_cols, *, chunk: int):
    """Combined view for the query kernel: dequantized cold rows
    followed by the hot columns, one device op."""
    out = {}
    for name, hot in hot_cols.items():
        if name in cold_q:
            qq = cold_q[name]
            n_chunks = qq.shape[0] // chunk
            deq = jax.vmap(dequantize)(qq.reshape(n_chunks, -1),
                                       cold_scales[name])
            cold = deq.reshape(qq.shape).astype(hot.dtype)
        else:
            cold = cold_int[name]
        out[name] = jnp.concatenate([cold, hot])
    return out


class TieredStore:
    """A ``SegmentStore`` hot tier plus an int8 cold tier it spills to."""

    def __init__(self, hot: SegmentStore, seed: int = 0):
        self.hot = hot
        self.seed = int(seed)
        self.n_cold = 0
        self.cold_q: Dict[str, jnp.ndarray] = {}
        self.cold_scales: Dict[str, jnp.ndarray] = {}
        self.cold_int: Dict[str, jnp.ndarray] = {}
        # memoized combined view; keyed on the hot columns object (every
        # append/spill replaces that dict) + the cold row count
        self._mat_cache = None
        self.tier_obs = _tier_obs_init()

    @property
    def n_rows(self) -> int:
        return self.n_cold + self.hot.n_rows

    @property
    def t_max(self) -> int:
        return self.hot.t_max

    def spill(self, keep_hot: int) -> int:
        """Move the oldest whole chunks to the cold tier until at most
        ``keep_hot`` rows (rounded up to a chunk) stay hot. Returns the
        number of rows spilled.

        Standing queries (``warehouse.standing``) are spill-invariant:
        every row's exact fp32 contribution folded into the stored
        partials when the row was INGESTED, so demoting rows to int8
        afterwards cannot touch a registered answer — only rescans (and
        backfills of queries registered after the spill) see the
        quantized values."""
        # keep_hot >= 0 keeps n_spill <= n_rows: capacity padding can
        # never enter the cold tier as phantom data
        assert keep_hot >= 0, keep_hot
        chunk = self.hot.chunk_rows
        n_spill = ((self.hot.n_rows - keep_hot) // chunk) * chunk
        if n_spill <= 0:
            return 0
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self.n_cold)
        q, scales, ints = _quantize_chunks(self.hot.columns, key,
                                           n=n_spill, chunk=chunk)
        if self.n_cold:
            q = {k: jnp.concatenate([self.cold_q[k], v])
                 for k, v in q.items()}
            scales = {k: jnp.concatenate([self.cold_scales[k], v])
                      for k, v in scales.items()}
            ints = {k: jnp.concatenate([self.cold_int[k], v])
                    for k, v in ints.items()}
        self.cold_q, self.cold_scales, self.cold_int = q, scales, ints
        self.n_cold += n_spill
        self.hot.columns = _compact(self.hot.columns, n_spill=n_spill)
        self.hot.n_rows -= n_spill
        self.tier_obs["spill_events"] += 1
        self.tier_obs["spilled_rows"] += n_spill
        return n_spill

    def materialize(self) -> Tuple[Dict[str, jnp.ndarray], int]:
        """(columns, n_rows) spanning both tiers — what the compiled
        query kernel scans. Valid rows stay a prefix: cold rows are
        oldest-first, hot live rows are a prefix of the hot arrays.
        Memoized: repeat queries between appends/spills reuse the
        combined view instead of re-dequantizing the cold tier."""
        if self.n_cold == 0:
            return self.hot.columns, self.hot.n_rows
        c = self._mat_cache
        if c is not None and c[0] is self.hot.columns \
                and c[1] == self.n_cold:
            return c[2], self.n_rows
        cols = _materialize(self.cold_q, self.cold_scales, self.cold_int,
                            self.hot.columns, chunk=self.hot.chunk_rows)
        self._mat_cache = (self.hot.columns, self.n_cold, cols)
        self.tier_obs["dequantize_events"] += 1
        return cols, self.n_rows

    @property
    def standing(self):
        """The hot store's ``StandingQueries`` registry (None until one
        is attached — ``StandingQueries(tiered_store)`` attaches to the
        hot tier, whose ingest kernels do the folding, while backfills
        scan this wrapper's two-tier view)."""
        return self.hot.standing

    def query(self, plan, **kw):
        from repro.warehouse import query as Q
        self.hot.obs["query_dispatches"] += 1
        return Q.execute(self, plan, **kw)

    def telemetry(self) -> StoreTelemetry:
        """Hot-tier flight recorder merged with the tier counters:
        total rows span both tiers; spills/dequantizes count cold-tier
        movement (a dequantize event = a materialize cache miss)."""
        import dataclasses
        return dataclasses.replace(
            self.hot.telemetry(),
            rows_by_shard=np.asarray([self.n_rows]), **self.tier_obs)

    def max_cold_scale(self) -> float:
        """Largest per-chunk quantization scale across the cold tier —
        the per-element error bound of cold-row values."""
        if not self.cold_scales:
            return 0.0
        return max(float(jnp.max(s)) for s in self.cold_scales.values())

    def __repr__(self) -> str:
        return (f"TieredStore(hot={self.hot.n_rows}, cold={self.n_cold}, "
                f"chunk={self.hot.chunk_rows})")


# ---------------------------------------------------------------------------
# sharded tiering: every shard spills its own oldest chunks
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "chunk"))
def _quantize_chunks_sharded(cols, key, *, n: int, chunk: int):
    """Per-shard ``_quantize_chunks``: quantize the first ``n`` rows of
    every shard's block with one scale per (shard, chunk)."""
    n_shards = next(iter(cols.values())).shape[0]
    keys = jax.random.split(key, n_shards)
    return jax.vmap(lambda c, k: _quantize_chunks(c, k, n=n,
                                                  chunk=chunk))(cols, keys)


@jax.jit
def _cold_write(dst, src, off):
    """Append each shard's spill block at that shard's own cold offset
    (``dst``/``src`` are dicts of (S, cap, ...) / (S, n, ...) arrays;
    ``off`` is (S,) int32). Rows past a shard's real spill depth are
    junk until a later spill overwrites them — they sit beyond the
    shard's valid cold count, so queries never see them."""
    def one(d, s, o):
        idx = (o,) + (0,) * (s.ndim - 1)
        return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), idx)

    return {k: jax.vmap(one)(dst[k], src[k], off) for k in dst}


@jax.jit
def _compact_ragged(cols, d):
    """Drop the first ``d_s`` rows of every shard's hot block (per-shard
    dynamic depth), shifting survivors to row 0 and zero-filling the
    tail (capacity unchanged)."""
    def one(cols_s, d_s):
        def shift(v):
            idx = jnp.arange(v.shape[0]) + d_s
            return jnp.take(v, idx, axis=0, mode="fill", fill_value=0)

        return {k: shift(v) for k, v in cols_s.items()}

    return jax.vmap(one)(cols, d)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _materialize_sharded(cold_q, cold_scales, cold_int, hot_cols, c, *,
                         chunk: int):
    """Combined two-tier view with per-shard cold depths: dequantize
    every shard's cold block, then land the hot block at that shard's
    own cold-valid offset ``c_s`` — so each shard's valid rows stay a
    prefix (c_s cold rows, then its hot rows) whatever the imbalance."""
    def one(q, s, i, h, c_s):
        out = {}
        for name, hot in h.items():
            if name in q:
                qq = q[name]
                n_chunks = qq.shape[0] // chunk
                deq = jax.vmap(dequantize)(qq.reshape(n_chunks, -1),
                                           s[name])
                cold = deq.reshape(qq.shape).astype(hot.dtype)
            else:
                cold = i[name]
            dst = jnp.concatenate([cold, jnp.zeros_like(hot)])
            idx = (c_s,) + (0,) * (hot.ndim - 1)
            out[name] = jax.lax.dynamic_update_slice(dst, hot, idx)
        return out

    return jax.vmap(one)(cold_q, cold_scales, cold_int, hot_cols, c)


class ShardedTieredStore:
    """Hot/cold tiering over a ``ShardedStore``: the spill is PER SHARD
    and RAGGED — each shard quantizes however many of its own oldest
    whole chunks exceed ``keep_hot`` (its own scales, one vmapped
    dispatch over the stacked shard axis), so an imbalanced or even
    permanently-empty shard never blocks the others from spilling.
    Cold blocks live in one capacity-padded stacked array with a
    per-shard valid depth; each shard's materialized rows are its valid
    cold rows followed by its hot rows (a per-shard-offset
    ``dynamic_update_slice``), keeping validity a prefix, and queries
    span both tiers through the same ONE-dispatch sharded partial/merge
    engine."""

    def __init__(self, hot: ShardedStore, seed: int = 0):
        self.hot = hot
        self.seed = int(seed)
        self._spills = 0
        self.n_cold_by_shard = np.zeros(hot.n_shards, np.int64)
        self.cold_q: Dict[str, jnp.ndarray] = {}
        self.cold_scales: Dict[str, jnp.ndarray] = {}
        self.cold_int: Dict[str, jnp.ndarray] = {}
        self._mat_cache = None
        self.tier_obs = _tier_obs_init()

    @property
    def n_shards(self) -> int:
        return self.hot.n_shards

    @property
    def mesh(self):
        return self.hot.mesh

    @property
    def n_rows(self) -> int:
        return int(self.n_cold_by_shard.sum()) + self.hot.n_rows

    @property
    def t_max(self) -> int:
        return self.hot.t_max

    @property
    def cold_capacity(self) -> int:
        return self.cold_q["quality"].shape[1] if self.cold_q else 0

    def _cold_reserve(self, need: int) -> None:
        """Grow the stacked cold arrays to fit the deepest shard's cold
        depth — on the same bucketed capacity ladder as the stores
        (``_bucket_cap``), so cold-tier growth never mints a new shape
        for the spill/materialize kernels either."""
        cap = self.cold_capacity
        if need <= cap:
            return
        chunk = self.hot.chunk_rows
        new_cap = _bucket_cap(need, chunk)

        def grow(tree, cap_units, unit):
            pad = (new_cap // unit) - cap_units
            return {k: jnp.pad(v, ((0, 0), (0, pad))
                               + ((0, 0),) * (v.ndim - 2))
                    for k, v in tree.items()}

        if not self.cold_q:     # first spill: build from the hot schema
            S = self.n_shards
            for name, col in self.hot.columns.items():
                tail = col.shape[2:]
                if col.dtype == jnp.float32:
                    self.cold_q[name] = jnp.zeros((S, new_cap) + tail,
                                                  jnp.int8)
                    self.cold_scales[name] = jnp.zeros(
                        (S, new_cap // chunk), jnp.float32)
                else:
                    self.cold_int[name] = jnp.zeros((S, new_cap) + tail,
                                                    col.dtype)
            return
        self.cold_q = grow(self.cold_q, cap, 1)
        self.cold_int = grow(self.cold_int, cap, 1)
        self.cold_scales = grow(self.cold_scales, cap // chunk, chunk)

    def spill(self, keep_hot: int) -> int:
        """Move each shard's oldest whole chunks to its cold tier until
        at most ``keep_hot`` rows (rounded up to a chunk) stay hot on
        that shard — depths are ragged across shards, so imbalanced or
        empty shards never block the rest. Returns total rows spilled.

        Spill-invariant for standing queries, exactly as on
        ``TieredStore.spill``: contributions folded at ingest, so the
        stored partials never see the quantization."""
        # keep_hot >= 0 keeps every depth <= that shard's live rows:
        # capacity padding can never enter the cold tier as phantom data
        assert keep_hot >= 0, keep_hot
        chunk = self.hot.chunk_rows
        d = np.maximum(
            ((self.hot.n_rows_by_shard - keep_hot) // chunk) * chunk, 0)
        d_max = int(d.max())
        if d_max <= 0:
            return 0
        # reserve the full d_max write window past EVERY shard's offset
        # (not just its own depth d_s): _cold_write lands a d_max-row
        # block at each shard's offset, and dynamic_update_slice CLAMPS
        # an out-of-range start backward — an unreserved junk tail would
        # silently overwrite the deepest shard's valid cold rows
        self._cold_reserve(int((self.n_cold_by_shard + d_max).max()))
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 self._spills)
        self._spills += 1
        # quantize the deepest depth on EVERY shard (static shape); a
        # shard whose own depth is smaller writes the extra rows as
        # junk past its valid cold count, where later spills overwrite
        # them — they are never queried and its hot copy stays live
        q, scales, ints = _quantize_chunks_sharded(
            self.hot.columns, key, n=d_max, chunk=chunk)
        off = jnp.asarray(self.n_cold_by_shard, jnp.int32)
        self.cold_q = _cold_write(self.cold_q, q, off)
        self.cold_int = _cold_write(self.cold_int, ints, off)
        self.cold_scales = _cold_write(self.cold_scales, scales,
                                       off // chunk)
        d_dev = jnp.asarray(d, jnp.int32)
        self.hot.columns = _compact_ragged(self.hot.columns, d_dev)
        self.hot.n_rows_by_shard = self.hot.n_rows_by_shard - d
        self.hot.n_rows_dev = self.hot.n_rows_dev - d_dev
        self.n_cold_by_shard += d
        self.tier_obs["spill_events"] += 1
        self.tier_obs["spilled_rows"] += int(d.sum())
        return int(d.sum())

    def shard_source(self):
        """(stacked columns spanning both tiers, per-shard valid counts):
        each shard's rows are its valid cold rows followed by its hot
        rows, so valid rows stay a per-shard prefix. Memoized like
        ``TieredStore.materialize``."""
        if not self.n_cold_by_shard.any():
            return self.hot.shard_source()
        cold_key = tuple(self.n_cold_by_shard)
        c = self._mat_cache
        off = jnp.asarray(self.n_cold_by_shard, jnp.int32)
        if c is not None and c[0] is self.hot.columns \
                and c[1] == cold_key:
            return c[2], off + self.hot.n_rows_dev
        cols = _materialize_sharded(self.cold_q, self.cold_scales,
                                    self.cold_int, self.hot.columns,
                                    off, chunk=self.hot.chunk_rows)
        self._mat_cache = (self.hot.columns, cold_key, cols)
        self.tier_obs["dequantize_events"] += 1
        return cols, off + self.hot.n_rows_dev

    @property
    def standing(self):
        """The hot store's ``StandingQueries`` registry (see
        ``TieredStore.standing``)."""
        return self.hot.standing

    def query(self, plan, **kw):
        from repro.warehouse import query as Q
        self.hot.obs["query_dispatches"] += 1
        return Q.execute_sharded(self, plan, **kw)

    def telemetry(self) -> StoreTelemetry:
        """Per-shard balance spans BOTH tiers (hot + that shard's cold
        depth), so the imbalance factor reflects where rows actually
        live, not just the hot residue after spills."""
        import dataclasses
        return dataclasses.replace(
            self.hot.telemetry(),
            rows_by_shard=(self.hot.n_rows_by_shard
                           + self.n_cold_by_shard),
            **self.tier_obs)

    def max_cold_scale(self) -> float:
        """Largest per-(shard, chunk) quantization scale across the cold
        tier — the per-element error bound of cold-row values."""
        if not self.cold_scales:
            return 0.0
        return max(float(jnp.max(s)) for s in self.cold_scales.values())

    def __repr__(self) -> str:
        return (f"ShardedTieredStore(shards={self.n_shards}, "
                f"hot={self.hot.n_rows_by_shard.tolist()}, "
                f"cold={self.n_cold_by_shard.tolist()}, "
                f"chunk={self.hot.chunk_rows})")


# ---------------------------------------------------------------------------
# persistence (through checkpoint/ckpt.py)
# ---------------------------------------------------------------------------

def save_warehouse(path: str, ts: TieredStore) -> str:
    """Atomic save of both tiers; restores onto any host/topology."""
    tree = {"hot": ts.hot.columns}
    if ts.n_cold:
        tree["cold"] = {"q": ts.cold_q, "scales": ts.cold_scales,
                        "ints": ts.cold_int}
    meta = {"n_rows": ts.hot.n_rows, "t_max": ts.hot.t_max,
            "out_dim": ts.hot.out_dim, "chunk_rows": ts.hot.chunk_rows,
            "n_cold": ts.n_cold, "seed": ts.seed}
    return ckpt.save(path, tree, meta=meta)


def load_warehouse(path: str) -> TieredStore:
    """Restore a ``save_warehouse`` checkpoint into a fresh hot
    ``SegmentStore`` wrapped in a ``TieredStore`` (cold tier re-attached
    from the saved metadata)."""
    tree, meta = ckpt.restore(path, return_meta=True)
    assert meta is not None, f"{path} is not a warehouse checkpoint"
    hot = SegmentStore(meta["out_dim"], chunk_rows=meta["chunk_rows"])
    hot.columns = tree["hot"]
    hot.n_rows = meta["n_rows"]
    hot.t_max = meta["t_max"]
    ts = TieredStore(hot, seed=meta["seed"])
    ts.n_cold = meta["n_cold"]
    if ts.n_cold:
        ts.cold_q = tree["cold"]["q"]
        ts.cold_scales = tree["cold"]["scales"]
        ts.cold_int = tree["cold"]["ints"]
    return ts


# ---- cache probes + static-analysis registry -------------------------------
from repro.analysis.registry import example_builder, register_engine  # noqa: E402
from repro.core.switcher import register_cache_probe  # noqa: E402

register_cache_probe(
    "warehouse_tiers",
    lambda: (_quantize_chunks._cache_size() + _compact._cache_size()
             + _materialize._cache_size()))
register_cache_probe(
    "warehouse_tiers_sharded",
    lambda: (_quantize_chunks_sharded._cache_size()
             + _cold_write._cache_size() + _compact_ragged._cache_size()
             + _materialize_sharded._cache_size()))

register_engine("tiers_quantize", example_builder("tiers_quantize"),
                probe=lambda: _quantize_chunks._cache_size(),
                covers=("repro.warehouse.tiers:_quantize_chunks",),
                probe_name="warehouse_tiers")
register_engine("tiers_compact", example_builder("tiers_compact"),
                probe=lambda: _compact._cache_size(),
                covers=("repro.warehouse.tiers:_compact",),
                probe_name="warehouse_tiers")
register_engine("tiers_materialize", example_builder("tiers_materialize"),
                probe=lambda: _materialize._cache_size(),
                covers=("repro.warehouse.tiers:_materialize",),
                probe_name="warehouse_tiers")
register_engine("tiers_quantize_sharded",
                example_builder("tiers_quantize_sharded"),
                probe=lambda: _quantize_chunks_sharded._cache_size(),
                covers=("repro.warehouse.tiers:_quantize_chunks_sharded",),
                probe_name="warehouse_tiers_sharded")
# the CLIP scatters in _cold_write / _materialize_sharded are vmapped
# dynamic_update_slice — start-index clamping is that op's documented
# semantics (offsets are cumulative cold depths, in range by
# construction), not an out-of-bounds footgun, so the clip ban is
# waived for exactly these two engines.
register_engine("tiers_cold_write", example_builder("tiers_cold_write"),
                invariants={"no_clip_scatter": False},
                probe=lambda: _cold_write._cache_size(),
                covers=("repro.warehouse.tiers:_cold_write",),
                probe_name="warehouse_tiers_sharded")
register_engine("tiers_compact_ragged",
                example_builder("tiers_compact_ragged"),
                probe=lambda: _compact_ragged._cache_size(),
                covers=("repro.warehouse.tiers:_compact_ragged",),
                probe_name="warehouse_tiers_sharded")
register_engine("tiers_materialize_sharded",
                example_builder("tiers_materialize_sharded"),
                invariants={"no_clip_scatter": False},
                probe=lambda: _materialize_sharded._cache_size(),
                covers=("repro.warehouse.tiers:_materialize_sharded",),
                probe_name="warehouse_tiers_sharded")

"""Architecture config dataclasses.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published numbers) and relying on ``reduced()`` for
CPU smoke tests. ``registry()`` maps arch-id -> ArchConfig.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    n_experts: int = 8
    top_k: int = 2
    # capacity factor for dropping-style dispatch (dry-run realistic comms)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    d_inner: int = 0          # 0 -> 2*d_model
    chunk: int = 256          # SSD chunk length
    n_groups: int = 1
    conv_width: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"       # swiglu | relu2 | gelu
    window: Optional[int] = None          # sliding-window attention size
    global_layers: Tuple[int, ...] = ()   # layers with full attention (hybrid)
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # enc-dec (whisper)
    n_enc_layers: int = 0
    max_target_len: int = 448
    # modality frontend stub: none | patch | audio
    frontend: str = "none"
    frontend_tokens: int = 0   # prepended stub-embedding positions
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.d_inner or 2 * self.d_model

    @property
    def ssm_heads(self) -> int:
        if self.ssm is None:
            return 0
        return self.d_inner // self.ssm.head_dim

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            window=min(self.window, 32) if self.window else None,
            global_layers=(0,) if self.global_layers else (),
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
        )
        if self.moe is not None:
            kw["moe"] = MoECfg(n_experts=4, top_k=2)
        if self.ssm is not None:
            kw["ssm"] = SSMCfg(d_state=16, head_dim=16, d_inner=128, chunk=16)
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["max_target_len"] = 16
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for MODEL_FLOPS = 6·N·D) -------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd
        n = 0
        if self.family == "ssm":
            n += self._ssm_layer_params() * self.n_layers
        elif self.family == "hybrid":
            n += (self._attn_params() + self._ssm_layer_params(hybrid=True)
                  + self._mlp_params()) * self.n_layers
        else:
            per_layer = self._attn_params() + self._mlp_params(active_only)
            n += per_layer * self.n_layers
        if self.n_enc_layers:
            # encoder layers: full attention + mlp (dense)
            enc = (4 * d * d) + self._mlp_params()
            # decoder adds cross-attention
            n += (self.n_enc_layers * enc) + (4 * d * d) * self.n_layers
        n += self.vocab * d * (1 if self.tie_embeddings else 2)
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def _mlp_params(self, active_only: bool = False) -> int:
        d = self.d_model
        mult = 3 if self.mlp == "swiglu" else 2
        dense = mult * d * self.d_ff
        if self.moe is None:
            return dense
        e = self.moe.top_k if active_only else self.moe.n_experts
        return e * dense + d * self.moe.n_experts  # + router

    def _ssm_layer_params(self, hybrid: bool = False) -> int:
        d = self.d_model
        di = self.d_inner if not hybrid else self.n_heads * self.hd
        s = self.ssm
        nh = di // s.head_dim
        in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
        out_proj = di * d
        conv = s.conv_width * (di + 2 * s.n_groups * s.d_state)
        extra = (0 if hybrid else 2 * d * self.d_ff)  # pure-ssm has no sep. mlp
        return in_proj + out_proj + conv + nh + extra * 0


_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def registry():
    # import all arch modules for side effect
    from repro.configs import (  # noqa: F401
        internvl2_26b, nemotron_4_15b, qwen1_5_0_5b, llama3_8b, qwen1_5_110b,
        hymba_1_5b, mamba2_370m, mixtral_8x7b, mixtral_8x22b, whisper_large_v3,
    )
    return dict(_REGISTRY)


def get(name: str) -> ArchConfig:
    return registry()[name]

"""mamba2-370m [ssm] — SSD (state-space duality), attn-free [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMCfg, register

CONFIG = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    d_ff=0,             # no separate MLP; mamba block only
    vocab=50280,
    ssm=SSMCfg(d_state=128, head_dim=64, d_inner=2048, chunk=256, n_groups=1),
    tie_embeddings=True,
    source="arXiv:2405.21060",
))

"""hymba-1.5b [hybrid] — parallel attn+mamba heads, SWA with 3 global
full-attention layers [arXiv:2411.13676]. Meta tokens omitted (noted in
DESIGN.md — irrelevant to the scheduling layer under study)."""
from repro.configs.base import ArchConfig, SSMCfg, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    mlp="swiglu",
    window=1024,
    global_layers=(0, 15, 31),   # full attention; rest use SWA
    ssm=SSMCfg(d_state=16, head_dim=64, d_inner=1600, chunk=256, n_groups=1),
    source="arXiv:2411.13676",
))

from repro.configs.base import ArchConfig, MoECfg, SSMCfg, get, registry
from repro.configs.shapes import SHAPES, ShapeSpec, all_cells, applicable

__all__ = [
    "ArchConfig", "MoECfg", "SSMCfg", "get", "registry",
    "SHAPES", "ShapeSpec", "all_cells", "applicable",
]

"""The paper's own V-ETL workloads (§5.2), re-synthesized.

Each workload defines: the knob space (name -> domain), the synthetic
stream generator parameters (content categories with diurnal/spike
dynamics and per-(category, config) ground-truth quality), and the
resource provisioning grid used in Fig. 4 / Table 2.

Real sources (Shibuya streams, CMU-MOSEI, Twitch counts) are not
available offline; generators match the published statistics instead:
category dwell times (COVID 42 s, MOT 43 s, MOSEI-HIGH 30 s,
MOSEI-LONG 24 s), diurnal periodicity, and the HIGH/LONG spike shapes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class WorkloadCfg:
    name: str
    knobs: Dict[str, tuple]
    # latent content states ("easy"/"medium"/"hard"/...), their base rates
    n_latent: int
    dwell_seconds: float            # mean category dwell time (paper §5.3)
    diurnal: bool                   # day/night cycle (traffic cams)
    spike: str                      # none | high | long
    segment_seconds: float = 2.0    # knob switcher period (paper: 2 s)
    # UDF DAG: list of (task_name, deps, onprem_ms, cloud_ms, mb_in, mb_out)
    dag: Tuple = ()


# --- COVID: YOLOv5 detector + KCF tracker + homography (detect-to-track) ---
COVID = WorkloadCfg(
    name="covid",
    knobs={
        "frame_rate": (30, 15, 10, 5, 1),
        "det_interval": (1, 5, 30, 60),
        "tiling": (1, 4),            # 1x1 / 2x2 tiles
    },
    n_latent=3,
    dwell_seconds=42.0,
    diurnal=True,
    spike="none",
    dag=(
        ("decode", (), 1.6, 1.6, 0.0, 2.7),
        ("yolo", ("decode",), 86.0, 35.0, 0.20, 0.01),
        ("kcf", ("yolo",), 9.0, 6.0, 0.20, 0.01),
        ("homography", ("kcf",), 2.0, 2.0, 0.01, 0.01),
        ("mask_cls", ("yolo",), 30.0, 14.0, 0.05, 0.01),
    ),
)

# --- MOT: TransMOT graph-transformer tracker -------------------------------
MOT = WorkloadCfg(
    name="mot",
    knobs={
        "frame_rate": (30, 15, 10, 5),
        "tiling": (1, 4),
        "history": (1, 2, 3, 5),
        "model_size": ("small", "medium", "large"),
    },
    n_latent=3,
    dwell_seconds=43.0,
    diurnal=True,
    spike="none",
    dag=(
        ("decode", (), 1.6, 1.6, 0.0, 2.7),
        ("detect", ("decode",), 86.0, 35.0, 0.20, 0.02),
        ("embed", ("detect",), 40.0, 18.0, 0.10, 0.02),
        ("graph_tf", ("embed",), 120.0, 45.0, 0.05, 0.01),
    ),
)

# --- MOSEI: multimodal sentiment over many Twitch-like streams -------------
def _mosei(spike: str, dwell: float) -> WorkloadCfg:
    return WorkloadCfg(
        name=f"mosei-{spike}",
        knobs={
            "sent_skip": (0, 1, 2, 3, 4, 5, 6),
            "frac_frames": (1, 2, 3, 4, 5, 6),   # sixths of each sentence
            "model_size": ("small", "medium", "large"),
        },
        n_latent=5,
        dwell_seconds=dwell,
        diurnal=False,
        spike=spike,
        segment_seconds=7.0,   # paper: 7 s for MOSEI
        dag=(
            ("asr", (), 60.0, 30.0, 0.30, 0.01),
            ("glove", ("asr",), 5.0, 4.0, 0.01, 0.01),
            ("face", (), 70.0, 30.0, 0.20, 0.02),
            ("acoustic", (), 25.0, 12.0, 0.30, 0.01),
            ("fuse_cls", ("glove", "face", "acoustic"), 45.0, 20.0, 0.02, 0.01),
        ),
    )


MOSEI_HIGH = _mosei("high", 30.0)
MOSEI_LONG = _mosei("long", 24.0)

WORKLOADS = {w.name: w for w in (COVID, MOT, MOSEI_HIGH, MOSEI_LONG)}

# Fig. 4 provisioning grid: (vCPUs, USD/h) Google-Cloud-equivalents.
SERVER_GRID = ((4, 0.14), (8, 0.27), (16, 0.54), (32, 1.07), (60, 2.51))
ONPREM_DISCOUNT = 1.8        # App. L: cloud VM is 1.8x an on-prem core
CLOUD_COST_PER_CORE_S = 0.27 / 3600 / 8 * 1.8   # lambda-equivalent $/core-s

"""internvl2-26b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2 26B-class language backbone [arXiv:2404.16821]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    head_dim=128,
    mlp="swiglu",
    frontend="patch",
    frontend_tokens=256,   # stub patch embeddings prepended to the text
    source="arXiv:2404.16821",
))

"""Assigned input shapes and (arch x shape) applicability.

LM transformer shapes are seq_len x global_batch. ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``. ``long_500k`` requires sub-quadratic attention: run for
SSM / hybrid / SWA archs, skip (documented) for pure full-attention archs.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Archs whose attention cost/cache is sub-quadratic / bounded in seq_len:
# SSM (mamba2), hybrid (hymba: SWA + 3 global layers), SWA MoEs (mixtral).
SUBQUADRATIC = {"mamba2-370m", "hymba-1.5b", "mixtral-8x7b", "mixtral-8x22b"}


def applicable(arch: ArchConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return arch.name in SUBQUADRATIC
    return True


def skip_reason(arch: ArchConfig, shape: ShapeSpec) -> str:
    if shape.name == "long_500k" and arch.name not in SUBQUADRATIC:
        return ("pure full-attention arch: 500k-token decode needs a "
                "sub-quadratic attention mechanism (see DESIGN.md §5)")
    return ""


def all_cells():
    """Yield (arch_name, shape_name, runnable, reason) for all 40 cells."""
    from repro.configs.base import registry
    for aname, acfg in sorted(registry().items()):
        for sname, sspec in SHAPES.items():
            ok = applicable(acfg, sspec)
            yield aname, sname, ok, ("" if ok else skip_reason(acfg, sspec))

"""whisper-large-v3 [audio] — enc-dec transformer backbone; the conv audio
frontend is a STUB (input_specs() provides precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,          # decoder layers
    n_enc_layers=32,      # encoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,        # MHA
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    mlp="gelu",
    max_target_len=448,
    frontend="audio",
    source="arXiv:2212.04356",
))

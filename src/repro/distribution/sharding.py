"""Logical-axis sharding layer.

Models annotate params/activations with *logical* axes ("batch", "fsdp",
"tensor", "vocab", "expert", ...). This module resolves them to physical
mesh axes with divisibility-aware fallbacks:

- ``with_sharding_constraint`` tolerates uneven shardings, so activation
  constraints are applied whenever the mesh has the axis;
- ``in_shardings`` (param/cache arguments) must divide evenly, so
  ``spec_for`` drops any axis that does not divide the dimension.

Mesh is ambient (context manager) so model code stays mesh-agnostic and
runs unsharded on a single CPU device in tests.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred physical axes (in order; tuples mean "use all")
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "fsdp_pod": ("pod", "data"),   # opt-in: fully shard over pods too
    "tensor": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "cache_seq": ("model",),
    "seq": (),                     # sequence parallelism off by default
    None: (),
}


@dataclass
class ShardingCtx:
    mesh: Optional[Mesh] = None
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def axis_size(self, names: Tuple[str, ...]) -> int:
        if self.mesh is None:
            return 1
        s = 1
        for n in names:
            if n in self.mesh.shape:
                s *= self.mesh.shape[n]
        return s

    def physical(self, logical) -> Tuple[str, ...]:
        names = self.rules.get(logical, ())
        if self.mesh is None:
            return ()
        return tuple(n for n in names if n in self.mesh.shape)


_CTX = ShardingCtx()


@contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    global _CTX
    prev = _CTX
    r = dict(DEFAULT_RULES)
    if rules:
        r.update(rules)
    _CTX = ShardingCtx(mesh=mesh, rules=r)
    try:
        yield _CTX
    finally:
        _CTX = prev


def ctx() -> ShardingCtx:
    return _CTX


def _resolve(dim_axes: Sequence, shape=None, strict: bool = False) -> P:
    """logical per-dim axes -> PartitionSpec. strict=True enforces
    divisibility (required for in_shardings); non-strict keeps axes
    (with_sharding_constraint supports uneven)."""
    c = _CTX
    out = []
    for i, ax in enumerate(dim_axes):
        phys = c.physical(ax)
        if not phys:
            out.append(None)
            continue
        if strict and shape is not None:
            size = math.prod(c.mesh.shape[p] for p in phys)
            if shape[i] % size != 0:
                out.append(None)
                continue
        out.append(phys if len(phys) > 1 else phys[0])
    return P(*out)


def shard(x, *dim_axes):
    """Apply a logical sharding constraint to an activation (no-op without
    a mesh)."""
    c = _CTX
    if c.mesh is None:
        return x
    spec = _resolve(dim_axes, shape=getattr(x, "shape", None), strict=False)
    return jax.lax.with_sharding_constraint(x, NamedSharding(c.mesh, spec))


def spec_for(shape: Tuple[int, ...], dim_axes: Sequence, mesh: Mesh,
             rules: Optional[dict] = None) -> P:
    """Strict (divisible) PartitionSpec for a param/cache argument."""
    with use_mesh(mesh, rules):
        return _resolve(dim_axes, shape=shape, strict=True)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def row_sharding(mesh: Mesh, axis: str = "shard") -> NamedSharding:
    """Leading-axis row partitioning: a leaf's first dimension split over
    ``axis``, everything else replicated — the warehouse's stream-hash
    shard layout for its stacked (n_shards, cap, ...) columns."""
    return NamedSharding(mesh, P(axis))


def put_row_sharded(tree, mesh: Mesh, axis: str = "shard"):
    """device_put every leaf of ``tree`` with its leading axis
    partitioned over ``axis`` (see ``row_sharding``). Used by
    ``warehouse.ShardedStore`` to land columns on the shard mesh."""
    sh = row_sharding(mesh, axis)
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


# ---------------------------------------------------------------------------
# Param metadata: single source of truth for shape/dtype/init/logical axes.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Tuple                      # logical axis (or None) per dim
    init: str = "normal"             # normal | zeros | ones | ssm_a | dt_bias | embed
    dtype: str = "float32"
    fan_in_dims: Tuple[int, ...] = (0,)   # dims contracted at use (for scale)

    def sds(self):
        import jax.numpy as jnp
        return jax.ShapeDtypeStruct(self.shape, getattr(jnp, self.dtype))


def materialize(meta, key):
    """Initialize one param from its meta."""
    import jax.numpy as jnp
    dt = getattr(jnp, meta.dtype)
    if meta.init == "zeros":
        return jnp.zeros(meta.shape, dt)
    if meta.init == "ones":
        return jnp.ones(meta.shape, dt)
    if meta.init == "ssm_a":        # A_log: log of uniform [1, 16]
        u = jax.random.uniform(key, meta.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dt)
    if meta.init == "dt_bias":      # inverse-softplus of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, meta.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dt)
    if meta.init == "embed":
        return (jax.random.normal(key, meta.shape, jnp.float32) * 0.02).astype(dt)
    fan_in = math.prod(meta.shape[d] for d in meta.fan_in_dims) or 1
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, meta.shape, jnp.float32) * scale).astype(dt)


def init_tree(meta_tree, key):
    leaves, treedef = jax.tree.flatten(
        meta_tree, is_leaf=lambda x: isinstance(x, ParamMeta))
    keys = jax.random.split(key, len(leaves))
    vals = [materialize(m, k) for m, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(meta_tree):
    return jax.tree.map(lambda m: m.sds(), meta_tree,
                        is_leaf=lambda x: isinstance(x, ParamMeta))


def spec_tree(meta_tree, mesh, rules=None):
    return jax.tree.map(
        lambda m: spec_for(m.shape, m.axes, mesh, rules), meta_tree,
        is_leaf=lambda x: isinstance(x, ParamMeta))


def sharding_tree(meta_tree, mesh, rules=None):
    return jax.tree.map(
        lambda m: NamedSharding(mesh, spec_for(m.shape, m.axes, mesh, rules)),
        meta_tree, is_leaf=lambda x: isinstance(x, ParamMeta))

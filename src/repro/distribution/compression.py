"""Gradient compression for the cross-pod reduction.

int8 quantization with per-chunk scales + stochastic rounding + error
feedback (1-bit-Adam style, at 8 bits): the pod-level all-reduce moves
4x fewer bytes — the pod axis is the slowest link (DCN between pods),
so this shrinks the straggler-critical collective.

``compressed_psum`` runs inside shard_map over the 'pod' axis; the error
-feedback residual is carried in the optimizer state so compression
noise is unbiased over steps.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor scale, stochastic rounding. Returns (q int8, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    y = x / scale
    lo = jnp.floor(y)
    p = y - lo
    r = jax.random.uniform(key, x.shape)
    q = lo + (r < p).astype(jnp.float32)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str, key, err):
    """Quantize (x + err) to int8, psum across ``axis_name``, dequantize.
    Returns (mean-reduced value, new error residual)."""
    n = jax.lax.psum(1, axis_name)
    q, scale = quantize_int8(x + err, key)
    new_err = (x + err) - dequantize(q, scale)
    # int8 summed in int32 to avoid overflow; scales averaged
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    # each shard contributed with its own scale; approximate with the
    # mean scale (exact when shards share dynamic range)
    return total.astype(jnp.float32) * (scale_sum / n) / n, new_err


def compress_grads_across_pods(grads, err_tree, key, mesh):
    """shard_map wrapper: reduce gradient pytree across the 'pod' axis
    with int8 compression + error feedback. Grads must be identical in
    shape across pods (pure DP on the pod axis)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    leaves, treedef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_tree)
    keys = jax.random.split(key, len(leaves))

    outs = []
    for leaf, e, k in zip(leaves, errs, keys):
        def f(x, err):
            return compressed_psum(x, "pod", k, err)

        spec = P()  # replicated view per pod
        g, ne = shard_map(f, mesh=mesh, in_specs=(spec, spec),
                          out_specs=(spec, spec))(leaf, e)
        outs.append((g, ne))
    gs = treedef.unflatten([o[0] for o in outs])
    es = treedef.unflatten([o[1] for o in outs])
    return gs, es

"""Paper §5.4 (Figs. 6-13): ablate buffering and cloud bursting
independently, across cloud:on-prem cost ratios, plus the work-quality
comparison against the ground-truth Optimum (2a/2b/2c)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, fitted, stream
from repro.core import ingest as IG

VARIANTS = {
    "no_buffer_no_cloud": dict(buffer_gb=1e-6, cloud=0.0),
    "only_buffer": dict(buffer_gb=4.0, cloud=0.0),
    "only_cloud": dict(buffer_gb=1e-6, cloud=None),   # None -> generous
    "buffer_and_cloud": dict(buffer_gb=4.0, cloud=None),
}


def run(verbose: bool = True):
    rows = []
    for wname in ("covid", "mosei-high", "mosei-long"):
        ncat = 3 if wname == "covid" else 5
        # low provisioning — the regime where buffering/cloud matter
        cores = 4
        f = fitted(wname, cores, ncat)
        s = stream(wname, days=1.0)
        for vname, v in VARIANTS.items():
            cloud = v["cloud"] if v["cloud"] is not None else cores * 2000.0
            res = IG.run_skyscraper(f, s, n_cores=cores,
                                    cloud_budget_core_s=cloud,
                                    buffer_gb=v["buffer_gb"],
                                    plan_days=0.25)
            rows.append((wname, vname, res.quality_pct, res.work_core_s,
                         res.cloud_core_s))
            if verbose:
                emit(f"ablation/{wname}/{vname}", res.work_core_s,
                     f"quality={res.quality_pct:.1f}%"
                     f";cloud_core_s={res.cloud_core_s:.0f}")
        # work-quality vs optimum (Figs 7/9/11/13)
        opt = IG.run_optimum(f, s, n_cores=cores,
                             cloud_budget_core_s=cores * 2000.0)
        k = IG.best_static_config(f, cores)
        stat = IG.run_static(f, s, k, n_cores=cores)
        full = IG.run_skyscraper(f, s, n_cores=cores,
                                 cloud_budget_core_s=cores * 2000.0,
                                 plan_days=0.25)
        if verbose:
            emit(f"ablation/{wname}/work_static", stat.work_core_s,
                 f"quality={stat.quality_pct:.1f}%")
            emit(f"ablation/{wname}/work_skyscraper", full.work_core_s,
                 f"quality={full.quality_pct:.1f}%")
            emit(f"ablation/{wname}/work_optimum", opt.work_core_s,
                 f"quality={opt.quality_pct:.1f}%")
    return rows


if __name__ == "__main__":
    run()

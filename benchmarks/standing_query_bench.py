"""Standing queries at ingest rate vs the rescan loop.

Without the standing registry, keeping N registered queries fresh
means re-executing N full-store scans after every ingest tick — the
per-tick cost grows with BOTH the query count and the stored row
count. The registry folds every query's partial inside the ingest
dispatch itself (one vmapped fold for all same-shape queries, zero
extra dispatches) and answers from the maintained accumulators in
O(result), so the per-tick refresh cost is flat in the store size.

Reports, for 1000 registered same-shape queries (distinct thresholds):
  - standing: per-tick cost of ingest-with-fold + a whole-group answer
    snapshot (every query's table refreshed), with ZERO warm
    recompiles asserted across the timed ticks.
  - rescan: per-tick cost of the same ingest plus the query engine's
    zero-recompile rescan loop over the 1000 thresholds (the
    pre-standing implementation; itself already compiled + warm).
  - speedup: rescan / standing per-tick cost. Asserts >=10x, and
    bit-exact (fp32) agreement of standing answers with the numpy
    reference.

    PYTHONPATH=src:. python benchmarks/standing_query_bench.py [--tiny]

``--tiny`` runs a seconds-scale smoke configuration (used by
``scripts/tier1.sh --bench-smoke``) that keeps the correctness and
zero-recompile assertions but skips the speedup floor.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.switcher import compile_cache_sizes
from repro.warehouse import (Filter, GroupBy, SegmentStore,
                             StandingQueries, execute, execute_ref)

N_QUERIES = 1000
N_GROUPS = 16
BATCH = 512
N_TICKS = 8
N_TICKS_RESCAN = 2


def _plan(thr: float):
    return (Filter("quality", "ge", float(thr)),
            GroupBy("category", "quality", agg="sum",
                    num_groups=N_GROUPS))


def _batches(n_ticks, batch, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_ticks):
        n = batch
        out.append({
            "stream_id": rng.integers(0, 8, n).astype(np.int32),
            "t": (i * n + np.arange(n)).astype(np.int32),
            "category": rng.integers(0, N_GROUPS, n).astype(np.int32),
            "k": rng.integers(0, 4, n).astype(np.int32),
            "quality": rng.random(n).astype(np.float32),
            "on_core_s": (rng.random(n) * 20).astype(np.float32),
            "cloud_core_s": (rng.random(n) * 5).astype(np.float32),
            "buffer_s": (rng.random(n) * 40).astype(np.float32),
            "out": rng.random((n, 4)).astype(np.float32),
        })
    return out


def run(verbose: bool = True, tiny: bool = False):
    n_q = 64 if tiny else N_QUERIES
    batch = 128 if tiny else BATCH
    n_ticks = 3 if tiny else N_TICKS
    n_ticks_rescan = 1 if tiny else N_TICKS_RESCAN
    thrs = np.linspace(0.05, 0.95, n_q)
    # capacity covers every tick of both legs: no growth recompiles in
    # the timed region (growth is bucketed + pinned by its own test)
    cap = batch * (2 * n_ticks + n_ticks_rescan + 4)

    # ---- standing leg: register 1k queries on the EMPTY store --------
    # (registration backfill is skipped when there is nothing to scan;
    # every row's contribution arrives through the in-dispatch fold)
    store = SegmentStore(out_dim=4, chunk_rows=cap)
    reg = StandingQueries(store)
    t0 = time.perf_counter()
    handles = [reg.register(_plan(t)) for t in thrs]
    dt_reg = time.perf_counter() - t0
    (group,) = reg._groups.values()
    assert group.q == n_q

    ticks = _batches(2 * n_ticks, batch, seed=1)
    warm, timed = ticks[:n_ticks], ticks[n_ticks:]
    for rows in warm:                     # compile fold + answer once
        store.append_rows(rows)
    jax.block_until_ready(reg.group_answers(group))

    cache0 = sum(compile_cache_sizes().values())
    t0 = time.perf_counter()
    for rows in timed:
        store.append_rows(rows)          # fold rides the one dispatch
        table, mask = reg.group_answers(group)   # all n_q answers
    jax.block_until_ready((table, mask))
    dt_standing = (time.perf_counter() - t0) / n_ticks
    recompiles = sum(compile_cache_sizes().values()) - cache0
    assert recompiles == 0, \
        f"{recompiles} recompiles across warm standing ticks"

    # ---- rescan leg: same ingest, query engine re-executed per query --
    rescan = SegmentStore(out_dim=4, chunk_rows=cap)
    for rows in ticks:                   # same rows, same store size
        rescan.append_rows(rows)
    jax.block_until_ready(execute(rescan, _plan(thrs[0])))   # warm
    cache0 = sum(compile_cache_sizes().values())
    extra = _batches(n_ticks_rescan, batch, seed=2)
    t0 = time.perf_counter()
    for rows in extra:
        rescan.append_rows(rows)
        for thr in thrs:
            rtable, rmask = execute(rescan, _plan(thr))
    jax.block_until_ready((rtable, rmask))
    dt_rescan = (time.perf_counter() - t0) / n_ticks_rescan
    assert sum(compile_cache_sizes().values()) == cache0, \
        "rescan loop recompiled (unfair baseline)"

    # ---- correctness: standing == numpy reference, bit-exact ----------
    cols = store.host_rows()
    for i in (0, n_q // 2, n_q - 1):
        table, mask = reg.answer(handles[i])
        ref, rm = execute_ref(cols, store.n_rows, _plan(thrs[i]))
        assert np.array_equal(np.asarray(mask), rm)
        assert np.array_equal(np.asarray(table["quality"]),
                              ref["quality"])
        assert np.array_equal(np.asarray(table["count"]), ref["count"])

    speedup = dt_rescan / dt_standing
    if verbose:
        emit(f"standing/refresh/q{n_q}", dt_standing * 1e6,
             f"standing_tick={dt_standing * 1e3:.2f}ms;"
             f"rescan_tick={dt_rescan * 1e3:.1f}ms;"
             f"speedup={speedup:.1f}x;recompiles=0;"
             f"register={dt_reg * 1e3:.0f}ms;rows={store.n_rows}")
    if not tiny:
        assert speedup >= 10.0, \
            f"standing refresh must be >=10x the rescan loop at " \
            f"{n_q} queries, got {speedup:.1f}x"
    return [speedup]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(tiny="--tiny" in sys.argv[1:])

"""Shared benchmark plumbing: CSV emission + cached offline fits."""
from __future__ import annotations

import functools
import os
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# every emit() lands here so runners can serialize a perf snapshot
# (benchmarks/run.py --json) without re-parsing stdout
_RECORDS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": float(us_per_call),
                     "derived": derived})


def records():
    """All rows emitted so far (list of dicts, insertion order)."""
    return list(_RECORDS)


@functools.lru_cache(maxsize=None)
def fitted(workload_name: str, n_cores: int, n_categories: int = 4,
           days: float = 6.0, seed: int = 0):
    from repro.configs.workloads import WORKLOADS
    from repro.core.offline import fit
    return fit(WORKLOADS[workload_name], n_cores=n_cores,
               days_unlabeled=days, n_categories=n_categories, seed=seed)


@functools.lru_cache(maxsize=None)
def stream(workload_name: str, days: float = 2.0, seed: int = 99):
    from repro.configs.workloads import WORKLOADS
    from repro.data.stream import generate
    return generate(WORKLOADS[workload_name], days=days, seed=seed)

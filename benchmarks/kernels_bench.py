"""Pallas kernel harness: FLOP counts + interpret-mode allclose status
(wall-time on CPU interpret mode is NOT a perf claim; TPU perf comes from
the roofline analysis in benchmarks/roofline.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def run(verbose: bool = True):
    key = jax.random.PRNGKey(0)
    # flash attention
    B, S, H, G, D = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, G, D))
    v = jax.random.normal(key, (B, S, G, D))
    t0 = time.perf_counter()
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    out.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(out - ref.flash_attention_ref(q, k, v)).max())
    flops = 4 * B * S * S * H * D
    if verbose:
        emit("kernel/flash_attention_256", us,
             f"flops={flops:.2e};allclose_err={err:.1e}")
    # ssd
    B, S, H, P, N = 1, 256, 4, 32, 64
    x = jax.random.normal(key, (B, S, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, H)))
    A = -jnp.exp(jax.random.normal(key, (H,)) * 0.3)
    Bm = jax.random.normal(key, (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(key, (B, S, 1, N)) * 0.3
    t0 = time.perf_counter()
    y = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    y.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(y - ref.ssd_ref(x, dt, A, Bm, Cm)).max())
    if verbose:
        emit("kernel/ssd_256", us, f"allclose_err={err:.1e}")
    # frame downsample
    f = jax.random.normal(key, (4, 720, 1280, 3))
    t0 = time.perf_counter()
    d = ops.downsample(f, factor=2, block=64)
    d.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(d - ref.downsample_ref(f, 2)).max())
    if verbose:
        emit("kernel/downsample_720p_x2", us,
             f"bytes={f.size * 4:.2e};allclose_err={err:.1e}")


if __name__ == "__main__":
    run()

"""Paper Table 5/6 + Fig. 14/18: forecaster MAE vs horizon, vs input
featurization, and vs training-set size; end-to-end effect of the
horizon on Skyscraper quality."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, fitted, stream
from repro.configs.workloads import COVID, MOT
from repro.core import ingest as IG
from repro.core.forecaster import (forecast, init_forecaster, make_dataset,
                                   train_forecaster)
from repro.core.offline import fit
from repro.data.stream import generate


def _labels(w, days, n_cat, seed=0):
    f = fit(w, n_cores=8, days_unlabeled=days, n_categories=n_cat, seed=seed)
    s = generate(w, days=days, seed=seed + 1)
    q = s.quality(f.power, seed=seed + 2)
    d = ((q[:, None, :] - f.centers[None]) ** 2).sum(-1)
    return d.argmin(1), f


def run(verbose: bool = True):
    rows = []
    for w, wname in ((COVID, "covid"), (MOT, "mot")):
        labels, f = _labels(w, days=18.0, n_cat=3)
        tau = w.segment_seconds
        # Table 5: MAE vs forecast horizon
        for days_ahead in (1, 2, 4, 8):
            horizon = min(int(days_ahead * 86400 / tau), len(labels) // 3)
            interval = max(1, int(2 * 86400 / 8 / tau))
            interval = min(interval, (len(labels) - horizon) // 16)
            X, Y = make_dataset(labels, 3, interval=interval, n_split=8,
                                horizon=horizon)
            p = init_forecaster(jax.random.PRNGKey(0), 8, 3)
            p, m = train_forecaster(p, X, Y, epochs=40)
            rows.append((wname, "horizon", days_ahead, m["val_mae"]))
            if verbose:
                emit(f"forecaster/{wname}/mae_h{days_ahead}d",
                     m["val_mae"] * 1e6, f"val_mae={m['val_mae']:.4f}")
        # Fig. 18: MAE vs number of training samples
        horizon = min(int(2 * 86400 / tau), len(labels) // 3)
        interval = min(max(1, int(2 * 86400 / 8 / tau)),
                       (len(labels) - horizon) // 16)
        X, Y = make_dataset(labels, 3, interval=interval, n_split=8,
                            horizon=horizon)
        for n in (50, 200, 700, len(X)):
            n = min(n, len(X))
            p = init_forecaster(jax.random.PRNGKey(0), 8, 3)
            p, m = train_forecaster(p, X[:n], Y[:n], epochs=40)
            if verbose:
                emit(f"forecaster/{wname}/mae_n{n}", m["val_mae"] * 1e6,
                     f"val_mae={m['val_mae']:.4f}")
    # Fig. 14: end-to-end quality, model vs oracle vs uniform forecast
    f = fitted("covid", 8, 3)
    s = stream("covid", days=1.0)
    for mode in ("model", "oracle", "uniform"):
        res = IG.run_skyscraper(f, s, n_cores=8,
                                cloud_budget_core_s=5000.0,
                                plan_days=0.25, forecast_mode=mode)
        if verbose:
            emit(f"forecaster/e2e_covid/{mode}", res.quality_pct * 1e4,
                 f"quality={res.quality_pct:.2f}%")
    return rows


if __name__ == "__main__":
    run()

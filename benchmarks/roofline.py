"""Roofline report: reads the dry-run JSON and prints the per-cell
three-term roofline table (deliverable g)."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, emit


def run(verbose: bool = True, path: str = None, tag: str = "baseline",
        mesh: str = "16x16"):
    path = path or os.path.join(RESULTS_DIR, "dryrun.json")
    if not os.path.exists(path):
        if verbose:
            emit("roofline/missing", 0, f"run repro.launch.dryrun first ({path})")
        return []
    with open(path) as f:
        recs = json.load(f)
    rows = []
    for r in recs:
        if r.get("tag") != tag or r.get("mesh") != mesh:
            continue
        name = f"roofline/{r['arch']}/{r['shape']}"
        if "skipped" in r:
            if verbose:
                emit(name, 0, f"SKIP:{r['skipped'][:60]}")
            continue
        if "error" in r:
            if verbose:
                emit(name, 0, f"ERROR:{r['error'][:60]}")
            continue
        rl = r["roofline"]
        rows.append(r)
        if verbose:
            emit(name, rl["bound_s"] * 1e6,
                 f"dom={rl['dominant']};comp={rl['compute_s']:.4f}s"
                 f";mem={rl['memory_s']:.4f}s"
                 f";coll={rl['collective_s']:.4f}s"
                 f";useful={r['useful_ratio']:.2f}"
                 f";mfu_bound={min(1.0, r['model_flops_per_device'] / max(rl['bound_s'], 1e-12) / 197e12):.3f}")
    return rows


if __name__ == "__main__":
    run()

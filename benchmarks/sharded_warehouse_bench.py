"""Sharded warehouse: query scan throughput vs shard count.

The partial/merge engine's point is horizontal scale: the same plan
(Filter -> WindowAgg -> TopK) over the same rows, executed by a
``ShardedStore`` at 1/2/4/8 shards — each shard scans its own rows in
parallel (its own XLA CPU device) and the merge combiner reduces the
fixed-shape partials. Reports per-shard-count scan throughput plus the
``sharded_query_bench`` summary row: the shard-count scaling curve and
the 8-shard speedup over the 1-shard engine.

Because shard devices only exist under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (which must be
set before jax initializes), the benchmark re-executes itself in a
subprocess with that flag and re-emits the subprocess's CSV rows —
``benchmarks/run.py`` and ``scripts/tier1.sh --bench-smoke`` can call
``run()`` from an already-initialized single-device process.

    PYTHONPATH=src:. python benchmarks/sharded_warehouse_bench.py [--tiny]

``--tiny`` is the seconds-scale smoke configuration (correctness +
zero-recompile assertions, no speedup floor). The full run asserts the
8-shard engine >= 2x the 1-shard engine and exact-count / tolerant-sum
agreement with the numpy reference.
"""
from __future__ import annotations

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_DEVFLAG = "--xla_force_host_platform_device_count=8"

N_QUERIES = 16
WINDOW = 500
TOP_K = 10


def _inner(tiny: bool) -> None:
    import time

    import jax
    import numpy as np

    from repro.warehouse import (Filter, ShardedStore, TopK, WindowAgg,
                                 execute_ref, windows_for)
    from repro.warehouse import query as Q

    counts = (1, 8) if tiny else (1, 2, 4, 8)
    T = 16_000 if tiny else 240_000
    n_streams = 64                      # divisible by every shard count
    rng = np.random.default_rng(7)
    rows = {
        "stream_id": (np.arange(T, dtype=np.int32) % n_streams),
        "t": np.arange(T, dtype=np.int32),
        "category": rng.integers(0, 4, T).astype(np.int32),
        "k": rng.integers(0, 4, T).astype(np.int32),
        "quality": rng.random(T).astype(np.float32),
        "on_core_s": (rng.random(T) * 20).astype(np.float32),
        "cloud_core_s": (rng.random(T) * 5).astype(np.float32),
        "buffer_s": (rng.random(T) * 40).astype(np.float32),
        "out": rng.random((T, 4)).astype(np.float32),
    }

    def plan(thr, nw):
        return (Filter("quality", "ge", thr),
                WindowAgg(window=WINDOW, value="quality", agg="mean",
                          num_windows=nw),
                TopK(TOP_K, by="quality"))

    thrs = np.linspace(0.2, 0.8, N_QUERIES)
    thr_mrows = {}
    for S in counts:
        # chunk = exact per-shard rows: the scan covers zero padding at
        # every shard count, so the curve isolates the engine
        store = ShardedStore(out_dim=4, n_shards=S, chunk_rows=T // S)
        assert store.mesh is not None, \
            f"need {S} devices, have {jax.device_count()}"
        store.append_rows(rows)
        assert store.capacity == T // S, store
        nw = windows_for(store, WINDOW)
        jax.block_until_ready(store.query(plan(0.5, nw)))   # warm
        cache0 = Q.sharded_compile_cache_size()
        best = float("inf")
        for _ in range(1 if tiny else 3):     # best-of: CPU-quota noise
            t0 = time.perf_counter()
            for thr in thrs:
                table, mask = store.query(plan(float(thr), nw))
            jax.block_until_ready((table, mask))
            best = min(best, time.perf_counter() - t0)
        assert Q.sharded_compile_cache_size() == cache0, "recompiled"
        ref, rmask = execute_ref(store.host_rows(), T,
                                 plan(float(thrs[-1]), nw))
        np.testing.assert_array_equal(np.asarray(table["count"]),
                                      ref["count"])
        np.testing.assert_allclose(np.asarray(table["quality"]),
                                   ref["quality"], rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(mask), rmask)
        thr_mrows[S] = N_QUERIES * T / best / 1e6
        print(f"warehouse_sharded/query/S{S}_T{T},"
              f"{best / N_QUERIES * 1e6:.2f},"
              f"scan={thr_mrows[S]:.1f}Mrows/s;shards={S};recompiles=0")
    speedup = thr_mrows[counts[-1]] / thr_mrows[1]
    cores = os.cpu_count() or 1
    curve = ";".join(f"s{S}={thr_mrows[S]:.1f}Mrows/s" for S in counts)
    print(f"sharded_query_bench,{0.0:.2f},"
          f"{curve};speedup8={speedup:.2f}x;host_cores={cores};"
          f"rows={T};recompiles=0")
    # the scan is compute-bound, so S shards can only beat 1 shard by
    # min(S, physical cores): enforce the 8-shard >=2x floor where the
    # host can physically run >=8 shard devices in parallel (an 8-core
    # box); on smaller hosts the curve itself is the artifact (e.g. a
    # 2-core container tops out around 2x at 4 shards / ~1.4x at 8,
    # where 8 runtime threads thrash 2 cores)
    if not tiny and cores >= 8:
        assert speedup >= 2.0, \
            f"8-shard engine must be >=2x the 1-shard engine, got " \
            f"{speedup:.2f}x"

    # ---- fused Pallas partials inside the shard_map dispatch ----------
    # same plan minus the TopK post node (runs after the merge), each
    # shard's partial through the fused kernel: exactness vs the numpy
    # reference plus a zero-scatter census of the per-shard kernel —
    # the scatter floor stays broken under sharding.
    import jax.numpy as jnp

    from repro.analysis import DEFAULT_INVARIANTS
    from repro.analysis.jaxpr_lint import lint_jaxpr, trace_closed_jaxpr
    S = counts[-1]
    store = ShardedStore(out_dim=4, n_shards=S, chunk_rows=T // S)
    store.append_rows(rows)
    nw = windows_for(store, WINDOW)
    pplan = plan(0.5, nw)[:2]
    ptable, pmask = store.query(pplan, use_pallas=True)
    pref, prmask = execute_ref(store.host_rows(), T, pplan)
    np.testing.assert_array_equal(np.asarray(pmask), prmask)
    np.testing.assert_array_equal(np.asarray(ptable["count"]),
                                  pref["count"])
    np.testing.assert_allclose(np.asarray(ptable["quality"]),
                               pref["quality"], rtol=1e-5, atol=1e-4)
    spec, fvals = Q.normalize(pplan)
    pre, node, _post = Q.split_plan(spec)
    shard_cols = {k: v[0] for k, v in store.columns.items()}
    _, census = lint_jaxpr(trace_closed_jaxpr(
        lambda c, n, fv: Q._shard_partial_pallas(c, n, fv, jnp.int32(0),
                                                 pre=pre, node=node),
        (shard_cols, jnp.int32(T // S), fvals), {}), DEFAULT_INVARIANTS)
    n_scatter = census["totals"]["scatter_executed"]
    assert n_scatter == 0, \
        f"sharded Pallas partial executes {n_scatter} scatters"
    print(f"warehouse_sharded/query_pallas/S{S}_T{T},0.00,"
          f"scatter_ops=0;shards={S};exact=count;mean_rtol=1e-5")


def run(verbose: bool = True, tiny: bool = False):
    """Re-exec under a forced 8-device CPU topology and re-emit the
    subprocess's CSV rows through benchmarks.common (so --json
    snapshots include them)."""
    from benchmarks.common import emit

    env = dict(os.environ)
    # appended last: XLA flag parsing is last-wins, so this overrides
    # any device count the caller's environment already pinned
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _DEVFLAG).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    cmd = [sys.executable, os.path.abspath(__file__), "--inner"]
    if tiny:
        cmd.append("--tiny")
    p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       cwd=_ROOT)
    if p.returncode != 0:
        raise RuntimeError(
            f"sharded bench subprocess failed:\n{p.stdout[-2000:]}\n"
            f"{p.stderr[-2000:]}")
    out = []
    for line in p.stdout.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) == 3 and ("warehouse_sharded" in parts[0]
                                or parts[0] == "sharded_query_bench"):
            if verbose:
                emit(parts[0], float(parts[1]), parts[2])
            if "speedup8=" in parts[2]:
                out.append(float(parts[2].split("speedup8=")[1]
                                 .split("x")[0]))
    return out


if __name__ == "__main__":
    if "--inner" in sys.argv[1:]:
        _inner(tiny="--tiny" in sys.argv[1:])
    else:
        print("name,us_per_call,derived")
        run(tiny="--tiny" in sys.argv[1:])

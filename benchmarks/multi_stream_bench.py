"""Multi-stream switcher scaling (paper App. D): the batched fused-scan
engine vs the seed's per-stream Python loop.

The seed drove V streams through V separate ``lax.scan`` dispatches per
planning window (plus a fresh trace whenever the tail window shrank).
The batched engine stacks the tables pytree, vmaps the decision over the
stream axis, and runs ONE scan — so per-window dispatch cost is constant
in V and padded tails never recompile. Reports per-V wall-clock,
throughput (segment-decisions/s), speedup over the loop, and the jit
cache deltas proving zero recompiles after warmup.

    PYTHONPATH=src:. python benchmarks/multi_stream_bench.py [--tiny]

``--tiny`` runs a seconds-scale smoke configuration (used by
``scripts/tier1.sh --bench-smoke`` so this entry point cannot rot).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.switcher import (compile_cache_size, init_state,
                                 init_state_multi, pad_window_multi,
                                 run_window, run_window_multi, stack_tables)
from benchmarks.overheads import _tables

WINDOWS = 12          # planning windows per run (last one is a short tail)
W = 512               # segments per window
TAIL = 197            # length of the final (padded) window


def _stream_data(V, K, C, W, windows, tail, seed=0):
    rng = np.random.default_rng(seed)
    tables = [_tables(K, C, seed=v) for v in range(V)]
    alphas = rng.random((V, C, K)).astype(np.float32)
    alphas /= alphas.sum(-1, keepdims=True)
    T = (windows - 1) * W + tail
    quals = jnp.asarray(rng.random((V, T, K)), jnp.float32)
    arrs = jnp.asarray(0.5 + rng.random((V, T)), jnp.float32)
    return tables, jnp.asarray(alphas), quals, arrs, T


def _run_loop(tables, alphas, quals, arrs, T, W):
    """The seed implementation: V per-stream scans per window, tail
    window traced at its own (shorter) length — V dispatches/window plus
    one recompile for the tail shape, per stream."""
    states = [init_state(tb) for tb in tables]
    total = 0.0
    t = 0
    while t < T:
        W_t = min(W, T - t)
        for v in range(len(tables)):
            states[v], outs = run_window(states[v], quals[v, t:t + W_t],
                                         arrs[v, t:t + W_t], alphas[v],
                                         tables[v])
            total += float(np.asarray(outs["qual"]).sum())
        t += W_t
    return total


def _run_batched(tab_stack, states, alphas, quals, arrs, T, W):
    """The batched engine: one fused scan per window, tail padded to W."""
    total = 0.0
    t = 0
    while t < T:
        W_t = min(W, T - t)
        q_w, a_w, valid = pad_window_multi(quals[:, t:t + W_t],
                                           arrs[:, t:t + W_t], W)
        states, outs = run_window_multi(states, q_w, a_w, alphas, tab_stack,
                                        valid=valid)
        total += float(np.asarray(outs["qual"]).sum())
        t += W_t
    return total


def run(verbose: bool = True, tiny: bool = False):
    rows = []
    K, C = 8, 4
    W_, windows, tail = (64, 3, 23) if tiny else (W, WINDOWS, TAIL)
    for V in ((1, 4) if tiny else (1, 2, 4, 8)):
        tables, alphas, quals, arrs, T = _stream_data(V, K, C, W_, windows,
                                                      tail, seed=V)
        tab_stack = stack_tables(tables)

        # ---- seed loop ------------------------------------------------
        _run_loop(tables, alphas, quals, arrs, T, W_)      # warmup
        t0 = time.perf_counter()
        q_loop = _run_loop(tables, alphas, quals, arrs, T, W_)
        dt_loop = time.perf_counter() - t0

        # ---- batched engine -------------------------------------------
        _run_batched(tab_stack, init_state_multi(tables), alphas, quals,
                     arrs, T, W_)                          # warmup
        _, multi0 = compile_cache_size()
        t0 = time.perf_counter()
        q_bat = _run_batched(tab_stack, init_state_multi(tables), alphas,
                             quals, arrs, T, W_)
        dt_bat = time.perf_counter() - t0
        _, multi1 = compile_cache_size()
        recompiles = multi1 - multi0

        assert abs(q_loop - q_bat) < 1e-3 * max(abs(q_loop), 1.0), \
            f"batched engine diverged: {q_loop} vs {q_bat}"
        assert recompiles == 0, f"{recompiles} recompiles after warmup"
        decisions = V * T
        rows.append((V, dt_loop, dt_bat, dt_loop / dt_bat))
        if verbose:
            emit(f"multi_stream/V{V}",
                 dt_bat / decisions * 1e6,
                 f"loop={dt_loop * 1e3:.1f}ms;batched={dt_bat * 1e3:.1f}ms;"
                 f"speedup={dt_loop / dt_bat:.2f}x;"
                 f"throughput={decisions / dt_bat / 1e3:.0f}kdec/s;"
                 f"recompiles=0")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(tiny="--tiny" in sys.argv[1:])

"""Paper App. B (Figs. 16/17): the idealized per-segment forecaster vs
Skyscraper's category-histogram design; KMeans vs GMM clustering."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, fitted, stream
from repro.core import ingest as IG


def run(verbose: bool = True):
    # low provisioning: misallocating expensive configs actually hurts
    f = fitted("covid", 4, 3)
    s = stream("covid", days=1.0)
    # Skyscraper (practical forecasting task)
    sky = IG.run_skyscraper(f, s, n_cores=4, cloud_budget_core_s=5000.0,
                            plan_days=0.25, forecast_mode="model")
    # idealized design: per-segment quality forecast = time-of-day average
    # of the previous day (App. B.1) fed to the knapsack == run_optimum on
    # the SHIFTED stream (yesterday's qualities as the prediction)
    quals = s.quality(f.power, seed=0)
    day = int(86400 / s.segment_seconds)
    pred = np.roll(quals, day, axis=0)      # yesterday's quality as forecast
    import jax.numpy as jnp
    from repro.core.planner import solve_lp_lagrangian
    T = s.n_segments
    budget = 4 * s.segment_seconds * T + 5000.0 / IG.CLOUD_PREMIUM
    alpha = solve_lp_lagrangian(jnp.asarray(pred), jnp.asarray(f.cost),
                                jnp.full((T,), 1.0 / T), budget / T)
    k_sel = np.asarray(alpha).argmax(1)
    q_ideal = float(quals[np.arange(T), k_sel].sum())
    qmax = (1.0 - s.difficulty * (1.0 - 0.85 * f.power.max())).sum()
    ideal_pct = 100.0 * q_ideal / qmax
    opt = IG.run_optimum(f, s, n_cores=4, cloud_budget_core_s=5000.0)
    if verbose:
        emit("design_alt/idealized_per_segment", ideal_pct * 1e4,
             f"quality={ideal_pct:.1f}% (forecast noise hurts)")
        emit("design_alt/skyscraper", sky.quality_pct * 1e4,
             f"quality={sky.quality_pct:.1f}%")
        emit("design_alt/optimum_ground_truth", opt.quality_pct * 1e4,
             f"quality={opt.quality_pct:.1f}%")
    # KMeans vs GMM content categories (Fig. 17)
    from repro.core.categories import kmeans
    rng = np.random.default_rng(0)
    samp = rng.choice(len(quals), 800, replace=False)
    km_centers, _ = kmeans(quals[samp], 4)
    try:
        from scipy.stats import multivariate_normal  # noqa: F401
        # lightweight EM-GMM (diagonal) for the comparison
        X = quals[samp]
        mu = np.asarray(km_centers) + rng.normal(0, 0.02, km_centers.shape)
        var = np.ones_like(mu) * 0.05
        pi = np.ones(4) / 4
        for _ in range(30):
            logp = -0.5 * (((X[:, None] - mu[None]) ** 2) / var[None]
                           + np.log(var[None])).sum(-1) + np.log(pi)[None]
            logp -= logp.max(1, keepdims=True)
            resp = np.exp(logp)
            resp /= resp.sum(1, keepdims=True)
            nk = resp.sum(0) + 1e-9
            mu = (resp[..., None] * X[:, None]).sum(0) / nk[:, None]
            var = ((resp[..., None] * (X[:, None] - mu[None]) ** 2).sum(0)
                   / nk[:, None]) + 1e-4
            pi = nk / nk.sum()
        drift = float(np.abs(np.sort(mu, 0) - np.sort(np.asarray(km_centers),
                                                      0)).mean())
        if verbose:
            emit("design_alt/kmeans_vs_gmm_center_drift", drift * 1e6,
                 f"mean |centers| gap={drift:.4f} (same clusters)")
    except ImportError:
        pass
    return sky.quality_pct, ideal_pct


if __name__ == "__main__":
    run()

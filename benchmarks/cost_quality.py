"""Paper Fig. 4 / Table 2: cost-quality trade-off of Skyscraper vs
Chameleon* vs Static across the provisioning grid, on all 4 workloads.

Costs follow App. L: server $ = grid $/h / 1.8 (on-prem discount) x
duration; cloud $ = cloud core-s x lambda-equivalent rate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, fitted, stream
from repro.configs.workloads import (CLOUD_COST_PER_CORE_S, ONPREM_DISCOUNT,
                                     SERVER_GRID)
from repro.core import ingest as IG

DAYS = 1.0
GRID = SERVER_GRID[:4]          # 4..32 vCPUs (60 is slow on 1 host core)


def run(verbose: bool = True):
    rows = []
    for wname in ("covid", "mot", "mosei-high", "mosei-long"):
        # paper App. K: 3 content categories for COVID/MOT, 5 for MOSEI
        ncat = 3 if wname in ("covid", "mot") else 5
        s = stream(wname, days=DAYS)
        hours = DAYS * 24
        for cores, usd_h in GRID:
            server_usd = usd_h * hours / ONPREM_DISCOUNT
            try:
                f = fitted(wname, cores, ncat)
            except ValueError:
                continue    # provisioning below the cheapest config
            cloud_budget = cores * 400.0          # core-s of cloud credit
            sky = IG.run_skyscraper(f, s, n_cores=cores,
                                    cloud_budget_core_s=cloud_budget,
                                    plan_days=0.25)
            cham = IG.run_chameleon_star(f, s, n_cores=cores)
            kst = IG.best_static_config(f, cores)
            stat = IG.run_static(f, s, kst, n_cores=cores)
            for meth, res in (("skyscraper", sky), ("chameleon*", cham),
                              ("static", stat)):
                cloud_usd = res.cloud_core_s * CLOUD_COST_PER_CORE_S
                total = server_usd + cloud_usd
                rows.append((wname, meth, cores, res.quality_pct, total,
                             res.overflow))
                if verbose:
                    emit(f"fig4/{wname}/{meth}/{cores}c",
                         total * 100,  # cents as the "us" column
                         f"quality={res.quality_pct:.1f}%"
                         f";cloud=${cloud_usd:.2f}"
                         f";overflow={res.overflow}")
    # headline: cost reduction at matched quality (paper: up to 8.7x MOT).
    # For each Skyscraper point, the cheapest static point achieving the
    # same quality; report the best ratio across provisionings.
    for wname in ("covid", "mot"):
        sub = [r for r in rows if r[0] == wname]
        best_ratio, at = 0.0, None
        for sky in (r for r in sub if r[1] == "skyscraper"):
            match = [r for r in sub if r[1] == "static"
                     and r[3] >= sky[3] - 1.0]
            if match:
                ratio = min(r[4] for r in match) / sky[4]
                if ratio > best_ratio:
                    best_ratio, at = ratio, sky
        if at is not None:
            emit(f"fig4/{wname}/static_vs_sky_cost_ratio", best_ratio * 100,
                 f"static needs {best_ratio:.1f}x the cost to match "
                 f"skyscraper@{at[2]}c ({at[3]:.1f}%)")
    return rows


if __name__ == "__main__":
    run()

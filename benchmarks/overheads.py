"""Paper Fig. 13 (§5.5): knob-switcher and knob-planner decision
overheads vs problem size — plus the beyond-paper Lagrangian-vs-scipy
planner comparison."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.planner import solve_lp_lagrangian, solve_lp_scipy
from repro.core.switcher import SwitchTables, init_state, switch_step


def _tables(K, C, P=8, seed=0):
    rng = np.random.default_rng(seed)
    power = np.sort(rng.random(K)).astype(np.float32)
    cost = np.sort(rng.random(K) * 20 + 0.5).astype(np.float32)
    return SwitchTables(
        centers=jnp.asarray(np.sort(rng.random((C, K)), 0), jnp.float32),
        power=jnp.asarray(power), cost=jnp.asarray(cost),
        place_rt=jnp.asarray(rng.random((K, P)) * 3, jnp.float32),
        place_on=jnp.asarray(rng.random((K, P)) * 10, jnp.float32),
        place_cl=jnp.asarray(rng.random((K, P)) * 5, jnp.float32),
        place_valid=jnp.ones((K, P), bool),
        rank_pos=jnp.asarray(np.argsort(np.argsort(-power)), jnp.int32),
        tau=2.0, buffer_cap_s=1e4, cloud_budget=1e6)


def run(verbose: bool = True):
    rows = []
    # switcher latency vs (K x P) sizes (paper: worst case linear in #plc)
    # two numbers: eager per-call (python dispatch included) and the
    # scan-amortized per-decision cost (what the ingestion loop pays)
    from repro.core.switcher import run_window
    for K, C in [(4, 3), (8, 4), (16, 8), (64, 8), (256, 16)]:
        t = _tables(K, C)
        st = init_state(t)
        alpha = jnp.ones((C, K)) / K
        q = jnp.full((K,), 0.5)
        st, _ = switch_step(st, q, jnp.float32(1.0), alpha, t)  # warmup
        n = 300
        t0 = time.perf_counter()
        for _ in range(n):
            st, out = switch_step(st, q, jnp.float32(1.0), alpha, t)
        _ = float(out["qual"])
        us = (time.perf_counter() - t0) / n * 1e6
        T = 4096
        quals = jnp.full((T, K), 0.5)
        arr = jnp.ones((T,))
        st2, o = run_window(init_state(t), quals, arr, alpha, t)  # warmup
        jax.block_until_ready(o["qual"])
        t0 = time.perf_counter()
        st2, o = run_window(init_state(t), quals, arr, alpha, t)
        jax.block_until_ready(o["qual"])
        us_scan = (time.perf_counter() - t0) / T * 1e6
        rows.append(("switcher", K, C, us_scan))
        if verbose:
            emit(f"overhead/switcher/K{K}_C{C}", us_scan,
                 f"scan-amortized/decision; eager={us:.0f}us; "
                 + ("paper_bound_ok" if us_scan < 500 else "OVER"))
    # planner latency vs (C x K)
    rng = np.random.default_rng(0)
    for K, C in [(8, 4), (32, 8), (128, 16), (512, 32)]:
        qual = jnp.asarray(rng.random((C, K)), jnp.float32)
        cost = jnp.asarray(rng.random(K) * 10 + 0.1, jnp.float32)
        r = jnp.asarray(np.ones(C) / C, jnp.float32)
        solve_lp_lagrangian(qual, cost, r, 3.0).block_until_ready()
        n = 50
        t0 = time.perf_counter()
        for _ in range(n):
            solve_lp_lagrangian(qual, cost, r, 3.0).block_until_ready()
        us_l = (time.perf_counter() - t0) / n * 1e6
        t0 = time.perf_counter()
        for _ in range(5):
            solve_lp_scipy(np.asarray(qual), np.asarray(cost),
                           np.asarray(r), 3.0)
        us_s = (time.perf_counter() - t0) / 5 * 1e6
        rows.append(("planner", K, C, us_l))
        if verbose:
            emit(f"overhead/planner_lagrangian/K{K}_C{C}", us_l,
                 f"scipy={us_s:.0f}us;speedup={us_s / us_l:.0f}x")
    return rows


if __name__ == "__main__":
    run()

"""Run every paper-table/figure benchmark. Prints ``name,us_per_call,
derived`` CSV rows (one module per paper artifact — see DESIGN.md §6).

    PYTHONPATH=src:. python benchmarks/run.py [only] [--json [OUT]]
                                              [--compare OLD.json]

``only`` filters modules by substring. ``--json [OUT]`` additionally
writes a perf snapshot (bench name -> metric dict, with the numeric
fields of each row's ``derived`` string parsed out) so the repo's bench
trajectory can be tracked across PRs. OUT defaults to
``BENCH_HEAD.json`` — the rolling committed baseline; older PR-tagged
snapshots remain valid ``--compare`` inputs::

    python benchmarks/run.py --json                  # -> BENCH_HEAD.json
    python benchmarks/run.py --json BENCH_NEW.json --compare BENCH_HEAD.json

``--compare OLD.json`` loads a prior snapshot after the run, prints the
per-metric deltas, and exits non-zero if any FLOOR metric (a metric
whose key contains one of ``_FLOOR_KEYS`` — speedup factors and scan
throughputs, the numbers the engine benches assert lower bounds on)
regressed by more than 20%::

    python benchmarks/run.py --json BENCH_NEW.json --compare BENCH_HEAD.json

Floor metrics are ratios of two timings measured on the SAME host, so
they only compare across snapshots from the same machine class: each
snapshot records a ``host`` fingerprint (CPU core count), and when it
differs from the baseline's, floor regressions are reported as
warnings instead of failures (a 2-core baseline says nothing about a
1-core container's python-loop denominators). The structural CEILING
metrics (dispatch counts, scatter census, recompiles, violations) are
host-independent properties of the compiled programs and stay hard
failures everywhere.
"""
from __future__ import annotations

import json
import os
import re
import sys
import traceback

_NUM = re.compile(r"-?\d+(?:\.\d+)?(?:[eE]-?\d+)?")

# metric-name substrings treated as perf FLOORS (bigger is better);
# --compare fails the run when one drops >20% vs the old snapshot
_FLOOR_KEYS = ("speedup", "scan")
_FLOOR_DROP = 0.20

# metric-name substrings treated as CEILINGS (smaller is better, with
# zero headroom): the static-audit headline numbers. A dispatch count,
# scatter census, recompile count or violation count that GROWS at all
# vs the baseline fails the compare — these are structural properties
# of the compiled programs, not noisy timings.
_CEILING_KEYS = ("dispatch", "scatter_ops", "recompile", "violation")


def _metric_dict(row) -> dict:
    """Row -> metric dict: the leading number of every ``k=v`` part of
    the derived string (``speedup=12.3x`` -> ``{"speedup": 12.3}``);
    non-numeric parts keep their raw string."""
    out = {"us_per_call": row["us_per_call"]}
    for part in row["derived"].split(";"):
        if "=" not in part:
            continue
        key, val = part.split("=", 1)
        m = _NUM.match(val.strip())
        out[key.strip()] = float(m.group(0)) if m else val
    return out


def _host_cores(snap: dict):
    """Host fingerprint of a snapshot: the dedicated ``host`` record,
    falling back to the core count the sharded bench row has always
    carried (pre-fingerprint baselines)."""
    for rec in ("host", "sharded_query_bench"):
        v = snap.get(rec, {}).get("host_cores")
        if isinstance(v, (int, float)):
            return v
    return None


def _compare(snap: dict, old_path: str) -> int:
    """Print per-metric deltas vs a prior snapshot; return the number of
    >20% floor-metric regressions. A floor metric that existed in the
    baseline but is MISSING from this run (the bench errored out, was
    filtered away, or its derived key was renamed) counts as a
    regression too — a gate that goes green when its benchmark
    disappears is no gate. Floor deltas are only GATED when both
    snapshots come from the same host class (see module docstring);
    ceilings are gated unconditionally."""
    with open(old_path) as f:
        old = json.load(f)
    old_cores, new_cores = _host_cores(old), _host_cores(snap)
    same_host = (old_cores is None or new_cores is None
                 or old_cores == new_cores)
    if not same_host:
        print(f"# host class changed ({old_cores:.0f} -> "
              f"{new_cores:.0f} cores): floor deltas advisory, "
              f"ceilings still gated")
    # engines registered since the baseline legitimately grow the
    # audit's dispatch_total; gate the total over the engines BOTH
    # snapshots cover (every shared engine keeps its own per-engine
    # ceiling either way, and new engines get one from the first
    # committed snapshot that includes them)
    new_sa, old_sa = snap.get("static_audit"), old.get("static_audit")
    if isinstance(new_sa, dict) and isinstance(old_sa, dict) \
            and isinstance(new_sa.get("dispatch_total"), (int, float)):
        extra = sum(v for k, v in new_sa.items()
                    if k.startswith("dispatch.") and k not in old_sa
                    and isinstance(v, (int, float)))
        if extra:
            print(f"# static_audit.dispatch_total: {extra:.0f} "
                  f"dispatches from engines new since the baseline "
                  f"excluded from the ceiling")
            new_sa = dict(new_sa)
            new_sa["dispatch_total"] -= extra
            snap = {**snap, "static_audit": new_sa}
    regressions = []
    for name in sorted(snap):
        if name not in old:
            print(f"# {name}: new bench (no baseline)")
            continue
        for key, new_v in sorted(snap[name].items()):
            old_v = old[name].get(key)
            if not isinstance(new_v, (int, float)) \
                    or not isinstance(old_v, (int, float)):
                continue
            is_floor = any(fk in key for fk in _FLOOR_KEYS)
            is_ceiling = any(ck in key for ck in _CEILING_KEYS)
            if old_v == 0 and not is_ceiling:
                continue                  # ratio undefined; ceilings
            delta = (new_v - old_v) / abs(old_v) if old_v else 0.0
            flag = " [floor]" if is_floor else \
                " [ceiling]" if is_ceiling else ""
            if is_floor and new_v < old_v * (1.0 - _FLOOR_DROP):
                if same_host:
                    flag = " [floor] REGRESSION >20%"
                    regressions.append(f"{name}.{key}")
                else:
                    flag = " [floor] WARNING >20% (host class changed)"
            elif is_ceiling and new_v > old_v:
                flag = " [ceiling] REGRESSION (grew)"
                regressions.append(f"{name}.{key}")
            print(f"{name}.{key}: {old_v:.4g} -> {new_v:.4g} "
                  f"({delta:+.1%}){flag}")
    # baseline floor/ceiling metrics this run no longer reports at all
    for name, metrics in sorted(old.items()):
        missing = [key for key, old_v in metrics.items()
                   if isinstance(old_v, (int, float))
                   and any(k in key for k in _FLOOR_KEYS + _CEILING_KEYS)
                   and not isinstance(snap.get(name, {}).get(key),
                                      (int, float))]
        if name not in snap:
            print(f"# {name}: missing from this run (was in baseline)")
        for key in missing:
            print(f"{name}.{key}: {metrics[key]:.4g} -> MISSING "
                  f"REGRESSION (gated metric disappeared)")
            regressions.append(f"{name}.{key}")
    if regressions:
        print(f"FAIL: gated metrics regressed (floor drop >20% or "
              f"ceiling growth): {', '.join(regressions)}",
              file=sys.stderr)
    return len(regressions)


def _audit_record() -> dict:
    """Static-audit headline numbers for the perf snapshot: per-engine
    dispatch counts (ONE warm call = N executables) and the scatter
    census of every warehouse query plan — the structural floor the
    Pallas query-kernel work has to beat. All ceilings: growth fails
    ``--compare``."""
    from repro.analysis.run import run_audit
    report = run_audit(skip_source=True)
    recs = report["engines"]
    out = {
        "engines": float(len(recs)),
        "violations": float(report["n_violations"]),
        "dispatch_total": float(sum(
            r["dispatch"]["new_executables"] for r in recs.values()
            if "dispatch" in r)),
        "recompiles_total": float(sum(
            r["dispatch"]["recompiles"] for r in recs.values()
            if "dispatch" in r)),
    }
    for name, r in sorted(recs.items()):
        if "jaxpr_census" in r:
            out[f"dispatch.{name}"] = float(
                r.get("dispatch", {}).get("new_executables", 0))
        if name.startswith("warehouse_query") and "jaxpr_census" in r:
            t = r["jaxpr_census"]["totals"]
            out[f"scatter_ops.{name}"] = float(t["scatter_executed"])
    # aggregated ceiling over every fused-Pallas query engine: the
    # scatter floor the kernel breaks is pinned at literally ZERO, so
    # any single scatter creeping into any Pallas-path plan fails
    # --compare even if a new engine is registered without its own
    # per-engine baseline
    out["scatter_ops.query_pallas"] = float(sum(
        r["jaxpr_census"]["totals"]["scatter_executed"]
        for name, r in recs.items()
        if "_pallas" in name and "jaxpr_census" in r))
    return out


def main() -> None:
    from benchmarks import (ablation, common, cost_quality,
                            design_alternatives, forecaster_bench,
                            fused_ingest_bench, kernels_bench,
                            multi_stream_bench, offline_phase, overheads,
                            pool_scale_bench, roofline, sharded_warehouse_bench,
                            standing_query_bench, switcher_accuracy,
                            warehouse_bench)
    args = list(sys.argv[1:])
    json_out = compare_to = None
    for flag in ("--json", "--compare"):
        if flag in args:
            i = args.index(flag)
            if i + 1 >= len(args) or args[i + 1].startswith("--"):
                # --json defaults to the rolling head snapshot; --compare
                # has no sensible default (the baseline is the input)
                if flag == "--json":
                    json_out = "BENCH_HEAD.json"
                    del args[i:i + 1]
                    continue
                sys.exit(f"usage: run.py [only] [--json [OUT]] "
                         f"[--compare OLD.json] — missing {flag} value")
            if flag == "--json":
                json_out = args[i + 1]
            else:
                compare_to = args[i + 1]
            del args[i:i + 2]
    only = args[0] if args else None

    print("name,us_per_call,derived")
    # the engine benches with hard perf-floor asserts run first, while
    # a fresh process (and any host CPU-quota burst budget) gives the
    # least noisy timings
    modules = [
        ("fused_ingest", fused_ingest_bench),
        ("warehouse(Load)", warehouse_bench),
        ("sharded_warehouse(Load)", sharded_warehouse_bench),
        ("standing_queries(Load)", standing_query_bench),
        ("multi_stream(AppD)", multi_stream_bench),
        ("pool_scale", pool_scale_bench),
        ("overheads(Fig13)", overheads),
        ("offline_phase(Table3)", offline_phase),
        ("kernels", kernels_bench),
        ("roofline(g)", roofline),
        ("switcher_accuracy(Fig15/T4)", switcher_accuracy),
        ("forecaster(T5/T6/Fig14/18)", forecaster_bench),
        ("design_alternatives(AppB)", design_alternatives),
        ("ablation(Figs6-13)", ablation),
        ("cost_quality(Fig4/T2)", cost_quality),
    ]
    errors = {}
    for name, mod in modules:
        if only and only not in name:
            continue
        try:
            mod.run(verbose=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{str(e)[:120]}")
            errors[name] = str(e)
            traceback.print_exc(file=sys.stderr)
    snap = {row["name"]: _metric_dict(row) for row in common.records()}
    # host fingerprint: floor metrics only gate against same-class hosts
    snap["host"] = {"host_cores": float(os.cpu_count() or 1)}
    for name, err in errors.items():
        snap[f"{name}/ERROR"] = {"error": err}
    if not only or only in "static_audit":
        try:
            snap["static_audit"] = _audit_record()
        except Exception as e:  # noqa: BLE001
            snap["static_audit/ERROR"] = {"error": str(e)}
            errors["static_audit"] = str(e)
            traceback.print_exc(file=sys.stderr)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(snap)} bench records to {json_out}",
              file=sys.stderr)
    if compare_to:
        n_regressed = _compare(snap, compare_to)
        if n_regressed:
            sys.exit(1)


if __name__ == "__main__":
    main()

"""Run every paper-table/figure benchmark. Prints ``name,us_per_call,
derived`` CSV rows (one module per paper artifact — see DESIGN.md §6).

    PYTHONPATH=src:. python benchmarks/run.py [only] [--json OUT]

``only`` filters modules by substring. ``--json OUT`` additionally
writes a perf snapshot (bench name -> metric dict, with the numeric
fields of each row's ``derived`` string parsed out) so the repo's bench
trajectory can be tracked across PRs, e.g.::

    python benchmarks/run.py --json BENCH_PR3.json
"""
from __future__ import annotations

import json
import re
import sys
import traceback

_NUM = re.compile(r"-?\d+(?:\.\d+)?(?:[eE]-?\d+)?")


def _metric_dict(row) -> dict:
    """Row -> metric dict: the leading number of every ``k=v`` part of
    the derived string (``speedup=12.3x`` -> ``{"speedup": 12.3}``);
    non-numeric parts keep their raw string."""
    out = {"us_per_call": row["us_per_call"]}
    for part in row["derived"].split(";"):
        if "=" not in part:
            continue
        key, val = part.split("=", 1)
        m = _NUM.match(val.strip())
        out[key.strip()] = float(m.group(0)) if m else val
    return out


def main() -> None:
    from benchmarks import (ablation, common, cost_quality,
                            design_alternatives, forecaster_bench,
                            fused_ingest_bench, kernels_bench,
                            multi_stream_bench, offline_phase, overheads,
                            roofline, switcher_accuracy, warehouse_bench)
    args = list(sys.argv[1:])
    json_out = None
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args):
            sys.exit("usage: run.py [only] [--json OUT] — missing OUT path")
        json_out = args[i + 1]
        del args[i:i + 2]
    only = args[0] if args else None

    print("name,us_per_call,derived")
    # the engine benches with hard perf-floor asserts run first, while
    # a fresh process (and any host CPU-quota burst budget) gives the
    # least noisy timings
    modules = [
        ("fused_ingest", fused_ingest_bench),
        ("warehouse(Load)", warehouse_bench),
        ("multi_stream(AppD)", multi_stream_bench),
        ("overheads(Fig13)", overheads),
        ("offline_phase(Table3)", offline_phase),
        ("kernels", kernels_bench),
        ("roofline(g)", roofline),
        ("switcher_accuracy(Fig15/T4)", switcher_accuracy),
        ("forecaster(T5/T6/Fig14/18)", forecaster_bench),
        ("design_alternatives(AppB)", design_alternatives),
        ("ablation(Figs6-13)", ablation),
        ("cost_quality(Fig4/T2)", cost_quality),
    ]
    errors = {}
    for name, mod in modules:
        if only and only not in name:
            continue
        try:
            mod.run(verbose=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{str(e)[:120]}")
            errors[name] = str(e)
            traceback.print_exc(file=sys.stderr)
    if json_out:
        snap = {row["name"]: _metric_dict(row) for row in common.records()}
        for name, err in errors.items():
            snap[f"{name}/ERROR"] = {"error": err}
        with open(json_out, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(snap)} bench records to {json_out}",
              file=sys.stderr)


if __name__ == "__main__":
    main()

"""Run every paper-table/figure benchmark. Prints ``name,us_per_call,
derived`` CSV rows (one module per paper artifact — see DESIGN.md §6)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (ablation, cost_quality, design_alternatives,
                            forecaster_bench, fused_ingest_bench,
                            kernels_bench, multi_stream_bench, offline_phase,
                            overheads, roofline, switcher_accuracy)
    print("name,us_per_call,derived")
    modules = [
        ("overheads(Fig13)", overheads),
        ("fused_ingest", fused_ingest_bench),
        ("multi_stream(AppD)", multi_stream_bench),
        ("offline_phase(Table3)", offline_phase),
        ("kernels", kernels_bench),
        ("roofline(g)", roofline),
        ("switcher_accuracy(Fig15/T4)", switcher_accuracy),
        ("forecaster(T5/T6/Fig14/18)", forecaster_bench),
        ("design_alternatives(AppB)", design_alternatives),
        ("ablation(Figs6-13)", ablation),
        ("cost_quality(Fig4/T2)", cost_quality),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, mod in modules:
        if only and only not in name:
            continue
        try:
            mod.run(verbose=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{str(e)[:120]}")
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()

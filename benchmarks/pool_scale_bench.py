"""Elastic serving pool scaling: one fused tick for V streams vs the
seed's per-stream switcher loop.

``SkyscraperPool`` serves V live streams from ONE jitted tick program
(`_pool_tick`: vmapped masked switch + shed stage) on a power-of-two
slot ladder, so per-tick dispatch cost is constant in V and admitting
or retiring a stream never recompiles inside a capacity bucket. The
seed semantics — V independent ``switch_step`` dispatches per tick —
pay V host round-trips. This bench sweeps V and reports ticks/sec for
both, the warm recompile count (a ceiling: must stay 0), and the shed
fraction by priority band under a capacity squeeze (must be monotone:
lower priority sheds no less than higher).

Floor: at the top of the sweep (V=512) the fused tick must clear >= 5x
the per-stream loop's tick rate (hard assert), and the snapshot carries
a clamped ``speedup`` floor metric for ``--compare`` — clamped well
below the observed margin so run-to-run loop-timing noise cannot trip
the 20% gate, while a real collapse still fails it.

    PYTHONPATH=src:. python benchmarks/pool_scale_bench.py [--tiny]

``--tiny`` runs a seconds-scale smoke sweep (used by
``scripts/tier1.sh --bench-smoke`` so this entry point cannot rot).
"""
from __future__ import annotations

import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.api import Skyscraper, SkyscraperPool
from repro.core.switcher import compile_cache_sizes, init_state, switch_step

SPEEDUP_FLOOR = 5.0
# emitted floor metric is clamped here: stable across noisy loop
# denominators, still fails --compare if the real speedup collapses
FLOOR_CLAMP = 25.0


def _quality_of(knobs):
    return min(0.5 + 0.1 * knobs["q"], 1.0)


def _proc(seg, knobs):
    return ("out", _quality_of(knobs))


_SKY = []


def _sky():
    if not _SKY:
        rng = np.random.default_rng(0)
        s = Skyscraper(fps=2, segment_seconds=1.0, n_categories=2, seed=0)
        s.set_resources(num_cores=4, buffer_gb=1.0, cloud_budget_core_s=0.0)
        s.register_knob("q", [1, 2, 3])
        s.fit([rng.random((3,)) for _ in range(12)], _proc)
        _SKY.append(s)
    return _SKY[0]


def _loop_ticks(sky, V, mults, n_ticks, seg):
    """Seed semantics: V per-stream ``switch_step`` dispatches per tick
    (plus the same per-stream proc call the pool makes)."""
    alpha0 = jnp.asarray(sky.alpha)
    zeros = jnp.zeros(len(sky.configs))
    states = [init_state(sky.tables) for _ in range(V)]
    pending = [None] * V
    for _ in range(n_ticks):
        for v in range(V):
            stt = dict(states[v])
            if pending[v] is not None:
                stt["qual_prev"] = jnp.float32(pending[v])
            stt, outs = switch_step(stt, zeros, jnp.float32(mults[v]),
                                    alpha0, sky.tables)
            states[v] = stt
            if bool(outs["dropped"]):
                pending[v] = None
            else:
                _, q = sky.proc_fn(seg, sky.configs[int(outs["k"])])
                pending[v] = q
    return states


def _pool_ticks(pool, segs, mults, n_ticks):
    for _ in range(n_ticks):
        pool.process(segs, arrival_mults=mults)


def _shed_by_priority(sky, V, n_ticks, verbose):
    """Capacity squeeze at V streams in 4 priority bands; returns
    {priority: shed fraction} from the pool's own telemetry."""
    prios = [1.0 + (v % 4) for v in range(V)]
    pool = SkyscraperPool(sky, n_streams=V, priorities=prios,
                          telemetry=True)
    seg = np.zeros(3)
    pool.process([seg] * V)                # unconstrained: measure demand
    tel = pool.telemetry()
    demand = float(np.asarray(tel.counters["onprem_core_s"]).sum())
    pool.capacity_core_s = demand * 0.5    # room for ~half the fleet
    for _ in range(n_ticks):
        pool.process([seg] * V)
    stats = pool.shed_stats()
    frac = {}
    for p in sorted(set(prios)):
        sids = [s for s in pool.streams if stats[s]["priority"] == p]
        shed = sum(stats[s]["dropped"] for s in sids)
        tot = sum(stats[s]["segments"] for s in sids)
        frac[p] = shed / max(tot, 1)
    ordered = [frac[p] for p in sorted(frac)]
    assert all(a >= b - 1e-9 for a, b in zip(ordered, ordered[1:])), \
        f"shed fraction not monotone in priority: {frac}"
    return frac


def run(verbose: bool = True, tiny: bool = False):
    sky = _sky()
    plan_every0 = sky._plan_every
    sky._plan_every = 10_000               # isolate tick cost from replan
    try:
        return _run(sky, verbose, tiny)
    finally:
        sky._plan_every = plan_every0


def _run(sky, verbose, tiny):
    rows = []
    seg = np.zeros(3)
    sweep = (8, 32) if tiny else (8, 64, 512)
    ticks = 4 if tiny else 12
    loop_ticks = 2 if tiny else 3
    for V in sweep:
        rng = np.random.default_rng(V)
        mults = (0.5 + rng.random(V)).astype(np.float32)
        segs = [seg] * V

        # ---- seed loop ------------------------------------------------
        _loop_ticks(sky, V, mults, 1, seg)                 # warmup
        t0 = time.perf_counter()
        _loop_ticks(sky, V, mults, loop_ticks, seg)
        tps_loop = loop_ticks / (time.perf_counter() - t0)

        # ---- fused pool tick ------------------------------------------
        pool = SkyscraperPool(sky, n_streams=V, telemetry=True)
        _pool_ticks(pool, segs, mults, 1)                  # warmup
        sizes0 = compile_cache_sizes()
        t0 = time.perf_counter()
        _pool_ticks(pool, segs, mults, ticks)
        tps_pool = ticks / (time.perf_counter() - t0)
        recompiles = sum(compile_cache_sizes().values()) \
            - sum(sizes0.values())
        assert recompiles == 0, f"{recompiles} recompiles after warmup"
        tel = pool.telemetry()
        assert int(np.asarray(tel.counters["seg_total"]).sum()) \
            == V * (ticks + 1)

        speedup = tps_pool / tps_loop
        rows.append((V, tps_loop, tps_pool, speedup))
        if verbose:
            # ratio= is informational (loop timing is noisy at few
            # ticks); the gated floor metric is the clamped one below
            emit(f"pool_scale/V{V}", 1e6 / tps_pool,
                 f"loop={tps_loop:.1f}tps;pool={tps_pool:.1f}tps;"
                 f"ratio={speedup:.2f}x;recompiles=0")
    if not tiny:
        V_top, _, tps_pool, speedup = rows[-1]
        assert speedup >= SPEEDUP_FLOOR, \
            f"V={V_top} fused tick {speedup:.2f}x < {SPEEDUP_FLOOR}x floor"
        if verbose:
            emit(f"pool_scale/floor_V{V_top}", 1e6 / tps_pool,
                 f"speedup={min(speedup, FLOOR_CLAMP):.2f}x")

    # ---- shed fraction by priority under a capacity squeeze -----------
    V_shed, shed_ticks = (8, 3) if tiny else (16, 8)
    frac = _shed_by_priority(sky, V_shed, shed_ticks, verbose)
    if verbose:
        parts = ";".join(f"shed_p{int(p)}={frac[p]:.2f}"
                         for p in sorted(frac))
        emit(f"pool_scale/shed_V{V_shed}", 0.0, parts)
    rows.append(("shed", frac))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(tiny="--tiny" in sys.argv[1:])

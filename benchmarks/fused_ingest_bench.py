"""Fused whole-run ingestion engine vs the windowed host loop.

The windowed ``run_skyscraper`` dispatches one window scan per planning
window and does its forecast/LP/label bookkeeping in host numpy between
windows, so a T-segment run costs T/W python round-trips. The fused
engine (``run_skyscraper_fused``) lowers forecast -> LP -> switch into
ONE ``lax.scan`` program: a whole run is a single dispatch and exactly
one compiled executable after warmup. Reports wall-clock for both,
speedup, per-decision cost, and the fused jit cache size.

    PYTHONPATH=src:. python benchmarks/fused_ingest_bench.py [--tiny]

``--tiny`` runs a seconds-scale smoke configuration (used by
``scripts/tier1.sh --bench-smoke`` so this path cannot silently rot).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.forecaster import init_forecaster
from repro.core.offline import Fitted
from repro.data.stream import generate

N_CORES = 8


def _synthetic_fitted(K=8, C=4, n_split=4, interval=64, seed=0) -> Fitted:
    """A Fitted profile with controlled shapes — skips the (expensive)
    offline phase; the online engines only read its tables."""
    rng = np.random.default_rng(seed)
    tau = COVID.segment_seconds
    power = np.sort(rng.random(K)).astype(np.float32)
    cost = np.sort(rng.random(K) * 20 + 0.5).astype(np.float32)
    cost[0] = min(cost[0], N_CORES * tau * 0.9)   # throughput guarantee
    rt = np.stack([cost / N_CORES, cost / N_CORES * 0.6,
                   cost / N_CORES * 0.3], 1)
    cl = np.stack([np.zeros(K), cost * 0.4, cost * 0.7], 1)
    on = np.stack([cost, cost * 0.6, cost * 0.3], 1)
    centers = np.sort(rng.random((C, K)), axis=0).astype(np.float32)
    params = init_forecaster(jax.random.PRNGKey(seed), n_split, C)
    return Fitted(workload=COVID, configs=[{"cfg": i} for i in range(K)],
                  power=power, cost=cost, place_rt=rt, place_on=on,
                  place_cl=cl, place_valid=np.ones((K, 3), bool),
                  centers=centers, forecaster=params, n_split=n_split,
                  interval_segments=interval, horizon_segments=256,
                  n_cores=N_CORES)


def _bench_one(fitted, stream, W, mode, verbose):
    tau = fitted.workload.segment_seconds
    T = stream.n_segments
    # +0.5 so float division can never floor the window length to W-1
    kw = dict(n_cores=N_CORES, cloud_budget_core_s=5_000.0,
              plan_days=(W + 0.5) * tau / 86400, forecast_mode=mode)

    # best-of-3 on both sides: single-shot timings flake badly on
    # shared/throttled CPUs, and a perf floor should compare the
    # engines, not the noisy-neighbor schedule
    IG.run_skyscraper(fitted, stream, **kw)               # warmup
    dt_loop = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        ref = IG.run_skyscraper(fitted, stream, **kw)
        dt_loop = min(dt_loop, time.perf_counter() - t0)

    IG.run_skyscraper_fused(fitted, stream, **kw)         # warmup
    cache = IG.fused_cache_size()
    dt_fused = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        got = IG.run_skyscraper_fused(fitted, stream, **kw)
        dt_fused = min(dt_fused, time.perf_counter() - t0)
    recompiles = IG.fused_cache_size() - cache

    assert abs(got.quality_sum - ref.quality_sum) \
        < 1e-3 * max(abs(ref.quality_sum), 1.0), \
        f"fused diverged: {ref.quality_sum} vs {got.quality_sum}"
    assert recompiles == 0, f"{recompiles} recompiles after warmup"
    assert cache == 1, f"expected ONE fused executable, cache={cache}"
    speedup = dt_loop / dt_fused
    if verbose:
        emit(f"fused_ingest/{mode}/T{T}_W{W}",
             dt_fused / T * 1e6,
             f"loop={dt_loop * 1e3:.1f}ms;fused={dt_fused * 1e3:.1f}ms;"
             f"speedup={speedup:.1f}x;windows={-(-T // W)};"
             f"fused_cache={cache}")
    return speedup


def run(verbose: bool = True, tiny: bool = False):
    fitted = _synthetic_fitted()
    if tiny:
        stream = generate(COVID, days=0.02, seed=3)       # T = 864
        speedup = _bench_one(fitted, stream, 64, "model", verbose)
        return [speedup]
    stream = generate(COVID, days=0.25, seed=3)           # T = 10800
    assert stream.n_segments >= 10_000
    speedup = _bench_one(fitted, stream, 128, "model", verbose)
    assert speedup >= 5.0, \
        f"fused engine must be >=5x the windowed loop, got {speedup:.1f}x"
    return [speedup]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(tiny="--tiny" in sys.argv[1:])

"""Paper Table 3 (App. E): runtime of each offline-phase step."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.workloads import COVID
from repro.core.offline import fit


def run(verbose: bool = True):
    f = fit(COVID, n_cores=8, days_unlabeled=8.0, n_categories=4, seed=0)
    total = sum(f.timings.values())
    for step, sec in f.timings.items():
        if verbose:
            emit(f"offline/{step}", sec * 1e6,
                 f"{sec:.2f}s ({100 * sec / total:.0f}% of offline)")
    if verbose:
        emit("offline/total", total * 1e6,
             f"{total:.2f}s; forecaster val_mae="
             f"{f.forecast_metrics['val_mae']:.4f}; K={len(f.configs)}")
    return f.timings


if __name__ == "__main__":
    run()

"""Warehouse Load + query engine vs the pre-warehouse numpy host loop.

Without the Load layer, answering "which five-minute windows had the
worst quality above a confidence floor?" means re-walking the run's
trace on the host: a Python loop over time windows doing numpy masking
and aggregation per window. The warehouse answers the same question as
ONE compiled dispatch over the device-resident columnar store
(vmapped filter mask -> segment_sum window aggregation -> lax.top_k).

Reports:
  - ingest: device-side ``SegmentStore.ingest_fused`` throughput for a
    full fused run (zero per-segment host transfers), plus the
    ingest-to-first-query-answer latency (cold: includes the one-time
    plan compile; warm: the steady-state answer latency).
  - query: scan throughput over >=100k stored segments for a batch of
    Filter -> WindowAgg -> TopK queries with varying thresholds,
    vs the equivalent numpy host-loop baseline. Asserts >=5x speedup,
    ZERO recompiles across the repeated queries, and exact (fp32)
    agreement with the numpy reference.

    PYTHONPATH=src:. python benchmarks/warehouse_bench.py [--tiny]

``--tiny`` runs a seconds-scale smoke configuration (used by
``scripts/tier1.sh --bench-smoke``) that keeps the correctness and
zero-recompile assertions but skips the speedup floor.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.fused_ingest_bench import _synthetic_fitted
from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.data.stream import generate
from repro.warehouse import (Filter, SegmentStore, TopK, WindowAgg,
                             execute, execute_ref, windows_for)
from repro.warehouse import query as Q

N_CORES = 8
WINDOW = 60           # 2 minutes of 2 s segments per query window
N_QUERIES = 16
TOP_K = 10


def _plan(thr: float, nw: int):
    return (Filter("quality", "ge", thr),
            WindowAgg(window=WINDOW, value="quality", agg="mean",
                      num_windows=nw),
            TopK(TOP_K, by="quality"))


def _host_loop_query(cols, n_rows, thr, nw):
    """The pre-warehouse implementation: walk the windows on the host,
    numpy-masking the rows that belong to each, then sort for the top
    k. Like the compiled engine (which must serve multi-stream stores),
    it makes NO row-order assumption — window membership is a predicate
    on the t column, not a slice."""
    t = cols["t"][:n_rows]
    q = cols["quality"][:n_rows]
    qok = q >= thr                      # one pass, shared by all windows
    means = np.zeros(nw, np.float32)
    counts = np.zeros(nw, np.float32)
    wid = t // WINDOW
    for w in range(nw):
        keep = (wid == w) & qok
        c = keep.sum()
        counts[w] = c
        if c:
            means[w] = q[keep].astype(np.float32).sum() / c
    score = np.where(counts > 0, means, -np.inf)
    idx = np.argsort(-score, kind="stable")[:TOP_K]
    return idx, score[idx]


def run(verbose: bool = True, tiny: bool = False):
    days = 0.02 if tiny else 2.5
    fitted = _synthetic_fitted()
    tau = fitted.workload.segment_seconds
    K = len(fitted.configs)
    stream = generate(COVID, days=days, seed=3)
    T = stream.n_segments
    if not tiny:
        assert T >= 100_000, T
    W = 64 if tiny else 256
    kw = dict(n_cores=N_CORES, cloud_budget_core_s=5_000.0,
              plan_days=(W + 0.5) * tau / 86400, forecast_mode="oracle")

    # ---- ingest: fused run -> store, all on device --------------------
    # warm BOTH the engine and the T-specialized ingest kernel (on a
    # throwaway store) so the timed run measures device-side ingest
    # throughput, not one-time compiles
    warm = SegmentStore(out_dim=K, chunk_rows=T // 4)
    IG.run_skyscraper_fused(fitted, stream, sink=warm, **kw)
    jax.block_until_ready(warm.columns)
    # chunk size divides T: the query kernel scans no capacity padding
    store = SegmentStore(out_dim=K, chunk_rows=T // 4)
    t0 = time.perf_counter()
    IG.run_skyscraper_fused(fitted, stream, sink=store, **kw)
    jax.block_until_ready(store.columns)
    dt_ingest = time.perf_counter() - t0
    assert store.n_rows == T
    nw = windows_for(store, WINDOW)

    # ---- ingest-to-first-answer: cold (plan compiles) then warm -------
    t0 = time.perf_counter()
    jax.block_until_ready(execute(store, _plan(0.5, nw)))
    dt_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(execute(store, _plan(0.5, nw)))
    dt_warm = time.perf_counter() - t0
    if verbose:
        emit(f"warehouse/ingest/T{T}", dt_ingest / T * 1e6,
             f"ingest={dt_ingest * 1e3:.1f}ms;"
             f"first_answer={dt_first * 1e3:.1f}ms;"
             f"warm_answer={dt_warm * 1e3:.2f}ms;rows={T}")

    # ---- query scan throughput vs the numpy host loop -----------------
    thrs = np.linspace(0.2, 0.8, N_QUERIES)
    cols_np = store.host_rows()

    cache0 = Q.compile_cache_size()
    t0 = time.perf_counter()
    for thr in thrs:
        table, mask = execute(store, _plan(float(thr), nw))
    jax.block_until_ready((table, mask))
    dt_jax = time.perf_counter() - t0
    recompiles = Q.compile_cache_size() - cache0
    assert recompiles == 0, f"{recompiles} recompiles across queries"

    t0 = time.perf_counter()
    for thr in thrs:
        idx_np, score_np = _host_loop_query(cols_np, store.n_rows,
                                            float(thr), nw)
    dt_np = time.perf_counter() - t0

    # correctness: the compiled answer == the numpy reference, exactly
    ref, rmask = execute_ref(cols_np, store.n_rows, _plan(float(thrs[-1]),
                                                          nw))
    assert np.array_equal(np.asarray(table["quality"]), ref["quality"])
    assert np.array_equal(np.asarray(table["window"]), ref["window"])
    assert np.array_equal(np.asarray(mask), rmask)
    # and the host-loop baseline agrees with it (same top windows)
    assert np.array_equal(idx_np[rmask], ref["window"][rmask])

    speedup = dt_np / dt_jax
    scanned = N_QUERIES * store.n_rows
    if verbose:
        emit(f"warehouse/query/T{T}_q{N_QUERIES}",
             dt_jax / N_QUERIES * 1e6,
             f"host_loop={dt_np * 1e3:.1f}ms;fused={dt_jax * 1e3:.1f}ms;"
             f"speedup={speedup:.1f}x;"
             f"scan={scanned / dt_jax / 1e6:.0f}Mrows/s;recompiles=0")
    if not tiny:
        assert speedup >= 5.0, \
            f"warehouse query must be >=5x the host loop, got {speedup:.1f}x"

    # ---- fused Pallas path: exactness + the broken scatter floor ------
    # interpret mode on CPU is a correctness path, so this leg records
    # the census (ZERO executed scatters for the groupby-style plan),
    # not a timing claim; on TPU the same kernel compiles natively.
    pplan = (Filter("quality", "ge", float(thrs[-1])),
             WindowAgg(window=WINDOW, value="quality", agg="mean",
                       num_windows=nw))
    pref, prmask = execute_ref(cols_np, store.n_rows, pplan)
    pt, pm = execute(store, pplan, use_pallas=True)
    assert np.array_equal(np.asarray(pm), prmask)
    assert np.array_equal(np.asarray(pt["count"]), pref["count"])
    assert np.allclose(np.asarray(pt["quality"]), pref["quality"],
                       rtol=1e-5, atol=1e-4)
    from repro.analysis import DEFAULT_INVARIANTS
    from repro.analysis.jaxpr_lint import lint_jaxpr, trace_closed_jaxpr
    spec, fvals = Q.normalize(pplan)
    args = (store.columns, np.int32(store.n_rows), fvals)
    _, census = lint_jaxpr(trace_closed_jaxpr(
        lambda c, n, fv: Q._run_plan(c, n, fv, spec=spec,
                                     use_pallas=True), args, {}),
        DEFAULT_INVARIANTS)
    n_scatter = census["totals"]["scatter_executed"]
    assert n_scatter == 0, f"Pallas query path executes {n_scatter} scatters"
    if verbose:
        emit(f"warehouse/query_pallas/T{T}", 0.0,
             f"scatter_ops=0;exact=count,window;mean_rtol=1e-5;"
             f"interpret={jax.default_backend() != 'tpu'}")
    return [speedup]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(tiny="--tiny" in sys.argv[1:])

"""Paper Fig. 15 + Table 4 (§5.6): knob-switcher content-classification
accuracy, the Type-A (1-D projection) vs Type-B (timing lag) error
split, and accuracy vs the number of content categories."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, fitted, stream
from repro.configs.workloads import COVID
from repro.core import ingest as IG
from repro.core.offline import fit
from repro.data.stream import generate


def run(verbose: bool = True):
    rows = []
    for wname in ("covid", "mot"):
        f = fitted(wname, 8, 3)     # paper App. K: 3 categories
        s = stream(wname, days=1.0)
        res = IG.run_skyscraper(f, s, n_cores=8,
                                cloud_budget_core_s=5000.0, plan_days=0.25)
        quals = s.quality(f.power, seed=0)
        d = ((quals[:, None, :] - f.centers[None]) ** 2).sum(-1)
        true_cat = d.argmin(1)                 # category of each segment
        pred = res.c_trace
        T = len(pred)
        # switcher classifies segment t from segment t-1's quality:
        total_err = (pred[1:] != true_cat[1:]).mean()
        # Type-B: the content actually changed between t-1 and t
        type_b = ((true_cat[:-1] != true_cat[1:])
                  & (pred[1:] == true_cat[:-1])).mean()
        type_a = total_err - type_b
        rows.append((wname, total_err, type_a, type_b))
        if verbose:
            emit(f"switcher_acc/{wname}/total_err", total_err * 1e6,
                 f"err={total_err * 100:.2f}%  (paper: 2.1% covid, "
                 f"6.6% mot)")
            emit(f"switcher_acc/{wname}/type_a", max(type_a, 0) * 1e6,
                 f"typeA={max(type_a, 0) * 100:.2f}%")
            emit(f"switcher_acc/{wname}/type_b", type_b * 1e6,
                 f"typeB={type_b * 100:.2f}%")
    # Table 4: accuracy vs number of categories
    for ncat in (1, 2, 3, 4, 8):
        f = fit(COVID, n_cores=8, days_unlabeled=6.0, n_categories=ncat,
                seed=0)
        s = generate(COVID, days=0.5, seed=5)
        res = IG.run_skyscraper(f, s, n_cores=8,
                                cloud_budget_core_s=5000.0, plan_days=0.25)
        quals = s.quality(f.power, seed=0)
        d = ((quals[:, None, :] - f.centers[None]) ** 2).sum(-1)
        true_cat = d.argmin(1)
        acc = (res.c_trace[1:] == true_cat[1:]).mean()
        if verbose:
            emit(f"switcher_acc/covid/ncat{ncat}", acc * 1e6,
                 f"acc={acc * 100:.1f}%;quality={res.quality_pct:.1f}%")
    return rows


if __name__ == "__main__":
    run()
